"""In-house AdamW with global-norm clipping and warmup+cosine schedule.

Optimizer state shards exactly like the parameters (the `m`/`v` trees reuse
the parameter logical axes), so FSDP-sharded training keeps the full
ZeRO-style distribution of optimizer memory for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: Any                   # pytree like params
    v: Any


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio."""
    step_f = step.astype(jnp.float32)
    warm = step_f / jnp.maximum(cfg.warmup_steps, 1)
    denom = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    progress = jnp.clip((step_f - cfg.warmup_steps) / denom, 0.0, 1.0)
    cosine = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return cfg.learning_rate * jnp.where(step_f < cfg.warmup_steps,
                                         warm, cosine)


def init_opt_state(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.zeros_like, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def _decay_mask(path: Tuple, leaf) -> bool:
    """No weight decay for 1-D params (norm scales, biases, gates)."""
    return leaf.ndim >= 2


def adamw_update(cfg: OptimizerConfig, params, grads, state: AdamWState
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, grad_norm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:   # decay mask: skip 1-D params
            delta = delta + cfg.weight_decay * pf
        p_new = pf - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), \
            v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": grad_norm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
