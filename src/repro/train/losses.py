"""Vocab-sharded cross-entropy.

The logits tensor (B, T, V_padded) stays sharded over `model` (vocab) and
`(pod, data)` (batch); the log-sum-exp and the label-logit extraction are
written as reductions/einsums over the sharded vocab axis so XLA inserts only
small (B, T)-shaped all-reduces — the full unsharded logits tensor never
materializes.  Padded vocab columns (Megatron-style padding, see
`transformer.padded_vocab`) are masked to -inf.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  loss_mask: Optional[jax.Array] = None,
                  vocab_size: Optional[int] = None,
                  z_loss_coef: float = 0.0
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """logits: (B, T, Vp); labels: (B, T) int32; loss_mask: (B, T) 0/1."""
    B, T, Vp = logits.shape
    lf = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < Vp:
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, Vp), 2)
        lf = jnp.where(col < vocab_size, lf, -1e30)
    # NOTE: no stop_gradient on the max — the +m / -m contributions cancel
    # analytically, giving the exact softmax gradient (a one-sided
    # stop_gradient would add a spurious one-hot at the argmax).
    m = jnp.max(lf, axis=-1, keepdims=True)
    sumexp = jnp.sum(jnp.exp(lf - m), axis=-1)
    lse = jnp.log(sumexp) + m[..., 0]
    # label logit via one-hot contraction (gather over a sharded axis would
    # force an all-gather; the einsum keeps everything local + all-reduce).
    onehot = jax.nn.one_hot(labels, Vp, dtype=lf.dtype)
    label_logit = jnp.einsum("btv,btv->bt", lf, onehot)
    nll = lse - label_logit
    if z_loss_coef > 0.0:
        nll = nll + z_loss_coef * jnp.square(lse)
    if loss_mask is None:
        loss_mask = jnp.ones((B, T), jnp.float32)
    loss_mask = loss_mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    loss = jnp.sum(nll * loss_mask) / denom
    acc = jnp.sum((jnp.argmax(lf, -1) == labels) * loss_mask) / denom
    return loss, {"loss": loss, "accuracy": acc,
                  "tokens": jnp.sum(loss_mask)}


def chunked_ce(x: jax.Array, head_w: jax.Array, labels: jax.Array,
               loss_mask: Optional[jax.Array], vocab_size: int,
               chunk: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused LM-head + cross-entropy over sequence chunks.

    x: (B, T, D) final hidden; head_w: (D, Vp).  The (B, chunk, Vp) logits
    tile is the only logits tensor that ever exists (forward *and* backward
    — the scan body is rematerialized), which is what lets 256k-vocab archs
    fit training memory.  Sums are accumulated in f32.
    """
    B, T, D = x.shape
    Vp = head_w.shape[-1]
    if loss_mask is None:
        loss_mask = jnp.ones((B, T), jnp.float32)
    loss_mask = loss_mask.astype(jnp.float32)
    if not (chunk and T > chunk and T % chunk == 0):
        logits = jnp.einsum("btd,dv->btv", x, head_w.astype(x.dtype))
        return cross_entropy(logits, labels, loss_mask, vocab_size)
    n = T // chunk
    xs = (x.reshape(B, n, chunk, D).swapaxes(0, 1),
          labels.reshape(B, n, chunk).swapaxes(0, 1),
          loss_mask.reshape(B, n, chunk).swapaxes(0, 1))

    @jax.checkpoint
    def body(carry, xs_c):
        nll_sum, correct, ntok = carry
        x_c, y_c, m_c = xs_c
        logits = jnp.einsum("btd,dv->btv", x_c, head_w.astype(x_c.dtype))
        lf = logits.astype(jnp.float32)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, Vp), 2)
        lf = jnp.where(col < vocab_size, lf, -1e30)
        m = jnp.max(lf, axis=-1)
        lse = jnp.log(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)) + m
        onehot = jax.nn.one_hot(y_c, Vp, dtype=lf.dtype)
        label_logit = jnp.einsum("btv,btv->bt", lf, onehot)
        nll = (lse - label_logit) * m_c
        hit = (jnp.argmax(lf, -1) == y_c) * m_c
        return (nll_sum + jnp.sum(nll), correct + jnp.sum(hit),
                ntok + jnp.sum(m_c)), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32))
    (nll_sum, correct, ntok), _ = jax.lax.scan(body, init, xs)
    denom = jnp.maximum(ntok, 1.0)
    loss = nll_sum / denom
    return loss, {"loss": loss, "accuracy": correct / denom, "tokens": ntok}
