"""Train-step construction: loss, grads, microbatch accumulation, update.

`make_train_step(cfg, opt_cfg, ...)` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for `jax.jit` with explicit
in/out shardings (see `repro.launch.dryrun`).  Features:

* vocab-sharded cross-entropy (never materializes unsharded logits),
* MoE auxiliary (load-balance) loss folded in,
* per-layer remat (``jax.checkpoint`` around each scanned superblock),
* gradient accumulation over microbatches via ``jax.lax.scan`` (grads
  averaged in f32),
* donated state for in-place buffer reuse.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.train import losses
from repro.train.optimizer import (AdamWState, OptimizerConfig, adamw_update,
                                   init_opt_state)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(rng, cfg: ArchConfig) -> TrainState:
    from repro.models.params import init_params
    params = init_params(rng, tf.model_specs(cfg), cfg.param_dtype)
    return TrainState(params=params, opt=init_opt_state(params))


def train_state_axes(cfg: ArchConfig):
    """Logical-axes tree mirroring TrainState (for shardings)."""
    from repro.models.params import param_axes
    axes = param_axes(tf.model_specs(cfg))
    return TrainState(params=axes,
                      opt=AdamWState(step=(), m=axes, v=axes))


def batch_axes(cfg: ArchConfig, accum: int = 1) -> Dict[str, tuple]:
    lead = ("microbatch",) if accum > 1 else ()
    ax = {"tokens": lead + ("act_batch", None),
          "labels": lead + ("act_batch", None),
          "loss_mask": lead + ("act_batch", None)}
    if cfg.family == "vlm":
        ax["pixel_embeds"] = lead + ("act_batch", None, None)
    if cfg.family == "audio":
        ax["audio_embeds"] = lead + ("act_batch", None, None)
    return ax


def _loss_fn(params, batch: Dict, cfg: ArchConfig, remat: bool):
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.ce_chunk:
        # fused chunked LM-head + CE: full (B,T,V) logits never exist
        x, aux = tf.forward_hidden(params, batch, cfg, remat=remat)
        if cfg.family == "vlm":
            x = x[:, cfg.vision_prefix_len:]
        loss, metrics = losses.chunked_ce(
            x, tf.head_weights(params, cfg), labels, mask,
            vocab_size=cfg.vocab_size, chunk=cfg.ce_chunk)
    else:
        logits, aux = tf.forward_train(params, batch, cfg, remat=remat)
        if cfg.family == "vlm":
            # logits cover [pixels, tokens]; loss only on the token tail.
            logits = logits[:, cfg.vision_prefix_len:]
        loss, metrics = losses.cross_entropy(
            logits, labels, mask, vocab_size=cfg.vocab_size)
    total = loss + aux
    metrics["aux_loss"] = aux
    return total, metrics


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    accum: int = 1, remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    With accum > 1, every batch leaf carries a leading (accum,) microbatch
    axis and gradients are averaged across microbatches before the update.
    """
    grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)

    from repro.distributed.sharding import shard as _shard
    from repro.models.params import param_axes
    _axes = param_axes(tf.model_specs(cfg))

    def _constrain_grads(grads):
        """Pin gradients to the parameter shardings.  Without this the
        backward scan accumulates *unsharded* per-layer gradient stacks and
        reduce-scatters only after the loop (measured: +GiBs of temp on the
        30-40L archs); the constraint propagates through the accumulation
        so each layer's dW is scattered inside the loop."""
        return jax.tree.map(lambda g, ax: _shard(g, ax), grads, _axes)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch, cfg, remat)
        return _constrain_grads(grads), metrics

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if accum == 1:
            grads, metrics = single(state.params, batch)
        else:
            def micro(carry, mb):
                g_acc = carry
                g, m = single(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / accum,
                    g_acc, g)
                return g_acc, m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, ms = jax.lax.scan(micro, g0, batch)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics.update(opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step
