"""Trainer: the runnable training job the orchestrator schedules.

Implements the *moveable/checkpointable* job contract (DESIGN.md §2):

* periodic checkpointing (step-boundary durable progress),
* cooperative preemption — `request_stop()` (the orchestrator's evict signal)
  makes the loop checkpoint and return cleanly,
* resume-from-latest on construction, so an evicted/failed job rescheduled
  on another node continues instead of restarting.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 2
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: OptimizerConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data = SyntheticLM(cfg, data_cfg)
        self.log = log_fn
        self._stop = threading.Event()
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir,
                                       keep=tcfg.keep_checkpoints)
                     if tcfg.checkpoint_dir else None)
        self.state = init_train_state(jax.random.key(tcfg.seed), cfg)
        if self.ckpt and self.ckpt.latest_step() is not None:
            self.state, self.step, _ = self.ckpt.restore(self.state)
            self.log(f"[trainer] resumed from step {self.step}")
        self._step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, accum=data_cfg.accum),
            donate_argnums=(0,))

    # -- the orchestrator's evict signal ---------------------------------------
    def request_stop(self) -> None:
        self._stop.set()

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()

    def checkpoint(self) -> None:
        if self.ckpt:
            self.ckpt.save(self.step, self.state)

    # -- main loop ---------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        t0 = time.time()
        while self.step < self.tcfg.total_steps:
            if self._stop.is_set():
                self.checkpoint()
                self.log(f"[trainer] preempted at step {self.step}; "
                         "checkpointed")
                return {"completed": 0.0, "step": float(self.step)}
            batch = jax.tree.map(jnp.asarray, self.data.batch(self.step))
            self.state, metrics = self._step_fn(self.state, batch)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or \
               self.step == self.tcfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                self.history.append(m)
                self.log(f"[trainer] step {self.step} "
                         f"loss={m['loss']:.4f} acc={m['accuracy']:.3f} "
                         f"gnorm={m['grad_norm']:.2f}")
            if self.tcfg.checkpoint_every and \
               self.step % self.tcfg.checkpoint_every == 0:
                self.checkpoint()
        self.checkpoint()
        dt = time.time() - t0
        self.log(f"[trainer] done: {self.step} steps in {dt:.1f}s")
        return {"completed": 1.0, "step": float(self.step),
                "final_loss": self.history[-1]["loss"] if self.history else -1}
