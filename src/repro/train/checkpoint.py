"""Checkpoint manager: atomic, keep-N, resumable, mesh-flexible.

This is the substrate that makes the paper's *moveable* label real for
training jobs (DESIGN.md §2): an evicted trainer checkpoints, is rescheduled,
and resumes from the last durable step — and the *elastic* path restores the
same checkpoint onto a different mesh (the leaves are stored unsharded, so
restoring is `device_put` with the new mesh's shardings).

Format: one directory per step, `step_<n>/` containing `leaves.npz` (flat
leaf arrays keyed by tree path) + `meta.json`; a `LATEST` file is updated
via atomic rename last, so a crash mid-save never corrupts the newest valid
checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _aside(self, final: str) -> str:
        """Parking name for the old copy of a step during a re-save swap.
        Dot-prefixed so `all_steps` never counts it as a checkpoint."""
        return os.path.join(self.directory,
                            "." + os.path.basename(final) + ".old")

    def _recover(self, final: str) -> None:
        """Heal a crash between the aside-rename and the swap in `save`:
        if the step dir is gone but its aside survives, the aside *is*
        the newest valid copy of that step — put it back."""
        aside = self._aside(final)
        if os.path.isdir(aside):
            if os.path.isdir(final):
                shutil.rmtree(aside, ignore_errors=True)
            else:
                os.rename(aside, final)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        leaves = {k: np.asarray(v) for k, v in _flatten_with_paths(tree)}
        final = os.path.join(self.directory, f"step_{step:08d}")
        self._recover(final)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        aside = None
        try:
            np.savez(os.path.join(tmp, "leaves.npz"), **leaves)
            meta = {"step": step, "extra": extra or {}}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                # Re-save of an existing step: park the old copy instead
                # of deleting it, so a crash anywhere in the swap leaves a
                # restorable version of the step LATEST may still name.
                aside = self._aside(final)
                os.rename(final, aside)
            try:
                os.rename(tmp, final)
            except BaseException:
                if aside is not None:
                    os.rename(aside, final)
                    aside = None
                raise
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        if aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
        # LATEST last: readers never see a partial checkpoint.
        latest_tmp = os.path.join(self.directory, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- load -----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                name = f.read().strip()
            self._recover(os.path.join(self.directory, name))
            if os.path.isdir(os.path.join(self.directory, name)):
                return int(name[5:])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int, Dict]:
        """Restore into the structure of `tree_like` (shapes must match).

        `shardings`: optional pytree of NamedSharding (elastic restore onto a
        different mesh); leaves are device_put accordingly.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        self._recover(d)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "leaves.npz"))
        flat = _flatten_with_paths(tree_like)
        leaves = []
        for key, like in flat:
            arr = data[key]
            assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
            leaves.append(arr.astype(like.dtype))
        treedef = jax.tree_util.tree_structure(tree_like)
        restored = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings)
        return restored, meta["step"], meta.get("extra", {})
