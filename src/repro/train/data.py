"""Synthetic deterministic data pipeline.

A production input pipeline's contract, kept: deterministic per (seed, step,
host), shard-aware (each data-parallel host materializes only its slice),
prefetchable, and resumable from an arbitrary step (the "checkpointed"
dataset state is just the step counter — restart-safe by construction, which
is what the orchestrator's checkpoint/restart fault-tolerance relies on).

The token stream is a fixed-vocabulary LCG-mixed sequence with a learnable
structure (periodic n-gram patterns) so small models show decreasing loss in
the examples — not pure noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8            # per-host examples per step
    seq_len: int = 128
    seed: int = 0
    accum: int = 1                 # leading microbatch axis if > 1
    pattern_period: int = 16       # learnable structure in the stream


class SyntheticLM:
    """Deterministic synthetic LM batches: batch(step) is pure."""

    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig,
                 host_id: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self.dc = data_cfg
        self.host_id = host_id
        self.num_hosts = num_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        dc, cfg = self.dc, self.cfg
        shape = (dc.accum, dc.batch_size, dc.seq_len + 1) if dc.accum > 1 \
            else (dc.batch_size, dc.seq_len + 1)
        rng = np.random.default_rng(
            (dc.seed * 1_000_003 + step) * 65_537 + self.host_id)
        # structured stream: a *fixed* periodic pattern (per seed) seen
        # through per-step noise and per-row phase — learnable structure.
        pat_rng = np.random.default_rng(dc.seed * 7_919 + 13 * self.host_id)
        base = pat_rng.integers(0, cfg.vocab_size, size=(dc.pattern_period,))
        reps = -(-(dc.seq_len + 1) // dc.pattern_period) + 1
        track = np.tile(base, reps)
        phase = rng.integers(0, dc.pattern_period, size=shape[:-1])
        idx = phase[..., None] + np.arange(dc.seq_len + 1)
        stream = track[idx]
        noise = rng.integers(0, cfg.vocab_size, size=shape)
        noisy = rng.random(shape) < 0.1
        tokens = np.where(noisy, noise, stream).astype(np.int32)
        out = {"tokens": tokens[..., :-1],
               "labels": tokens[..., 1:],
               "loss_mask": np.ones(shape[:-1] + (dc.seq_len,), np.float32)}
        if cfg.family == "vlm":
            out["pixel_embeds"] = 0.02 * rng.standard_normal(
                shape[:-1] + (cfg.vision_prefix_len, cfg.d_model)
            ).astype(np.float32)
        if cfg.family == "audio":
            out["audio_embeds"] = 0.02 * rng.standard_normal(
                shape[:-1] + (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
