"""Elastic scaling: resume a checkpoint on a different mesh (DESIGN.md §4).

Checkpoints store unsharded leaves (`repro.train.checkpoint`), so elasticity
reduces to recomputing shardings for the new mesh from the same logical axes
and `device_put`-ing on restore.  The orchestrator uses this when it resizes
a job (scale the data axis up/down) instead of merely migrating it.

`plan_resize` also exposes the policy knob: given a new device count, choose
the (data, model) split that keeps the model axis divisibility constraints
of the architecture — the fleet-level analogue of the paper's "number and
type of VMs to launch" decision.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.distributed.sharding import (DEFAULT_RULES, ShardingCtx,
                                        tree_shardings)
from repro.models.params import param_axes, param_shapes
from repro.models import transformer as tf
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import TrainState, train_state_axes


def plan_resize(n_devices: int, cfg: ArchConfig,
                prefer_model: int = 16) -> Tuple[int, int]:
    """Choose (data, model) for a new device count: the largest model-axis
    size <= prefer_model that divides both the device count and the arch's
    shardable dims (heads or d_ff or experts)."""
    dims = [d for d in (cfg.num_heads, cfg.d_ff or 0, cfg.n_experts or 0,
                        cfg.d_model) if d]
    for model in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % model:
            continue
        if any(dim % model == 0 for dim in dims):
            return n_devices // model, model
    return n_devices, 1


def shardings_for_mesh(mesh: Mesh, cfg: ArchConfig, *, state: bool = True):
    """NamedSharding tree for a TrainState (or bare params) on `mesh`."""
    ctx = ShardingCtx(mesh, dict(DEFAULT_RULES))
    if state:
        from repro.train.train_step import init_train_state
        axes = train_state_axes(cfg)
        shapes = jax.eval_shape(lambda: init_train_state(
            jax.random.key(0), cfg))
    else:
        axes = param_axes(tf.model_specs(cfg))
        shapes = param_shapes(tf.model_specs(cfg), cfg.param_dtype)
    return tree_shardings(ctx, shapes, axes)


def restore_elastic(ckpt: CheckpointManager, cfg: ArchConfig, mesh: Mesh,
                    step: Optional[int] = None):
    """Restore the latest checkpoint resharded for `mesh`."""
    from repro.train.train_step import init_train_state
    like = jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg))
    shardings = shardings_for_mesh(mesh, cfg, state=True)
    return ckpt.restore(like, step=step, shardings=shardings)
