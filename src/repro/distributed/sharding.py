"""Logical-axis sharding rules with divisibility fallback (DESIGN.md §4).

Parameters and activations are annotated with *logical* axis names; rules map
each logical name to an ordered list of candidate mesh axes.  Resolution picks
the first candidate whose mesh size divides the dimension and whose axes are
not already taken by another dimension of the same tensor — otherwise the
dimension is replicated.  This is what lets one model definition serve
archs from xlstm-125m (d_model=768, 4 heads) to command-r-35b (64 heads)
on the same (pod, data, model) production mesh.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Tuple[str, ...]
Rule = Tuple[str, Tuple[Union[str, Tuple[str, ...]], ...]]

# Candidate mesh axes per logical axis, in preference order.  ("pod","data")
# as a single tuple entry means "shard over the flattened pod×data axes".
DEFAULT_RULES: Dict[str, Tuple] = {
    # -- parameters ----------------------------------------------------------
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "expert": ("model",),
    "rnn": ("model",),
    "rnn_blocks": ("model",),
    "embed": (("pod", "data"), "data"),       # ZeRO-3/FSDP over DP axes
    "layer": (),                              # scan stack dim: never sharded
    "head_dim": (),
    "conv": (),
    # -- activations -----------------------------------------------------------
    "act_batch": (("pod", "data"), "data"),
    # Megatron-style sequence parallelism for the *residual stream only*:
    # block inputs/outputs are (batch, seq/model, embed); attention/MLP
    # internals re-gather seq and shard heads/mlp instead (the transitions
    # lower to the standard SP all-gather + reduce-scatter pairs).  Without
    # this, the per-layer saved residuals of command-r-35b@train_4k alone
    # exceed HBM (40 layers x 1 GB/device).
    "act_seq": ("model",),
    # query-sequence dim *inside* attention: context parallelism for archs
    # whose head count does not divide the model axis (qwen1.5-32b: 40 H).
    "act_q_seq": (),
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_expert": ("model",),
    "act_rnn": ("model",),
    "act_kv_seq": ("model",),                 # decode: shard the KV cache seq
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Dict[str, Tuple]

    def axis_size(self, entry) -> int:
        if isinstance(entry, tuple):
            return int(np.prod([self.mesh.shape[a] for a in entry]))
        return int(self.mesh.shape[entry])

    def resolve(self, dims: Sequence[int],
                axes: Sequence[Optional[str]]) -> P:
        """Logical axes -> PartitionSpec with divisibility fallback."""
        assert len(dims) == len(axes), (dims, axes)
        used: set = set()
        out: List = []
        for dim, name in zip(dims, axes):
            spec = None
            for entry in self.rules.get(name, ()) if name else ():
                flat = entry if isinstance(entry, tuple) else (entry,)
                if any(a in used for a in flat):
                    continue
                if any(a not in self.mesh.shape for a in flat):
                    continue
                if dim % self.axis_size(entry) != 0:
                    continue   # divisibility fallback
                spec = entry
                used.update(flat)
                break
            out.append(spec)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding_for(self, shape: Sequence[int],
                     axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(shape, axes))


_CTX: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx",
                                                      default=None)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Optional[Dict[str, Tuple]] = None):
    """Enable logical-axis sharding constraints inside model code."""
    token = _CTX.set(ShardingCtx(mesh, dict(rules or DEFAULT_RULES)))
    try:
        yield _CTX.get()
    finally:
        _CTX.reset(token)


def current_ctx() -> Optional[ShardingCtx]:
    return _CTX.get()


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a context
    (CPU smoke tests) so model code stays mesh-agnostic."""
    ctx = current_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding_for(x.shape, axes))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def tree_shardings(ctx: ShardingCtx, shapes_tree, axes_tree):
    """NamedSharding pytree for jit in_shardings/out_shardings.

    ``axes_tree`` mirrors ``shapes_tree`` with tuples of logical axis names
    as leaves (the tree is mapped over axes first since a tuple-of-str leaf
    would otherwise be treated as an inner node).
    """
    return jax.tree.map(
        lambda a, s: ctx.sharding_for(s.shape, a),
        axes_tree, shapes_tree, is_leaf=_is_axes_leaf)
