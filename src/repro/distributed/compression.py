"""Int8 gradient compression for cross-pod all-reduce (DESIGN.md §4).

On a multi-pod fleet the `pod` axis rides the slow inter-pod links (DCN),
while `data`/`model` ride intra-pod ICI.  A hierarchical gradient reduction —
full-precision psum within the pod, int8 (value+scale) psum across pods —
cuts cross-pod collective bytes ~4× with stochastic-rounding-free symmetric
quantization (max-abs shared scale, itself a cheap f32 psum-max).

Usage: build the DDP train step with `make_compressed_ddp_step` (a
`shard_map` over the whole mesh; params replicated, batch sharded).  This is
the pure-DP path — for FSDP/TP jobs the pjit pipeline is used instead and
compression applies to the long_500k/small-model cells where pure DP is the
natural layout.  Compression error is bounded by scale/2 per element;
`tests/test_distributed_multidev.py` asserts end-to-end closeness vs fp32.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Compressed psum: shared max-abs scale + int8 payload (as int32 psum —
    int8 summands across <=128 pods cannot overflow int32)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = quantize_int8(x, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def hierarchical_grad_sync(grads, *, intra_axes=("data",), pod_axis="pod",
                           compress: bool = True):
    """Inside shard_map: psum grads over intra-pod axes in f32, then across
    pods in int8 (or f32 when compress=False, for the ablation)."""
    def sync(g):
        g = jax.lax.psum(g.astype(jnp.float32), intra_axes)
        if compress:
            return psum_int8(g, pod_axis)
        return jax.lax.psum(g, pod_axis)
    return jax.tree.map(sync, grads)


def make_compressed_ddp_step(loss_fn: Callable, mesh: Mesh,
                             batch_axes: Tuple[str, ...] = ("pod", "data",
                                                            "model"),
                             compress: bool = True,
                             pod_axis: str = "pod"):
    """DDP train-grad step: params replicated, batch sharded over all axes;
    returns (mean_loss, synced_grads).  Optimizer update happens outside
    (it is identical on every device since grads are fully synced)."""
    intra = tuple(a for a in batch_axes if a != pod_axis)

    def local_step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = hierarchical_grad_sync(grads, intra_axes=intra,
                                       pod_axis=pod_axis, compress=compress)
        grads = jax.tree.map(
            lambda g: g / mesh.devices.size, grads)
        loss = jax.lax.pmean(loss, batch_axes)
        return loss, grads

    from jax.experimental.shard_map import shard_map
    return shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(batch_axes)),
        out_specs=(P(), P()),
        check_rep=False)
