"""Observability layer: flight recorder, decision attribution, and a
cycle-phase profiler (ISSUE 10 tentpole).

Always compiled out unless enabled: every instrumented object in the core
carries an ``obs`` attribute defaulting to ``None``, and each hot-path
hook is a single ``is not None`` test — with ``ExperimentSpec.obs`` unset
nothing else runs and results are untouched (the ci.sh bench-regression
gates pin the obs-off overhead to the committed baselines).  With obs
enabled, recording is strictly passive, so ``ExperimentResult`` stays
bit-identical (``tests/test_obs.py``).

Quickstart::

    from repro.core import ExperimentSpec
    from repro.obs import ObsConfig, run_recorded

    spec = ExperimentSpec(scenario="flash-crowd", scenario_jobs=400,
                          autoscaler="predictive", obs=ObsConfig())
    result, rec = run_recorded(spec)
    rec.export("run.npz")           # or .json (exact float round-trip)

    # then: python scripts/obsreport.py --load run.npz
"""
from repro.obs.profiler import PhaseProfiler, chrome_trace
from repro.obs.recorder import (EventLog, ObsConfig, ObsRecorder,
                                load_bundle, save_bundle)
from repro.obs.report import (decision_summary, explain_events, phase_table,
                              render_report)


def run_recorded(spec):
    """``run_experiment`` with observability forced on; returns
    ``(ExperimentResult, ObsRecorder)``.  ``spec.obs`` may be an
    ``ObsConfig`` (used as-is) or ``None`` (defaults apply)."""
    import dataclasses

    from repro.core.experiment import build_simulation

    if spec.obs is None:
        spec = dataclasses.replace(spec, obs=ObsConfig())
    sim = build_simulation(spec)
    result = sim.run()
    result.workload = spec.workload_label()
    return result, sim.obs


__all__ = [
    "EventLog", "ObsConfig", "ObsRecorder", "PhaseProfiler",
    "chrome_trace", "load_bundle", "save_bundle", "run_recorded",
    "decision_summary", "explain_events", "phase_table", "render_report",
]
