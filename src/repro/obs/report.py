"""Run-report rendering over obs bundles (``scripts/obsreport.py`` backend).

Everything renders from the plain-dict bundle shape
(``ObsRecorder.bundle()`` live, or ``recorder.load_bundle(path)`` from
disk), so the CLI can report on a run it just executed or on an exported
trace with identical output:

* ``phase_table``    — per-phase breakdown of where the cycle time went;
* ``decision_summary`` — event counts with the interesting splits
  (scale-outs by disposition, scale-ins by Alg. 6 step, evictions by
  reason);
* ``explain_events`` — per-decision drill-down: one line per event with
  its attributed inputs (pending depth, utilization, forecast
  rate/confidence, rate-limiter state) decoded per kind.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs.recorder import (EV_BIND, EV_EVICT, EV_FORECAST, EV_NOTICE,
                                EV_RESCHED, EV_SCALE_IN, EV_SCALE_OUT, FCOLS,
                                KIND_NAMES, REASON_NAMES, RESCHED_NAMES,
                                SCALE_OUT_NAMES)


def _fmt_s(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:8.3f} s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:8.3f} ms"
    return f"{seconds * 1e6:8.1f} µs"


def phase_table(bundle: Dict) -> str:
    """Per-phase profiler breakdown, heaviest first."""
    prof = bundle.get("profile")
    if prof is None or not prof["names"]:
        return "(no profile data: run with ObsConfig(profile=True))"
    names = prof["names"]
    count = np.asarray(prof["count"])
    total = np.asarray(prof["total_s"], np.float64)
    mn = np.asarray(prof["min_s"], np.float64)
    mx = np.asarray(prof["max_s"], np.float64)
    grand = float(total.sum()) or 1.0
    order = np.argsort(-total, kind="stable")
    lines = [f"{'phase':<22} {'calls':>9} {'total':>11} {'share':>6} "
             f"{'mean':>11} {'min':>11} {'max':>11}"]
    for i in order:
        c = int(count[i])
        mean = total[i] / c if c else 0.0
        lines.append(
            f"{names[i]:<22} {c:>9d} {_fmt_s(float(total[i])):>11} "
            f"{100.0 * total[i] / grand:5.1f}% {_fmt_s(mean):>11} "
            f"{_fmt_s(float(mn[i])):>11} {_fmt_s(float(mx[i])):>11}")
    dropped = prof["n_spans_seen"] - min(prof["n_spans_seen"],
                                         len(prof["spans"]["t0"]))
    if dropped > 0:
        lines.append(f"(span ring wrapped: oldest {dropped} raw spans "
                     f"dropped; aggregates above cover every span)")
    return "\n".join(lines)


def _event_cols(bundle: Dict) -> Optional[Dict[str, np.ndarray]]:
    ev = bundle.get("events")
    if ev is None:
        return None
    cols = {k: np.asarray(v) for k, v in ev["columns"].items()}
    cols["_node_table"] = ev["node_table"]
    cols["_n_seen"] = ev["n_seen"]
    return cols


def decision_summary(bundle: Dict) -> str:
    cols = _event_cols(bundle)
    if cols is None:
        return "(no event data: run with ObsConfig(events=True))"
    kind = cols["kind"]
    v1 = cols["v1"]
    v2 = cols["v2"]
    lines = []
    n_held = len(kind)
    dropped = cols["_n_seen"] - n_held
    lines.append(f"events: {n_held} retained"
                 + (f" (+{dropped} overwritten by the ring)" if dropped > 0
                    else ""))
    for code, name in enumerate(KIND_NAMES):
        mask = kind == code
        n = int(mask.sum())
        if n == 0:
            continue
        detail = ""
        if code == EV_SCALE_OUT:
            parts = [f"{SCALE_OUT_NAMES[d]}={int((v1[mask] == d).sum())}"
                     for d in range(len(SCALE_OUT_NAMES))
                     if int((v1[mask] == d).sum())]
            detail = "  [" + ", ".join(parts) + "]"
        elif code == EV_SCALE_IN:
            parts = [f"step{s}={int((v1[mask] == s).sum())}"
                     for s in (1, 2, 3) if int((v1[mask] == s).sum())]
            detail = "  [" + ", ".join(parts) + "]"
        elif code == EV_EVICT:
            parts = [f"{REASON_NAMES[r]}={int((v2[mask] == r).sum())}"
                     for r in range(len(REASON_NAMES))
                     if int((v2[mask] == r).sum())]
            detail = "  [" + ", ".join(parts) + "]"
        elif code == EV_RESCHED:
            parts = [f"{RESCHED_NAMES[o]}={int((v1[mask] == o).sum())}"
                     for o in range(len(RESCHED_NAMES))
                     if int((v1[mask] == o).sum())]
            detail = "  [" + ", ".join(parts) + "]"
        lines.append(f"  {name:<15} {n:>7d}{detail}")
    return "\n".join(lines)


def _node_name(cols: Dict, i: int) -> str:
    idx = int(cols["node"][i])
    return cols["_node_table"][idx] if idx >= 0 else "-"


def _explain_one(cols: Dict, i: int) -> str:
    """One drill-down line: the event plus the inputs that drove it."""
    kind = int(cols["kind"][i])
    t = float(cols["t"][i])
    cyc = int(cols["cycle"][i])
    uid = int(cols["uid"][i])
    node = _node_name(cols, i)
    pend = cols["pending"][i]
    util = cols["util"][i]
    v1 = cols["v1"][i]
    v2 = cols["v2"][i]
    head = f"t={t:10.1f}s cycle={cyc:<6d}"
    if kind == EV_BIND:
        return (f"{head} bind       pod={uid} -> {node}  "
                f"waited={v1:.1f}s inc={int(v2)} pending={pend:.0f}")
    if kind == EV_EVICT:
        reason = REASON_NAMES[int(v2)] if 0 <= v2 < len(REASON_NAMES) \
            else "?"
        return (f"{head} evict      pod={uid} ({reason})  "
                f"inc={int(v1)} pending={pend:.0f}")
    if kind == EV_SCALE_OUT:
        disp = SCALE_OUT_NAMES[int(v1)] if 0 <= v1 < len(SCALE_OUT_NAMES) \
            else "?"
        rate = cols["rate"][i]
        conf = cols["conf"][i]
        hr = cols["headroom"][i]
        why = f"pending={pend:.0f} util={util:.3f}"
        if not np.isnan(rate):
            why += f" rate={rate:.4f}/s conf={conf:.2f}"
        if not np.isnan(hr):
            why += f" headroom={hr:.2f}"
        if not np.isnan(v2):
            why += f" since_last_launch={v2:.0f}s" if int(v1) in (0, 1) \
                else f" deficit={v2:.2f}"
        tgt = f" -> {node}" if node != "-" else ""
        return f"{head} scale_out  [{disp}]{tgt}  trigger_pod={uid}  {why}"
    if kind == EV_SCALE_IN:
        action = {1: "terminate empty", 2: "drain+terminate",
                  3: "evict movers + taint"}.get(int(v1), "?")
        return (f"{head} scale_in   {node} [{action}]  moved={int(v2)} "
                f"pending={pend:.0f} util={util:.3f}")
    if kind == EV_NOTICE:
        return (f"{head} notice     {node}  residents={int(v1)} "
                f"kill_in={v2:.0f}s pending={pend:.0f}")
    if kind == EV_RESCHED:
        out = RESCHED_NAMES[int(v1)] if 0 <= v1 < len(RESCHED_NAMES) else "?"
        vic = f" victim={node}" if node != "-" else ""
        return (f"{head} resched    pod={uid} [{out}]{vic}  "
                f"moved={int(v2)} pending={pend:.0f}")
    if kind == EV_FORECAST:
        rate = cols["rate"][i]
        conf = cols["conf"][i]
        state = "overloaded" if v1 == 1.0 else "keeping-up"
        return (f"{head} forecast   rate={rate:.4f}/s conf={conf:.2f} "
                f"slow={v2:.4f}/s [{state}] pending={pend:.0f} "
                f"util={util:.3f}")
    return f"{head} kind={kind} uid={uid} node={node}"


def explain_events(bundle: Dict, kinds: Optional[List[str]] = None,
                   limit: Optional[int] = None) -> str:
    """Drill-down listing, chronological.  ``kinds`` filters by kind name
    (default: scale_out + scale_in — the decisions the paper's claims rest
    on); ``limit`` keeps only the last N matching events."""
    cols = _event_cols(bundle)
    if cols is None:
        return "(no event data: run with ObsConfig(events=True))"
    if kinds is None:
        kinds = ["scale_out", "scale_in"]
    codes = []
    for name in kinds:
        if name not in KIND_NAMES:
            raise KeyError(f"unknown event kind {name!r}; "
                           f"one of {list(KIND_NAMES)}")
        codes.append(KIND_NAMES.index(name))
    idx = np.nonzero(np.isin(cols["kind"], codes))[0]
    total = idx.size
    if limit is not None and total > limit:
        idx = idx[-limit:]
    lines = [_explain_one(cols, int(i)) for i in idx]
    header = (f"{total} event(s) of kind {'/'.join(kinds)}"
              + (f", showing last {len(lines)}" if len(lines) < total
                 else ""))
    return "\n".join([header] + lines)


def node_count_summary(bundle: Dict) -> str:
    t = bundle.get("node_count_t")
    n = bundle.get("node_count_n")
    if t is None or len(t) == 0:
        return "(no node-count series in bundle)"
    n = np.asarray(n)
    return (f"node count: samples={len(n)} min={int(n.min())} "
            f"max={int(n.max())} final={int(n[-1])}; "
            f"pending intervals recorded={len(bundle.get('pending_intervals', []))}")


def render_report(bundle: Dict, kinds: Optional[List[str]] = None,
                  limit: Optional[int] = 50) -> str:
    """The full report: meta + phases + decisions + drill-down."""
    meta = bundle.get("meta") or {}
    parts = []
    if meta:
        parts.append("== run ==")
        parts.append("  ".join(f"{k}={v}" for k, v in sorted(meta.items())))
    parts.append("\n== cycle-phase profile ==")
    parts.append(phase_table(bundle))
    parts.append("\n== decisions ==")
    parts.append(decision_summary(bundle))
    parts.append(node_count_summary(bundle))
    parts.append("\n== drill-down ==")
    parts.append(explain_events(bundle, kinds=kinds, limit=limit))
    return "\n".join(parts)
