"""Cycle-phase profiler (ISSUE 10 tentpole, pillar 2).

``perf_counter`` spans around the hot-path phases of one simulated run —
timeline drain, arrival ingest, wave selection (scoring + select kernel),
bind commit, reschedule (including the shadow-capacity plan), autoscaler
step, scale-in, completion scheduling/commit, metrics sampling — each
aggregated into a per-phase histogram (count / total / min / max + log2
duration buckets) plus a bounded span ring for timeline inspection.

``chrome_trace`` renders the span ring as Chrome-trace/Perfetto JSON
(``chrome://tracing`` / https://ui.perfetto.dev): one complete-event
(``"ph": "X"``) per span, timestamps in microseconds relative to the first
recorded span, with the simulated time attached as an arg so wall-clock
hotspots can be correlated with simulation phases.

The profiler never touches simulation state — it reads the monotonic
clock and writes its own arrays — so profiling cannot perturb results.
"""
from __future__ import annotations

from time import perf_counter
from typing import Dict, List, Optional

import numpy as np

#: log2 duration buckets: bucket ``b`` holds spans with duration in
#: ``[2**(b-1), 2**b)`` microseconds (bucket 0: < 1 µs; bucket 31: the
#: catch-all for anything ≥ ~17.9 min).
N_BUCKETS = 32


class PhaseProfiler:
    """Named-phase span aggregation + a bounded raw-span ring.

    Usage at an instrumented site::

        t0 = prof.start()
        ... the phase body ...
        prof.stop("wave_select", t0, sim_now)

    ``stop`` is O(1): a dict lookup, four scalar updates, one histogram
    increment, and a ring write.  Phases are interned on first use.
    """

    __slots__ = ("max_spans", "n_spans_seen", "_agg", "_names",
                 "sp_name", "sp_t0", "sp_dur", "sp_sim")

    def __init__(self, max_spans: int = 1 << 16):
        self.max_spans = max_spans
        self.n_spans_seen = 0
        # name -> [count, total_s, min_s, max_s, hist(np.int64[32]), idx]
        self._agg: Dict[str, list] = {}
        self._names: List[str] = []
        self.sp_name = np.zeros(max_spans, np.int16)
        self.sp_t0 = np.zeros(max_spans, np.float64)
        self.sp_dur = np.zeros(max_spans, np.float64)
        self.sp_sim = np.zeros(max_spans, np.float64)

    @staticmethod
    def start() -> float:
        return perf_counter()

    def stop(self, name: str, t0: float, sim_now: float = 0.0) -> None:
        dur = perf_counter() - t0
        agg = self._agg.get(name)
        if agg is None:
            agg = self._agg[name] = [0, 0.0, np.inf, 0.0,
                                     np.zeros(N_BUCKETS, np.int64),
                                     len(self._names)]
            self._names.append(name)
        agg[0] += 1
        agg[1] += dur
        if dur < agg[2]:
            agg[2] = dur
        if dur > agg[3]:
            agg[3] = dur
        b = int(dur * 1e6).bit_length()
        agg[4][b if b < N_BUCKETS else N_BUCKETS - 1] += 1
        i = self.n_spans_seen % self.max_spans
        self.n_spans_seen += 1
        self.sp_name[i] = agg[5]
        self.sp_t0[i] = t0
        self.sp_dur[i] = dur
        self.sp_sim[i] = sim_now

    # -- reading -------------------------------------------------------------
    def phases(self) -> Dict[str, dict]:
        """Aggregates per phase, in first-use order."""
        return {name: {"count": agg[0], "total_s": agg[1],
                       "min_s": (0.0 if agg[0] == 0 else agg[2]),
                       "max_s": agg[3], "hist": agg[4].copy()}
                for name, agg in self._agg.items()}

    def _spans_unrolled(self):
        n = min(self.n_spans_seen, self.max_spans)
        if self.n_spans_seen <= self.max_spans:
            sl = slice(0, n)
            return (self.sp_name[sl].copy(), self.sp_t0[sl].copy(),
                    self.sp_dur[sl].copy(), self.sp_sim[sl].copy())
        head = self.n_spans_seen % self.max_spans
        order = np.r_[head:self.max_spans, 0:head]
        return (self.sp_name[order], self.sp_t0[order],
                self.sp_dur[order], self.sp_sim[order])

    def to_payload(self) -> Dict:
        names = list(self._names)
        count = np.asarray([self._agg[n][0] for n in names], np.int64)
        total = np.asarray([self._agg[n][1] for n in names], np.float64)
        mn = np.asarray([0.0 if self._agg[n][0] == 0 else self._agg[n][2]
                         for n in names], np.float64)
        mx = np.asarray([self._agg[n][3] for n in names], np.float64)
        hist = (np.stack([self._agg[n][4] for n in names])
                if names else np.zeros((0, N_BUCKETS), np.int64))
        sp_name, sp_t0, sp_dur, sp_sim = self._spans_unrolled()
        return {"names": names, "n_spans_seen": self.n_spans_seen,
                "count": count, "total_s": total, "min_s": mn, "max_s": mx,
                "hist": hist,
                "spans": {"name": sp_name, "t0": sp_t0, "dur_s": sp_dur,
                          "sim_s": sp_sim}}


def chrome_trace(profile: Dict, pid: int = 0, tid: int = 0) -> List[dict]:
    """Chrome-trace/Perfetto JSON event list from a profiler payload
    (live ``PhaseProfiler.to_payload()`` or the ``"profile"`` entry of a
    loaded obs bundle)."""
    names = profile["names"]
    spans = profile["spans"]
    sp_name = np.asarray(spans["name"])
    sp_t0 = np.asarray(spans["t0"], np.float64)
    sp_dur = np.asarray(spans["dur_s"], np.float64)
    sp_sim = np.asarray(spans["sim_s"], np.float64)
    if sp_t0.size == 0:
        return []
    epoch = float(sp_t0.min())
    return [{"name": names[int(sp_name[i])], "ph": "X", "pid": pid,
             "tid": tid, "ts": (float(sp_t0[i]) - epoch) * 1e6,
             "dur": float(sp_dur[i]) * 1e6,
             "args": {"sim_s": float(sp_sim[i])}}
            for i in range(sp_t0.size)]
