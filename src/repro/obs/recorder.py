"""Flight recorder: a columnar, ring-buffered event log with decision
attribution (ISSUE 10 tentpole, pillar 1).

``EventLog`` stores typed records — binds, evictions, scale-outs/ins,
preemption notices, rescheduler outcomes, forecaster predictions — as SoA
columns in the ``PodStore`` style: preallocated numpy arrays indexed by a
monotone event counter modulo a fixed capacity, so memory stays bounded on
arbitrarily long runs and the *latest* ``capacity`` events are always
available in chronological order.  Each record carries the inputs that
drove the decision (pending queue depth, mean RAM utilization, forecast
rate/confidence, headroom, rate-limiter state), so any decision in any run
can be replayed and explained without re-running the simulation.

``ObsRecorder`` is the hub threaded through the stack by
``repro.core.experiment.build_simulation`` when ``ExperimentSpec.obs`` is
set: it owns the event log and the cycle-phase profiler
(``repro.obs.profiler``), holds back-references for passive attribution
reads, and knows how to persist the whole run as a single NPZ/JSON bundle.

Bit-identity contract: recording is strictly passive.  Every helper only
*reads* simulation state — and the only mid-run aggregate it touches,
``Cluster.utilization_totals()``, is documented flush-order independent
(exact fsum reduction) — so an ``ExperimentResult`` produced with the
recorder attached is bit-identical to one produced without it
(``tests/test_obs.py``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

SCHEMA_VERSION = 1

# -- event kinds -------------------------------------------------------------
(EV_BIND, EV_EVICT, EV_SCALE_OUT, EV_SCALE_IN, EV_NOTICE, EV_RESCHED,
 EV_FORECAST) = range(7)
KIND_NAMES = ("bind", "evict", "scale_out", "scale_in", "preempt_notice",
              "resched", "forecast")

# -- eviction reasons (EVICT detail ``v2``) ----------------------------------
(R_UNSPEC, R_RESCHED, R_CONSOLIDATE, R_NODE_FAIL, R_STRAGGLER,
 R_CRASH) = range(6)
REASON_NAMES = ("unspecified", "reschedule", "scale_in_consolidation",
                "node_fail", "straggler", "crash_loop")

# -- scale-out dispositions (SCALE_OUT detail ``v1``) ------------------------
(SO_LIMITED, SO_LAUNCH, SO_ABSORBED, SO_ASSOCIATED, SO_PRELAUNCH) = range(5)
SCALE_OUT_NAMES = ("rate_limited", "launched", "absorbed_by_booting",
                   "already_associated", "predictive_prelaunch")

# -- rescheduler outcomes (RESCHED detail ``v1``) ----------------------------
(RS_WAIT, RS_RESCHEDULED, RS_FAILED) = range(3)
RESCHED_NAMES = ("wait", "rescheduled", "failed")

#: Float attribution columns, in storage order.  ``v1``/``v2`` are
#: kind-specific details (see docs/ARCHITECTURE.md "Observability" for the
#: full schema table); the rest are the decision inputs.
FCOLS = ("pending", "util", "rate", "conf", "headroom", "v1", "v2")

_NAN = float("nan")


class EventLog:
    """Columnar ring buffer of typed, attributed events.

    Writes go to slot ``n_seen % capacity`` — O(1), bounded memory; once
    the log wraps, the oldest events are overwritten and ``n_seen`` keeps
    counting so consumers can tell how many were dropped.  ``columns()``
    unrolls the ring into chronological per-column arrays.

    Node ids (strings like ``node-17``) are interned into ``node_table``
    so the ``node`` column stays a compact int32 index.
    """

    __slots__ = ("capacity", "n_seen", "t", "kind", "cycle", "uid", "node",
                 "f", "node_table", "_node_idx")

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.n_seen = 0
        self.t = np.zeros(capacity, np.float64)
        self.kind = np.zeros(capacity, np.int16)
        self.cycle = np.full(capacity, -1, np.int32)
        self.uid = np.full(capacity, -1, np.int64)
        self.node = np.full(capacity, -1, np.int32)
        self.f = np.full((capacity, len(FCOLS)), _NAN, np.float64)
        self.node_table: List[str] = []
        self._node_idx: Dict[str, int] = {}

    # -- recording -----------------------------------------------------------
    def intern_node(self, node_id: Optional[str]) -> int:
        if node_id is None:
            return -1
        idx = self._node_idx.get(node_id)
        if idx is None:
            idx = self._node_idx[node_id] = len(self.node_table)
            self.node_table.append(node_id)
        return idx

    def record(self, t: float, kind: int, *, cycle: int = -1, uid: int = -1,
               node: Optional[str] = None, pending: float = _NAN,
               util: float = _NAN, rate: float = _NAN, conf: float = _NAN,
               headroom: float = _NAN, v1: float = _NAN,
               v2: float = _NAN) -> None:
        i = self.n_seen % self.capacity
        self.n_seen += 1
        self.t[i] = t
        self.kind[i] = kind
        self.cycle[i] = cycle
        self.uid[i] = uid
        self.node[i] = self.intern_node(node)
        self.f[i] = (pending, util, rate, conf, headroom, v1, v2)

    # -- reading -------------------------------------------------------------
    def __len__(self) -> int:
        """Events currently held (≤ capacity; ``n_seen`` counts all ever)."""
        return min(self.n_seen, self.capacity)

    def _unroll(self, arr: np.ndarray) -> np.ndarray:
        n = len(self)
        if self.n_seen <= self.capacity:
            return arr[:n].copy()
        head = self.n_seen % self.capacity
        return np.concatenate([arr[head:], arr[:head]])

    def columns(self) -> Dict[str, np.ndarray]:
        """Chronological per-column view of the retained events."""
        out = {"t": self._unroll(self.t), "kind": self._unroll(self.kind),
               "cycle": self._unroll(self.cycle),
               "uid": self._unroll(self.uid),
               "node": self._unroll(self.node)}
        f = self._unroll(self.f)
        for j, name in enumerate(FCOLS):
            out[name] = f[:, j]
        return out

    def same_as(self, other: "EventLog") -> bool:
        """Bit-exact logical equality: same retained events (values and NaN
        pattern), same total count, same node intern table."""
        if (self.n_seen != other.n_seen or self.capacity != other.capacity
                or self.node_table != other.node_table):
            return False
        a, b = self.columns(), other.columns()
        for name in a:
            x, y = a[name], b[name]
            if np.issubdtype(x.dtype, np.floating):
                if not np.array_equal(x, y, equal_nan=True):
                    return False
            elif not np.array_equal(x, y):
                return False
        return True

    # -- persistence (TraceStore idiom: NPZ or exact-round-trip JSON) --------
    def to_payload(self) -> Dict:
        cols = self.columns()
        return {"schema": SCHEMA_VERSION, "n_seen": self.n_seen,
                "capacity": self.capacity, "node_table": list(self.node_table),
                "columns": cols}

    @classmethod
    def from_payload(cls, payload: Dict) -> "EventLog":
        cols = payload["columns"]
        n = len(cols["t"])
        log = cls(capacity=int(payload["capacity"]))
        log.n_seen = int(payload["n_seen"])
        head = log.n_seen % log.capacity if log.n_seen > log.capacity else 0
        # Re-lay the chronological arrays into the ring so columns() (and
        # therefore same_as) reproduce the saved view exactly.
        order = (np.r_[head:n, 0:head] if log.n_seen > log.capacity
                 else np.arange(n))
        log.t[order] = np.asarray(cols["t"], np.float64)
        log.kind[order] = np.asarray(cols["kind"], np.int16)
        log.cycle[order] = np.asarray(cols["cycle"], np.int32)
        log.uid[order] = np.asarray(cols["uid"], np.int64)
        log.node[order] = np.asarray(cols["node"], np.int32)
        for j, name in enumerate(FCOLS):
            log.f[order, j] = np.asarray(cols[name], np.float64)
        log.node_table = [str(s) for s in payload["node_table"]]
        log._node_idx = {s: i for i, s in enumerate(log.node_table)}
        return log

    def save(self, path: str) -> None:
        """Write the log to ``path`` (.npz: compressed columns + JSON meta;
        .json: exact float round-trip via repr)."""
        payload = self.to_payload()
        if str(path).endswith(".json"):
            with open(path, "w") as fh:
                json.dump(_jsonable(payload), fh)
            return
        meta = {k: payload[k] for k in
                ("schema", "n_seen", "capacity", "node_table")}
        np.savez_compressed(path, meta=np.asarray(json.dumps(meta)),
                            **payload["columns"])

    @classmethod
    def load(cls, path: str) -> "EventLog":
        if str(path).endswith(".json"):
            with open(path) as fh:
                return cls.from_payload(json.load(fh))
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            cols = {name: z[name]
                    for name in ("t", "kind", "cycle", "uid", "node") + FCOLS}
        meta["columns"] = cols
        return cls.from_payload(meta)


def _jsonable(obj):
    """Recursively convert numpy containers to exact JSON-native values
    (floats round-trip via repr; NaN survives as the JSON-extension token,
    matching the TraceStore persistence contract)."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


@dataclasses.dataclass
class ObsConfig:
    """Observability knobs on ``ExperimentSpec.obs`` (None = fully off:
    every hook in the hot path degenerates to one ``is not None`` test)."""

    events: bool = True          # flight recorder (EventLog)
    profile: bool = True         # cycle-phase profiler (perf_counter spans)
    capacity: int = 1 << 16      # event ring slots
    max_spans: int = 1 << 16     # profiler span ring slots (Chrome trace)


class ObsRecorder:
    """The recorder hub attached to one ``Simulation``.

    Instrumented objects (cluster, orchestrator, simulation, autoscaler,
    rescheduler) each carry an ``obs`` attribute defaulting to ``None``;
    ``attach`` points them all here.  Event helpers no-op when the event
    pillar is disabled, so a profile-only recorder costs nothing extra.

    ``reason`` is the eviction-attribution context: the code path about to
    trigger evictions (rescheduler, Alg. 6 consolidation, node failure,
    straggler mitigation, crash loop) sets it around the unbind calls and
    restores it after, so ``Cluster.unbind`` can stamp *why* without any
    plumbing through the call chain.
    """

    def __init__(self, config: Optional[ObsConfig] = None):
        self.config = config or ObsConfig()
        self.events: Optional[EventLog] = (
            EventLog(self.config.capacity) if self.config.events else None)
        if self.config.profile:
            from repro.obs.profiler import PhaseProfiler
            self.prof = PhaseProfiler(max_spans=self.config.max_spans)
        else:
            self.prof = None
        self.reason = R_UNSPEC
        self.meta: Dict = {}
        self._sim = None
        self._orch = None
        self._cluster = None

    # -- wiring --------------------------------------------------------------
    def attach(self, sim) -> "ObsRecorder":
        """Thread this recorder through one built simulation."""
        self._sim = sim
        self._orch = sim.orch
        self._cluster = sim.cluster
        sim.obs = self
        sim.orch.obs = self
        sim.cluster.obs = self
        sim.orch.autoscaler.obs = self
        sim.orch.rescheduler.obs = self
        return self

    # -- passive attribution reads -------------------------------------------
    def pending_depth(self) -> float:
        orch = self._orch
        return float(orch.n_pending) if orch is not None else _NAN

    def utilization(self) -> float:
        """Mean RAM req/cap ratio right now.  ``utilization_totals`` is
        incremental and its fsum reduction is flush-order independent, so
        this read cannot perturb the 20 s sampler (bit-identity contract)."""
        cluster = self._cluster
        if cluster is None:
            return _NAN
        n, ram_sum, _cpu, _ppn = cluster.utilization_totals()
        return ram_sum / n if n else 0.0

    def _cycle(self) -> int:
        orch = self._orch
        return orch._cycle_count if orch is not None else -1

    # -- event helpers (each maps to one call site in the stack) -------------
    def bind(self, now: float, uid: int, node_id: str, wait_s: float,
             incarnation: int) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_BIND, cycle=self._cycle(), uid=int(uid),
                  node=node_id, pending=self.pending_depth(),
                  v1=float(wait_s), v2=float(incarnation))

    def evict(self, now: float, uid: int, node_id: Optional[str],
              incarnation: int, failed: bool) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_EVICT, cycle=self._cycle(), uid=int(uid),
                  node=node_id, pending=self.pending_depth(),
                  v1=float(incarnation),
                  v2=float(self.reason if self.reason != R_UNSPEC
                           else (R_NODE_FAIL if failed else R_UNSPEC)))

    def scale_out(self, now: float, uid: int, node_id: Optional[str],
                  disposition: int, *, rate: float = _NAN, conf: float = _NAN,
                  headroom: float = _NAN, detail: float = _NAN) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_SCALE_OUT, cycle=self._cycle(), uid=int(uid),
                  node=node_id, pending=self.pending_depth(),
                  util=self.utilization(), rate=rate, conf=conf,
                  headroom=headroom, v1=float(disposition), v2=detail)

    def scale_in(self, now: float, node_id: str, step: int,
                 n_moved: int = 0) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_SCALE_IN, cycle=self._cycle(), node=node_id,
                  pending=self.pending_depth(), util=self.utilization(),
                  v1=float(step), v2=float(n_moved))

    def preempt_notice(self, now: float, node_id: str, residents: int,
                       kill_delay_s: float) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_NOTICE, cycle=self._cycle(), node=node_id,
                  pending=self.pending_depth(), v1=float(residents),
                  v2=float(kill_delay_s))

    def resched(self, now: float, uid: int, outcome: int,
                victim: Optional[str] = None, n_moved: int = 0) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_RESCHED, cycle=self._cycle(), uid=int(uid),
                  node=victim, pending=self.pending_depth(),
                  v1=float(outcome), v2=float(n_moved))

    def forecast(self, now: float, rate: float, conf: float,
                 overloaded: bool, slow_rate: float) -> None:
        ev = self.events
        if ev is None:
            return
        ev.record(now, EV_FORECAST, cycle=self._cycle(),
                  pending=self.pending_depth(), util=self.utilization(),
                  rate=float(rate), conf=float(conf),
                  v1=float(bool(overloaded)), v2=float(slow_rate))

    # -- export ---------------------------------------------------------------
    def bundle(self) -> Dict:
        """The whole run as one plain dict of arrays/lists: events +
        profiler aggregates + span ring + the MetricsCollector series the
        obs path exposes (node-count series, pending intervals) — the
        input format of ``repro.obs.report``."""
        out = {"schema": SCHEMA_VERSION, "meta": dict(self.meta),
               "kind_names": list(KIND_NAMES),
               "reason_names": list(REASON_NAMES),
               "scale_out_names": list(SCALE_OUT_NAMES),
               "resched_names": list(RESCHED_NAMES)}
        if self.events is not None:
            out["events"] = self.events.to_payload()
        if self.prof is not None:
            out["profile"] = self.prof.to_payload()
        sim = self._sim
        if sim is not None:
            series = sim.metrics.node_count_series
            out["node_count_t"] = np.asarray([s[0] for s in series],
                                             np.float64)
            out["node_count_n"] = np.asarray([s[1] for s in series], np.int64)
            out["pending_intervals"] = np.asarray(
                sim.metrics.pending_intervals, np.float64)
        return out

    def export(self, path: str) -> None:
        save_bundle(self.bundle(), path)


def save_bundle(bundle: Dict, path: str) -> None:
    """Persist a recorder bundle (.npz or exact-round-trip .json)."""
    if str(path).endswith(".json"):
        with open(path, "w") as fh:
            json.dump(_jsonable(bundle), fh)
        return
    arrays: Dict[str, np.ndarray] = {}
    meta = {"schema": bundle["schema"], "meta": bundle["meta"],
            "kind_names": bundle["kind_names"],
            "reason_names": bundle["reason_names"],
            "scale_out_names": bundle["scale_out_names"],
            "resched_names": bundle["resched_names"]}
    ev = bundle.get("events")
    if ev is not None:
        meta["events"] = {k: ev[k] for k in
                          ("schema", "n_seen", "capacity", "node_table")}
        for name, col in ev["columns"].items():
            arrays[f"ev_{name}"] = np.asarray(col)
    prof = bundle.get("profile")
    if prof is not None:
        meta["profile_names"] = prof["names"]
        meta["profile_n_spans_seen"] = prof["n_spans_seen"]
        for key in ("count", "total_s", "min_s", "max_s", "hist"):
            arrays[f"ph_{key}"] = np.asarray(prof[key])
        for key in ("name", "t0", "dur_s", "sim_s"):
            arrays[f"sp_{key}"] = np.asarray(prof["spans"][key])
    for key in ("node_count_t", "node_count_n", "pending_intervals"):
        if key in bundle:
            arrays[key] = np.asarray(bundle[key])
    np.savez_compressed(path, meta=np.asarray(json.dumps(meta)), **arrays)


def load_bundle(path: str) -> Dict:
    """Inverse of :func:`save_bundle`; returns the same dict shape
    ``ObsRecorder.bundle()`` produces (arrays come back as numpy)."""
    if str(path).endswith(".json"):
        with open(path) as fh:
            bundle = json.load(fh)
        if "events" in bundle:
            cols = bundle["events"]["columns"]
            for name in ("t",) + FCOLS:
                cols[name] = np.asarray(cols[name], np.float64)
            for name, dt in (("kind", np.int16), ("cycle", np.int32),
                             ("uid", np.int64), ("node", np.int32)):
                cols[name] = np.asarray(cols[name], dt)
        if "profile" in bundle:
            prof = bundle["profile"]
            for key in ("count", "total_s", "min_s", "max_s", "hist"):
                prof[key] = np.asarray(prof[key])
            for key in ("name", "t0", "dur_s", "sim_s"):
                prof["spans"][key] = np.asarray(prof["spans"][key])
        for key in ("node_count_t", "node_count_n", "pending_intervals"):
            if key in bundle:
                bundle[key] = np.asarray(bundle[key])
        return bundle
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
        bundle = {k: meta[k] for k in
                  ("schema", "meta", "kind_names", "reason_names",
                   "scale_out_names", "resched_names")}
        if "events" in meta:
            ev = meta["events"]
            ev["columns"] = {name: z[f"ev_{name}"]
                             for name in ("t", "kind", "cycle", "uid",
                                          "node") + FCOLS}
            bundle["events"] = ev
        if "profile_names" in meta:
            bundle["profile"] = {
                "names": meta["profile_names"],
                "n_spans_seen": meta["profile_n_spans_seen"],
                **{key: z[f"ph_{key}"]
                   for key in ("count", "total_s", "min_s", "max_s", "hist")},
                "spans": {key: z[f"sp_{key}"]
                          for key in ("name", "t0", "dur_s", "sim_s")}}
        for key in ("node_count_t", "node_count_n", "pending_intervals"):
            if key in z:
                bundle[key] = z[key]
    return bundle
