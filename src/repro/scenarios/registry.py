"""Scenario registry: name → seeded TraceStore builder.

The registry is the lookup behind ``ExperimentSpec(scenario="...")`` and
the sweep harness (``benchmarks/sweep_scenarios.py``): a scenario *name*
resolves to a builder ``fn(seed, n_jobs) -> TraceStore``, so experiment
specs stay plain data (a string + a seed) while traces stay columnar.

Built-ins:

* ``paper-bursty`` / ``paper-slow`` / ``paper-mixed`` — the paper's three
  §7.1 workloads, produced by ``generate_workload`` and columnarized
  bit-compatibly (``n_jobs`` is ignored: Table 2 fixes them at 50 jobs);
* ``diurnal``, ``flash-crowd``, ``heavy-tail``, ``mix-ramp``,
  ``scale-stress``, ``multi-tenant`` — the generator families of
  ``repro.scenarios.generators`` with their default configs.

``register`` adds custom scenarios (idempotent per name unless
``overwrite=True``); use a config dataclass directly when you need
non-default parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.workload import WORKLOAD_MIXES, generate_workload
from repro.scenarios import generators as _g
from repro.scenarios.trace import TraceStore

Builder = Callable[[int, Optional[int]], TraceStore]

_REGISTRY: Dict[str, Builder] = {}


def register(name: str, builder: Builder, *, overwrite: bool = False) -> None:
    """Add ``builder(seed, n_jobs) -> TraceStore`` under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise KeyError(f"scenario {name!r} already registered")
    _REGISTRY[name] = builder


def names() -> List[str]:
    return sorted(_REGISTRY)


def build_scenario(name: str, seed: int = 0,
                   n_jobs: Optional[int] = None) -> TraceStore:
    """Build the named scenario's trace.  ``n_jobs`` overrides the family's
    default trace length (ignored by the fixed-size paper workloads)."""
    builder = _REGISTRY.get(name)
    if builder is None:
        raise KeyError(f"unknown scenario {name!r}; one of {names()}")
    return builder(seed, n_jobs)


def _paper_builder(workload: str) -> Builder:
    def build(seed: int, n_jobs: Optional[int]) -> TraceStore:
        # Table 2 fixes the job count; n_jobs is accepted (and ignored) so
        # sweep code can treat every builder uniformly.
        trace = TraceStore.from_arrivals(generate_workload(workload, seed=seed),
                                         name=f"paper-{workload}")
        return trace
    return build


def _family_builder(cfg) -> Builder:
    def build(seed: int, n_jobs: Optional[int]) -> TraceStore:
        c = cfg
        if (n_jobs is not None
                and any(f.name == "n_jobs" for f in dataclasses.fields(cfg))):
            c = dataclasses.replace(cfg, n_jobs=n_jobs)
        return c.build(seed)
    return build


for _w in WORKLOAD_MIXES:
    register(f"paper-{_w}", _paper_builder(_w))

register("diurnal", _family_builder(_g.Diurnal()))
register("flash-crowd", _family_builder(_g.FlashCrowd()))
register("heavy-tail", _family_builder(_g.HeavyTail()))
register("mix-ramp", _family_builder(_g.MixRamp()))
register("scale-stress", _family_builder(_g.AutoscalerStress()))
register("multi-tenant", _family_builder(_g.MultiTenant()))

# Chaos families (repro.scenarios.chaos).  Registered builders produce only
# the workload trace — the disruption schedule rides on
# ExperimentSpec.failure_injector, wired by chaos.chaos_spec (a scenario
# name alone can't carry the stateful injector stack).
from repro.scenarios import chaos as _chaos   # noqa: E402  (needs register)

for _name, _cfg in _chaos.CHAOS_SCENARIOS.items():
    register(_name, _family_builder(_cfg))
