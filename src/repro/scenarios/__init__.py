"""Scenario subsystem: columnar workload traces, generators, replay, sweeps.

* :mod:`repro.scenarios.trace` — :class:`TraceStore`, the SoA trace that
  replays straight into the array engine's ``PodStore`` with zero
  per-arrival Python objects;
* :mod:`repro.scenarios.generators` — parameterized scenario families
  (diurnal, flash-crowd MMPP, heavy-tail durations, mix ramps,
  autoscaler stress, multi-tenant composition);
* :mod:`repro.scenarios.adapter` — Borg/Alibaba-style CSV ingestion with
  resource rescaling onto a target node template;
* :mod:`repro.scenarios.registry` — name → builder lookup behind
  ``ExperimentSpec(scenario=...)`` and ``benchmarks/sweep_scenarios.py``.
"""
from repro.scenarios.adapter import CsvTraceSpec, load_csv_trace
from repro.scenarios.generators import (AutoscalerStress, Diurnal, FlashCrowd,
                                        HeavyTail, MixRamp, MultiTenant)
from repro.scenarios.registry import build_scenario, names, register
from repro.scenarios.trace import KIND_BATCH, KIND_SERVICE, TraceStore

__all__ = [
    "TraceStore", "KIND_BATCH", "KIND_SERVICE",
    "Diurnal", "FlashCrowd", "HeavyTail", "MixRamp", "AutoscalerStress",
    "MultiTenant",
    "CsvTraceSpec", "load_csv_trace",
    "build_scenario", "names", "register",
]
