"""Scenario subsystem: columnar workload traces, generators, replay, sweeps.

* :mod:`repro.scenarios.trace` — :class:`TraceStore`, the SoA trace that
  replays straight into the array engine's ``PodStore`` with zero
  per-arrival Python objects;
* :mod:`repro.scenarios.generators` — parameterized scenario families
  (diurnal, flash-crowd MMPP, heavy-tail durations, mix ramps,
  autoscaler stress, multi-tenant composition);
* :mod:`repro.scenarios.adapter` — Borg/Alibaba-style CSV ingestion with
  resource rescaling onto a target node template;
* :mod:`repro.scenarios.registry` — name → builder lookup behind
  ``ExperimentSpec(scenario=...)`` and ``benchmarks/sweep_scenarios.py``;
* :mod:`repro.scenarios.chaos` — disruption-bearing scenario families
  (spot-spike, zone-outage, capacity-crunch) and the chaos-parity
  harness behind ``scripts/chaos.py`` and the golden chaos fixture.
"""
from repro.scenarios.adapter import CsvTraceSpec, load_csv_trace
from repro.scenarios.chaos import (CHAOS_SCENARIOS, CapacityCrunch, SpotSpike,
                                   ZoneOutage, capture_chaos_trace,
                                   chaos_spec, run_chaos_cell)
from repro.scenarios.generators import (AutoscalerStress, Diurnal, FlashCrowd,
                                        HeavyTail, MixRamp, MultiTenant)
from repro.scenarios.registry import build_scenario, names, register
from repro.scenarios.trace import KIND_BATCH, KIND_SERVICE, TraceStore

__all__ = [
    "TraceStore", "KIND_BATCH", "KIND_SERVICE",
    "Diurnal", "FlashCrowd", "HeavyTail", "MixRamp", "AutoscalerStress",
    "MultiTenant",
    "CHAOS_SCENARIOS", "SpotSpike", "ZoneOutage", "CapacityCrunch",
    "chaos_spec", "capture_chaos_trace", "run_chaos_cell",
    "CsvTraceSpec", "load_csv_trace",
    "build_scenario", "names", "register",
]
