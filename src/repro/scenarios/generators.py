"""Parameterized scenario families beyond the paper's three workloads.

Each family is a small config dataclass with a seeded, **vectorized**
sampler: ``cfg.build(seed)`` returns a :class:`repro.scenarios.trace.TraceStore`
without ever looping over individual arrivals in Python (loops run over
*segments* — rate epochs, tenants — never rows).

Arrival processes are sampled by **time-rescaling**: for an intensity
``λ(t)`` with integrated rate ``Λ(t)``, the arrival times are
``tᵢ = Λ⁻¹(Eᵢ)`` where ``Eᵢ`` is a cumulative sum of unit-mean exponential
draws.  ``Λ`` is piecewise-linear (MMPP, square waves) or evaluated in
closed form on a fine grid (diurnal sinusoid), and the inversion is one
``np.interp`` call — exact for piecewise-constant rates, grid-accurate for
the sinusoid, and fully deterministic under a fixed seed either way.

Families (mirroring the workload classes of Buyya et al., arXiv:1807.03578,
and the trace-driven evaluation gap of arXiv:2106.12739):

* :class:`Diurnal` — day/night sinusoidal rate with lognormal gap jitter
  (web traffic);
* :class:`FlashCrowd` — 2-state MMPP: exponential dwell in a *normal* and a
  *burst* rate regime (breaking-news / sale spikes);
* :class:`HeavyTail` — batch jobs with lognormal or Pareto durations drawn
  per row (big-data / ML training mix; exercises the per-row
  ``duration_s`` column);
* :class:`MixRamp` — batch→service composition ramp: the service fraction
  ramps linearly across the trace (a product launch shifting a cluster
  from offline to serving traffic);
* :class:`AutoscalerStress` — a rate staircase that climbs from
  ``low_rate`` to ``high_rate`` and cliffs back down, repeated — engineered
  to force scale-out bursts followed by reclaimable idle capacity;
* :class:`MultiTenant` — composition of independent sub-scenarios into one
  interleaved trace (each tenant seeded independently).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pods import PodSpec
from repro.core.workload import JOB_TYPES, mix_templates
from repro.scenarios.trace import TraceStore

BATCH_TEMPLATES: List[PodSpec] = [
    JOB_TYPES["batch_small"], JOB_TYPES["batch_med"], JOB_TYPES["batch_large"]]
SERVICE_TEMPLATES: List[PodSpec] = [
    JOB_TYPES["service_small"], JOB_TYPES["service_med"],
    JOB_TYPES["service_large"]]


def _normalized(weights: Optional[Sequence[float]], k: int) -> np.ndarray:
    w = (np.full(k, 1.0 / k) if weights is None
         else np.asarray(weights, np.float64))
    if w.shape != (k,) or (w < 0).any() or w.sum() <= 0:
        raise ValueError(f"need {k} non-negative weights with positive sum")
    return w / w.sum()


def _pick_templates(rng: np.random.Generator, k: int,
                    weights: Optional[Sequence[float]], n: int) -> np.ndarray:
    return rng.choice(k, size=n, p=_normalized(weights, k)).astype(np.int32)


def _unit_targets(rng: np.random.Generator, n: int) -> np.ndarray:
    """Cumulative unit-mean exponential targets E₁ < E₂ < … < Eₙ."""
    return np.cumsum(rng.exponential(1.0, size=n))


def _invert_piecewise(targets: np.ndarray, t_breaks: np.ndarray,
                      lam_cum: np.ndarray) -> np.ndarray:
    """tᵢ = Λ⁻¹(Eᵢ) for a piecewise-linear Λ given by breakpoints.

    ``t_breaks``/``lam_cum`` exclude the origin; the caller guarantees
    ``lam_cum[-1] >= targets[-1]`` so the interpolation never clamps."""
    assert lam_cum[-1] >= targets[-1], "integrated rate fell short"
    t0 = np.concatenate(([0.0], t_breaks))
    l0 = np.concatenate(([0.0], lam_cum))
    return np.interp(targets, l0, t0)


# --- diurnal sinusoid ---------------------------------------------------------

@dataclasses.dataclass
class Diurnal:
    """Sinusoidal day/night rate: λ(t) = base·(1 + amp·sin(2πt/period))."""

    n_jobs: int = 2_000
    base_rate_per_s: float = 1.0
    period_s: float = 3_600.0
    amplitude: float = 0.6           # must stay < 1 so λ(t) > 0
    noise: float = 0.1               # lognormal σ jitter on the unit gaps
    weights: Optional[Sequence[float]] = None    # over the six paper types
    name: str = "diurnal"

    def build(self, seed: int = 0) -> TraceStore:
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0, size=self.n_jobs)
        if self.noise > 0:
            gaps = gaps * rng.lognormal(0.0, self.noise, size=self.n_jobs)
        targets = np.cumsum(gaps)
        base, amp, period = (self.base_rate_per_s, self.amplitude,
                             self.period_s)
        # Λ(t) = base·(t − amp·period/2π·(cos(2πt/period) − 1)), monotone,
        # and ≥ base·t since (cos − 1) ≤ 0 — so Λ(horizon) ≥ 1.1·E_max and
        # one grid evaluation always brackets every target.
        horizon = targets[-1] / base * 1.1 + period
        grid = np.linspace(0.0, horizon,
                           max(4096, int(64 * horizon / period)))
        w = 2.0 * np.pi / period
        lam = base * (grid - amp / w * (np.cos(w * grid) - 1.0))
        assert lam[-1] >= targets[-1]
        times = np.interp(targets, lam, grid)
        templates, w_mix = mix_templates("mixed")
        tid = _pick_templates(rng, len(templates),
                              self.weights if self.weights is not None
                              else w_mix, self.n_jobs)
        return TraceStore(templates, tid, times, name=self.name)


# --- MMPP flash crowd ---------------------------------------------------------

@dataclasses.dataclass
class FlashCrowd:
    """2-state Markov-modulated Poisson process: normal ↔ burst regimes."""

    n_jobs: int = 2_000
    base_rate_per_s: float = 0.5
    burst_rate_per_s: float = 8.0
    mean_normal_s: float = 1_200.0   # exponential dwell in the normal state
    mean_burst_s: float = 120.0      # exponential dwell in the burst state
    weights: Optional[Sequence[float]] = None
    name: str = "flash-crowd"

    def build(self, seed: int = 0) -> TraceStore:
        rng = np.random.default_rng(seed)
        targets = _unit_targets(rng, self.n_jobs)
        pair_mass = (self.base_rate_per_s * self.mean_normal_s
                     + self.burst_rate_per_s * self.mean_burst_s)
        n_pairs = int(np.ceil(targets[-1] / pair_mass * 1.5)) + 4
        while True:
            dwell = np.empty(2 * n_pairs)
            dwell[0::2] = rng.exponential(self.mean_normal_s, size=n_pairs)
            dwell[1::2] = rng.exponential(self.mean_burst_s, size=n_pairs)
            rates = np.empty(2 * n_pairs)
            rates[0::2] = self.base_rate_per_s
            rates[1::2] = self.burst_rate_per_s
            lam_cum = np.cumsum(rates * dwell)
            if lam_cum[-1] >= targets[-1]:
                break
            n_pairs *= 2            # dwell draws came up short of Λ mass
        times = _invert_piecewise(targets, np.cumsum(dwell), lam_cum)
        templates, w_mix = mix_templates("bursty")
        tid = _pick_templates(rng, len(templates),
                              self.weights if self.weights is not None
                              else w_mix, self.n_jobs)
        return TraceStore(templates, tid, times, name=self.name)


# --- heavy-tailed batch durations --------------------------------------------

@dataclasses.dataclass
class HeavyTail:
    """Batch-only jobs whose durations are drawn per row (lognormal or
    Pareto) instead of taken from the template — the first user of the
    TraceStore's real ``duration_s`` column."""

    n_jobs: int = 2_000
    rate_per_s: float = 2.0
    dist: str = "lognormal"          # or "pareto"
    median_s: float = 120.0          # lognormal median / Pareto scale
    sigma: float = 1.0               # lognormal shape
    alpha: float = 1.5               # Pareto tail index
    cap_s: float = 7_200.0           # tail cap: keeps sim horizons bounded
    weights: Optional[Sequence[float]] = None    # over the batch templates
    name: str = "heavy-tail"

    def build(self, seed: int = 0) -> TraceStore:
        rng = np.random.default_rng(seed)
        times = np.cumsum(rng.exponential(1.0 / self.rate_per_s,
                                          size=self.n_jobs))
        if self.dist == "lognormal":
            dur = rng.lognormal(np.log(self.median_s), self.sigma,
                                size=self.n_jobs)
        elif self.dist == "pareto":
            dur = self.median_s * (1.0 + rng.pareto(self.alpha,
                                                    size=self.n_jobs))
        else:
            raise ValueError(f"dist must be lognormal|pareto, got {self.dist!r}")
        dur = np.clip(dur, 1.0, self.cap_s)
        tid = _pick_templates(rng, len(BATCH_TEMPLATES), self.weights,
                              self.n_jobs)
        return TraceStore(BATCH_TEMPLATES, tid, times, duration_s=dur,
                          name=self.name)


# --- batch→service mix ramp ---------------------------------------------------

@dataclasses.dataclass
class MixRamp:
    """Poisson arrivals whose service share ramps linearly from
    ``service_frac_start`` to ``service_frac_end`` across the trace."""

    n_jobs: int = 2_000
    rate_per_s: float = 1.0
    service_frac_start: float = 0.05
    service_frac_end: float = 0.5
    batch_weights: Optional[Sequence[float]] = None
    service_weights: Optional[Sequence[float]] = None
    name: str = "mix-ramp"

    def build(self, seed: int = 0) -> TraceStore:
        rng = np.random.default_rng(seed)
        times = np.cumsum(rng.exponential(1.0 / self.rate_per_s,
                                          size=self.n_jobs))
        p = np.linspace(self.service_frac_start, self.service_frac_end,
                        self.n_jobs)
        is_service = rng.random(self.n_jobs) < p
        nb = len(BATCH_TEMPLATES)
        tid = _pick_templates(rng, nb, self.batch_weights, self.n_jobs)
        tid_service = nb + _pick_templates(
            rng, len(SERVICE_TEMPLATES), self.service_weights, self.n_jobs)
        tid = np.where(is_service, tid_service, tid).astype(np.int32)
        return TraceStore(BATCH_TEMPLATES + SERVICE_TEMPLATES, tid, times,
                          name=self.name)


# --- autoscaler-stress staircase ---------------------------------------------

@dataclasses.dataclass
class AutoscalerStress:
    """Rate staircase low→high then cliff back down, repeated: every climb
    forces scale-out under a growing backlog, every cliff leaves idle
    autoscaled nodes for Alg. 6 scale-in to reclaim."""

    n_jobs: int = 2_000
    low_rate_per_s: float = 0.2
    high_rate_per_s: float = 4.0
    n_steps: int = 4                 # staircase levels per climb
    epoch_s: float = 300.0           # dwell per level
    batch_only: bool = True          # batch-heavy → nodes fully drain
    name: str = "scale-stress"

    def build(self, seed: int = 0) -> TraceStore:
        rng = np.random.default_rng(seed)
        targets = _unit_targets(rng, self.n_jobs)
        step_rates = np.linspace(self.low_rate_per_s, self.high_rate_per_s,
                                 self.n_steps)
        cycle_mass = step_rates.sum() * self.epoch_s
        n_cycles = int(np.ceil(targets[-1] / cycle_mass)) + 1
        rates = np.tile(step_rates, n_cycles)
        dwell = np.full(rates.size, self.epoch_s)
        lam_cum = np.cumsum(rates * dwell)
        times = _invert_piecewise(targets, np.cumsum(dwell), lam_cum)
        if self.batch_only:
            templates: List[PodSpec] = list(BATCH_TEMPLATES)
            weights = None
        else:
            templates, weights = mix_templates("mixed")
        tid = _pick_templates(rng, len(templates), weights, self.n_jobs)
        return TraceStore(templates, tid, times, name=self.name)


# --- multi-tenant composition -------------------------------------------------

@dataclasses.dataclass
class MultiTenant:
    """Independent tenant streams merged into one interleaved trace.

    Each tenant is any scenario config with a ``build(seed)`` method; tenant
    streams are seeded from ``np.random.SeedSequence(seed).spawn(...)`` so
    they are statistically independent of each other *and* across nearby
    experiment seeds (the earlier ``seed + 101·(i+1)`` arithmetic made
    ``(seed=0, tenant 1)`` and ``(seed=101, tenant 0)`` draw identical
    streams), while the composition stays a pure function of one seed.
    ``n_jobs`` sizes the
    *default* diurnal/flash-crowd/heavy-tail trio (total jobs, split
    35/35/30); explicit ``tenants`` carry their own sizes, so combining the
    two is rejected rather than silently ignoring one."""

    tenants: Tuple = ()              # scenario configs; () -> default trio
    n_jobs: Optional[int] = None     # total across the default trio
    name: str = "multi-tenant"

    def build(self, seed: int = 0) -> TraceStore:
        if self.tenants:
            if self.n_jobs is not None:
                raise ValueError("n_jobs sizes the default tenant trio; "
                                 "size explicit tenant configs directly")
            tenants = self.tenants
        else:
            total = self.n_jobs if self.n_jobs is not None else 2_000
            n1 = int(round(total * 0.35))
            n2 = int(round(total * 0.35))
            tenants = (Diurnal(n_jobs=n1), FlashCrowd(n_jobs=n2),
                       HeavyTail(n_jobs=total - n1 - n2))
        streams = np.random.SeedSequence(seed).spawn(len(tenants))
        parts = [cfg.build(stream) for cfg, stream in zip(tenants, streams)]
        return TraceStore.merge(parts, name=self.name)
