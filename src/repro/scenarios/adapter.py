"""External-trace adapter: Borg/Alibaba-style task CSVs → TraceStore.

Cluster traces in the wild (Google Borg ``task_events``, Alibaba
``batch_task``) reduce to rows of *(arrival time, cpu request, memory
request, duration)* with resources normalized to machine capacity.  The
adapter ingests that shape and **rescales** it onto a target
:class:`repro.cloud.adapter.NodeTemplate`:

* fractional cpu/mem (``[0, 1]`` of one machine) multiply out to the
  template's allocatable ``cpu_m`` / ``mem_mb`` (absolute units pass
  through via ``cpu_scale``/``mem_scale``);
* requests are **quantized** to a grid (``cpu_quant_m``, ``mem_quant_mb``)
  and clipped to ``[1 quantum, fraction_cap × allocatable]`` — the
  distinct quantized (cpu, mem) pairs become the trace's interned template
  table, keeping it bounded no matter how many rows the CSV has;
* durations land in the per-row ``duration_s`` column (0 for service
  rows), so big-data-style heavy tails survive ingestion exactly.

Parsing is vectorized: ``np.loadtxt`` over the selected columns, one
``np.unique`` for the template table — no per-row Python loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.pods import PodKind, PodSpec
from repro.core.resources import Resources
from repro.scenarios.trace import TraceStore


@dataclasses.dataclass
class CsvTraceSpec:
    """Column layout + rescaling rules for one external CSV.

    ``columns`` gives the 0-based indices of (arrival_time, cpu, mem,
    duration) in each row; ``cpu_is_fraction``/``mem_is_fraction`` say
    whether requests are machine fractions (Borg/Alibaba normalized form)
    or absolute ``cpu_m``/``mem_mb`` values."""

    columns: Sequence[int] = (0, 1, 2, 3)
    delimiter: str = ","
    skip_header: int = 0
    cpu_is_fraction: bool = True
    mem_is_fraction: bool = True
    cpu_scale: float = 1.0           # absolute-unit multiplier when not fractional
    mem_scale: float = 1.0
    cpu_quant_m: int = 50            # request quantization grid
    mem_quant_mb: float = 64.0
    fraction_cap: float = 1.0        # clip requests to this node fraction
    batch_kind: bool = True          # rows are run-to-completion tasks


def load_csv_trace(path, template=None, spec: Optional[CsvTraceSpec] = None,
                   name: str = "external") -> TraceStore:
    """Ingest an external task CSV into a :class:`TraceStore`.

    ``template`` is the target :class:`repro.cloud.adapter.NodeTemplate`
    (default ``M2_SMALL``) the normalized resources are rescaled against —
    the same template the experiment will provision nodes from, so a trace
    recorded on 64-core machines replays sensibly on 1-vCPU workers."""
    from repro.cloud.adapter import M2_SMALL
    template = template or M2_SMALL
    spec = spec or CsvTraceSpec()

    raw = np.loadtxt(path, delimiter=spec.delimiter,
                     skiprows=spec.skip_header,
                     usecols=tuple(spec.columns), ndmin=2, dtype=np.float64)
    if raw.size == 0:
        return TraceStore([], [], [], name=name)
    times, cpu, mem, dur = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]

    alloc = template.allocatable
    cpu_m = cpu * alloc.cpu_m if spec.cpu_is_fraction else cpu * spec.cpu_scale
    mem_mb = (mem * alloc.mem_mb if spec.mem_is_fraction
              else mem * spec.mem_scale)
    # Quantize to the grid, clip into (0, fraction_cap × allocatable].
    qc, qm = spec.cpu_quant_m, spec.mem_quant_mb
    cpu_m = np.clip(np.round(cpu_m / qc) * qc, qc,
                    np.floor(spec.fraction_cap * alloc.cpu_m / qc) * qc)
    mem_mb = np.clip(np.round(mem_mb / qm) * qm, qm,
                     np.floor(spec.fraction_cap * alloc.mem_mb / qm) * qm)

    pairs = np.stack([cpu_m, mem_mb], axis=1)
    uniq, tid = np.unique(pairs, axis=0, return_inverse=True)
    kind = PodKind.BATCH if spec.batch_kind else PodKind.SERVICE
    templates = [
        PodSpec(f"ext-{int(c)}m-{int(m)}mb", kind,
                Resources(int(c), float(m)),
                duration_s=0.0,
                moveable=not spec.batch_kind)
        for c, m in uniq.tolist()]
    dur = np.clip(dur, 0.0, None) if spec.batch_kind else np.zeros_like(dur)
    return TraceStore(templates, tid.astype(np.int32), times,
                      duration_s=dur, name=name)
