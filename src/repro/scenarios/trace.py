"""TraceStore: a columnar (SoA) workload trace.

The paper evaluates on three ~50-job synthetic workloads (§7.1); the array
engine sustains ~10⁵ pods/s — so workloads themselves must scale.  A
:class:`TraceStore` holds one arrival per *row* across NumPy columns
(arrival time, request sizes, duration, kind/moveable/checkpointable flags,
template id) plus a small **template table** of interned :class:`PodSpec`
objects.  Traces are generated (``repro.scenarios.generators``), loaded from
external task logs (``repro.scenarios.adapter``), saved/loaded as compact
JSON or NPZ, sliced, composed — and replayed *directly* into the engine:

* **array engine** — ``Simulation``/``Timeline`` batch over the trace's
  ``arrival_time`` column and ``Orchestrator.submit_trace`` bulk-ingests
  each batch straight into the SoA ``engine.PodStore`` columns
  (``PodStore.ingest_trace``) with **zero per-arrival Python objects** —
  no ``Arrival``, no ``Pod``, no per-pod heap push;
* **object engine** — :meth:`TraceStore.to_arrivals` materializes the
  classic ``List[Arrival]`` once, so the seed path needs no changes.

Replay is bit-compatible with the ``List[Arrival]`` path: the columns store
the identical floats the arrivals carry, the template table preserves spec
*identity* (``trace.templates[tid] is arrival.spec``), and ingestion writes
the same values the arrival path writes — parity-tested down to identical
bind sequences in ``tests/test_scenarios.py``.

**Per-row durations.**  ``duration_s`` is a real column, not just a spec
denormalization: heavy-tailed scenario families draw a distinct duration
per job while sharing one template.  The engine's completion path reads the
store's per-row duration column natively; a ``Pod`` shell materialized for
such a row carries a ``dataclasses.replace``-d spec with the row's true
duration (an API-boundary object, same economics as shells themselves).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.pods import PodKind, PodSpec
from repro.core.resources import Resources
from repro.core.workload import Arrival

# Row kind codes (the ``kind`` column; one byte per row).
KIND_BATCH = 0
KIND_SERVICE = 1

_KIND_CODE = {PodKind.BATCH: KIND_BATCH, PodKind.SERVICE: KIND_SERVICE}


def _spec_to_dict(spec: PodSpec) -> Dict:
    return {
        "type_name": spec.type_name,
        "kind": spec.kind.value,
        "cpu_m": spec.requests.cpu_m,
        "mem_mb": spec.requests.mem_mb,
        "duration_s": spec.duration_s,
        "moveable": spec.moveable,
        "checkpointable": spec.checkpointable,
        "checkpoint_interval_s": spec.checkpoint_interval_s,
        "scheduler_name": spec.scheduler_name,
    }


def _spec_from_dict(d: Dict) -> PodSpec:
    return PodSpec(
        type_name=d["type_name"], kind=PodKind(d["kind"]),
        requests=Resources(int(d["cpu_m"]), float(d["mem_mb"])),
        duration_s=float(d["duration_s"]), moveable=bool(d["moveable"]),
        checkpointable=bool(d["checkpointable"]),
        checkpoint_interval_s=float(d["checkpoint_interval_s"]),
        scheduler_name=d.get("scheduler_name", "customScheduler"))


class TraceStore:
    """One workload trace as SoA columns + an interned template table.

    Rows are sorted by ``arrival_time`` (stable — equal-time rows keep
    their construction order, matching ``Simulation``'s stable sort of
    ``List[Arrival]`` input).  Columns:

    | column            | dtype   | contents                               |
    |-------------------|---------|----------------------------------------|
    | ``arrival_time``  | float64 | submission instant (nondecreasing)     |
    | ``template_id``   | int32   | row into :attr:`templates`             |
    | ``cpu_m``         | int64   | request, denormalized from template    |
    | ``mem_mb``        | float64 | request, denormalized from template    |
    | ``duration_s``    | float64 | per-row runtime (template's by default)|
    | ``kind``          | int8    | ``KIND_BATCH`` / ``KIND_SERVICE``      |
    | ``moveable``      | bool    | from template                          |
    | ``checkpointable``| bool    | from template                          |
    """

    def __init__(self, templates: Sequence[PodSpec],
                 template_id, arrival_time,
                 duration_s=None, name: str = "trace"):
        self.name = name
        self.templates: List[PodSpec] = list(templates)
        tid = np.asarray(template_id, np.int32)
        times = np.asarray(arrival_time, np.float64)
        if tid.shape != times.shape or tid.ndim != 1:
            raise ValueError("template_id and arrival_time must be equal-"
                             f"length 1-D, got {tid.shape} vs {times.shape}")
        if len(self.templates) == 0 and tid.size:
            raise ValueError("non-empty trace with an empty template table")
        if tid.size and (tid.min() < 0 or tid.max() >= len(self.templates)):
            raise ValueError("template_id out of range")
        # Template-derived per-row columns (vectorized fancy indexing).
        t_cpu = np.asarray([s.requests.cpu_m for s in self.templates],
                           np.int64)
        t_mem = np.asarray([s.requests.mem_mb for s in self.templates],
                           np.float64)
        t_dur = np.asarray([s.duration_s for s in self.templates], np.float64)
        t_kind = np.asarray([_KIND_CODE[s.kind] for s in self.templates],
                            np.int8)
        t_move = np.asarray([s.moveable for s in self.templates], bool)
        t_ckpt = np.asarray([s.checkpointable for s in self.templates], bool)
        if duration_s is None:
            dur = t_dur[tid] if tid.size else np.zeros(0, np.float64)
        else:
            dur = np.asarray(duration_s, np.float64)
            if dur.shape != times.shape:
                raise ValueError("duration_s must match arrival_time length")
        if times.size and np.any(np.diff(times) < 0):
            order = np.argsort(times, kind="stable")
            times, tid, dur = times[order], tid[order], dur[order]
        self.arrival_time = times
        self.template_id = tid
        self.duration_s = dur
        if tid.size:
            self.cpu_m = t_cpu[tid]
            self.mem_mb = t_mem[tid]
            self.kind = t_kind[tid]
            self.moveable = t_move[tid]
            self.checkpointable = t_ckpt[tid]
        else:
            self.cpu_m = np.zeros(0, np.int64)
            self.mem_mb = np.zeros(0, np.float64)
            self.kind = np.zeros(0, np.int8)
            self.moveable = np.zeros(0, bool)
            self.checkpointable = np.zeros(0, bool)

    # -- basic views -----------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.arrival_time.size)

    def __len__(self) -> int:
        return self.n

    def __repr__(self):
        span = (f", t=[{self.arrival_time[0]:.0f}, "
                f"{self.arrival_time[-1]:.0f}]s" if self.n else "")
        return (f"TraceStore({self.name!r}, n={self.n}, "
                f"templates={len(self.templates)}{span})")

    def count_kinds(self, lo: int = 0, hi: Optional[int] = None):
        """``(n_batch, n_service)`` over rows ``[lo, hi)`` — one vector pass
        (the per-batch counter update of ``Orchestrator.submit_trace``)."""
        k = self.kind[lo:hi if hi is not None else self.n]
        return int((k == KIND_BATCH).sum()), int((k == KIND_SERVICE).sum())

    # -- interop with the List[Arrival] path -----------------------------------
    @classmethod
    def from_arrivals(cls, arrivals: Sequence[Arrival],
                      name: str = "trace") -> "TraceStore":
        """Columnarize a classic arrival list.

        Spec *identity* is preserved — each distinct ``PodSpec`` object
        becomes one template row, so replay hands the engine the identical
        spec objects the arrival path would have (bit-compatibility)."""
        templates: List[PodSpec] = []
        tmap: Dict[int, int] = {}
        tid = np.empty(len(arrivals), np.int32)
        times = np.empty(len(arrivals), np.float64)
        for i, a in enumerate(arrivals):
            j = tmap.get(id(a.spec))
            if j is None:
                j = len(templates)
                templates.append(a.spec)
                tmap[id(a.spec)] = j
            tid[i] = j
            times[i] = a.time
        return cls(templates, tid, times, name=name)

    def to_arrivals(self) -> List[Arrival]:
        """Materialize the classic ``List[Arrival]`` (object-engine replay,
        tests).  Rows whose duration column overrides the template's get a
        per-row ``dataclasses.replace``-d spec carrying the true duration —
        the same spec the engine's shell materialization would build."""
        t_dur = [s.duration_s for s in self.templates]
        out: List[Arrival] = []
        templates = self.templates
        for t, tid, d in zip(self.arrival_time.tolist(),
                             self.template_id.tolist(),
                             self.duration_s.tolist()):
            spec = templates[tid]
            if d != t_dur[tid]:
                spec = dataclasses.replace(spec, duration_s=d)
            out.append(Arrival(t, spec))
        return out

    def arrivals_slice(self, lo: int, hi: int) -> List[Arrival]:
        """``to_arrivals`` over rows ``[lo, hi)`` (object-engine fallback of
        ``Orchestrator.submit_trace``)."""
        return self.slice(lo, hi).to_arrivals()

    def to_lane_arrays(self) -> Dict:
        """Per-lane workload columns for the many-world engine
        (`repro.manyworld.lanes.stack_lanes`): float64 request/duration
        columns plus the batch-kind mask, in trace row order.  The caller
        adds the cluster scalars (``n_nodes`` / ``alloc_*`` / weights);
        ``stack_lanes`` pads the pod axis across lanes.  Integer CPU
        milli-units are exact in float64 (far below 2^53), so the lane
        program's comparisons and divisions match the serial engine
        bit-for-bit."""
        return {
            "arrival_t": self.arrival_time.astype(np.float64),
            "cpu_m": self.cpu_m.astype(np.float64),
            "mem_mb": self.mem_mb.astype(np.float64),
            "duration_s": self.duration_s.astype(np.float64),
            "is_batch": self.kind == KIND_BATCH,
        }

    # -- slicing / composition -------------------------------------------------
    def slice(self, lo: int, hi: Optional[int] = None) -> "TraceStore":
        """Row-range copy keeping the full template table (columns are
        copied, not views — mutating the parent never corrupts a slice)."""
        hi = self.n if hi is None else hi
        return TraceStore(self.templates, self.template_id[lo:hi].copy(),
                          self.arrival_time[lo:hi].copy(),
                          self.duration_s[lo:hi].copy(), name=self.name)

    def time_window(self, t0: float, t1: float) -> "TraceStore":
        """Rows with ``t0 <= arrival_time < t1``."""
        lo = int(np.searchsorted(self.arrival_time, t0, side="left"))
        hi = int(np.searchsorted(self.arrival_time, t1, side="left"))
        return self.slice(lo, hi)

    @classmethod
    def merge(cls, traces: Sequence["TraceStore"],
              name: str = "merged") -> "TraceStore":
        """Multi-tenant composition: interleave independent streams into one
        time-sorted trace (stable — equal-time rows keep stream order).
        Templates are deduplicated by object identity."""
        templates: List[PodSpec] = []
        tmap: Dict[int, int] = {}
        tids, times, durs = [], [], []
        for tr in traces:
            remap = np.empty(max(len(tr.templates), 1), np.int32)
            for i, s in enumerate(tr.templates):
                j = tmap.get(id(s))
                if j is None:
                    j = len(templates)
                    templates.append(s)
                    tmap[id(s)] = j
                remap[i] = j
            tids.append(remap[tr.template_id])
            times.append(tr.arrival_time)
            durs.append(tr.duration_s)
        if not times:
            return cls([], [], [], name=name)
        return cls(templates, np.concatenate(tids), np.concatenate(times),
                   np.concatenate(durs), name=name)

    # -- persistence -----------------------------------------------------------
    def save(self, path: str) -> None:
        """Write the trace to ``path`` — compact JSON (``.json``, exact
        float round-trip via repr) or compressed NPZ (``.npz``, exact
        binary) by suffix."""
        if str(path).endswith(".npz"):
            np.savez_compressed(
                path,
                template_id=self.template_id,
                arrival_time=self.arrival_time,
                duration_s=self.duration_s,
                meta=np.asarray(json.dumps({
                    "name": self.name,
                    "templates": [_spec_to_dict(s) for s in self.templates],
                })))
            return
        with open(path, "w") as f:
            json.dump({
                "name": self.name,
                "templates": [_spec_to_dict(s) for s in self.templates],
                "template_id": self.template_id.tolist(),
                "arrival_time": self.arrival_time.tolist(),
                "duration_s": self.duration_s.tolist(),
            }, f)

    @classmethod
    def load(cls, path: str) -> "TraceStore":
        if str(path).endswith(".npz"):
            with np.load(path, allow_pickle=False) as z:
                meta = json.loads(str(z["meta"]))
                return cls([_spec_from_dict(d) for d in meta["templates"]],
                           z["template_id"], z["arrival_time"],
                           z["duration_s"], name=meta.get("name", "trace"))
        with open(path) as f:
            d = json.load(f)
        return cls([_spec_from_dict(t) for t in d["templates"]],
                   d["template_id"], d["arrival_time"], d["duration_s"],
                   name=d.get("name", "trace"))
