"""Chaos scenario families + the chaos-parity harness.

Three disruption-bearing scenarios pair a workload trace with a seeded
`repro.core.disruption` schedule:

* ``spot-spike`` — flat Poisson mixed workload on spot capacity whose
  cheap instance types are reclaimed aggressively (notice-before-kill);
* ``zone-outage`` — steady mixed workload hit by a correlated zone
  failure at a fixed time;
* ``capacity-crunch`` — the `AutoscalerStress` rate staircase under
  simultaneous spot reclaims *and* pod crash-loops — the worst day the
  autoscaler can have.

Each config's ``build(seed)`` returns the workload :class:`TraceStore`
(so the registry can replay the trace *without* disruptions, like any
scenario) and ``injector(seed)`` returns a **fresh** injector stack
(injectors are stateful: RNG position, crash budgets, zone labels — a
shared instance would leak schedule state across runs and break parity).

The harness half of this module is shared by ``scripts/chaos.py`` and
``tests/test_chaos_trace.py``:

* `chaos_spec` — an `ExperimentSpec` wired with the scenario's trace and
  disruption schedule;
* `capture_chaos_trace` — a golden-trace-style spied run that logs every
  bind/evict/complete, the disruption log, and runs the column audit
  after **every** disruption event (`PodStore.audit_columns` on the
  array engine, `Cluster.check_invariants(deep=True)` on the object
  engine) — identical disruption schedules must yield bit-identical
  event sequences on both engines;
* `run_chaos_cell` — resilience metrics for one scenario (recovery time
  after each disruption, lost work, evictions, and the cost delta
  against the same trace run *without* disruptions).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.disruption import (CrashLoopInjector, DisruptionInjector,
                                   SpotReclaimInjector, ZoneOutageInjector)
from repro.core.workload import mix_templates
from repro.scenarios.generators import AutoscalerStress, _pick_templates
from repro.scenarios.trace import TraceStore


def _flat_mixed_trace(rng: np.random.Generator, n_jobs: int,
                      rate_per_s: float, name: str) -> TraceStore:
    times = np.cumsum(rng.exponential(1.0 / rate_per_s, size=n_jobs))
    templates, weights = mix_templates("mixed")
    tid = _pick_templates(rng, len(templates), weights, n_jobs)
    return TraceStore(templates, tid, times, name=name)


@dataclasses.dataclass
class SpotSpike:
    """Steady mixed workload on flaky spot capacity: cheaper instance
    types are reclaimed more often (per-type MTBR), each reclaim preceded
    by a notice window the binding autoscaler uses to pre-launch
    replacement capacity."""

    n_jobs: int = 400
    rate_per_s: float = 1.0
    mtbr_s: float = 900.0            # reclaim MTBR of the reference type
    notice_s: float = 90.0
    name: str = "spot-spike"

    def build(self, seed: int = 0) -> TraceStore:
        rng = np.random.default_rng(seed)
        return _flat_mixed_trace(rng, self.n_jobs, self.rate_per_s, self.name)

    def injector(self, seed: int = 0) -> DisruptionInjector:
        # Cheap types are flakier — the spot market's actual price/risk
        # trade keyed on Node.node_type (see NECTAR_CATALOG).
        rates = {"m2.tiny": 0.6 * self.mtbr_s,
                 "m2.small": self.mtbr_s,
                 "m2.medium": 1.6 * self.mtbr_s}
        return DisruptionInjector(injectors=(
            SpotReclaimInjector(reclaim_mtbr_s=rates,
                                default_mtbr_s=self.mtbr_s,
                                notice_s=self.notice_s, seed=seed + 17),
        ))


@dataclasses.dataclass
class ZoneOutage:
    """Steady mixed workload hit by one correlated zone failure: every
    live node in a seeded zone dies at ``outage_at_s``."""

    n_jobs: int = 400
    rate_per_s: float = 1.0
    outage_at_s: Tuple[float, ...] = (240.0,)
    zones: Tuple[str, ...] = ("zone-a", "zone-b", "zone-c")
    name: str = "zone-outage"

    def build(self, seed: int = 0) -> TraceStore:
        rng = np.random.default_rng(seed)
        return _flat_mixed_trace(rng, self.n_jobs, self.rate_per_s, self.name)

    def injector(self, seed: int = 0) -> DisruptionInjector:
        return DisruptionInjector(injectors=(
            ZoneOutageInjector(zones=self.zones,
                               outage_times=self.outage_at_s,
                               seed=seed + 29),
        ))


@dataclasses.dataclass
class CapacityCrunch:
    """`AutoscalerStress` staircase under spot reclaims and crash-loops:
    demand spikes exactly while capacity is being reclaimed and software
    is flaking — the compound-disruption worst case."""

    n_jobs: int = 400
    mtbr_s: float = 1_200.0
    notice_s: float = 60.0
    mtbc_s: float = 400.0            # mean time between pod crashes
    restart_budget: int = 3
    name: str = "capacity-crunch"

    def build(self, seed: int = 0) -> TraceStore:
        cfg = dataclasses.replace(AutoscalerStress(), n_jobs=self.n_jobs,
                                  name=self.name)
        return cfg.build(seed)

    def injector(self, seed: int = 0) -> DisruptionInjector:
        return DisruptionInjector(injectors=(
            SpotReclaimInjector(default_mtbr_s=self.mtbr_s,
                                notice_s=self.notice_s, seed=seed + 41),
            CrashLoopInjector(mtbc_s=self.mtbc_s, seed=seed + 43,
                              restart_budget=self.restart_budget),
        ))


CHAOS_SCENARIOS = {
    "spot-spike": SpotSpike(),
    "zone-outage": ZoneOutage(),
    "capacity-crunch": CapacityCrunch(),
}

# Trace length pinned by tests/data/golden_chaos_trace.json and checked by
# scripts/chaos.py --smoke: small enough to keep the committed fixture and
# the CI wall time bounded, large enough that every scenario still evicts,
# reclaims and audits (tests/test_chaos_trace.py asserts nontriviality).
GOLDEN_JOBS = 120


# --- harness ------------------------------------------------------------------

def chaos_spec(name: str, seed: int = 0, n_jobs: Optional[int] = None,
               engine: Optional[str] = None, scheduler: str = "best-fit",
               rescheduler: str = "non-binding", autoscaler: str = "binding",
               with_disruptions: bool = True, obs: object = None):
    """An `ExperimentSpec` for one chaos scenario — trace + fresh
    disruption schedule (or, with ``with_disruptions=False``, the same
    trace undisturbed: the baseline for cost/recovery deltas).  ``obs``
    (an ``repro.obs.ObsConfig``) attaches the flight recorder, which
    captures the disruption decisions — preemption notices, node-fail
    evictions, crash loops — with their attributed inputs."""
    from repro.core.experiment import ExperimentSpec
    cfg = CHAOS_SCENARIOS[name]
    if n_jobs is not None:
        cfg = dataclasses.replace(cfg, n_jobs=n_jobs)
    return ExperimentSpec(
        trace=cfg.build(seed), scheduler=scheduler, rescheduler=rescheduler,
        autoscaler=autoscaler, seed=seed, engine=engine, initial_workers=3,
        failure_injector=cfg.injector(seed) if with_disruptions else None,
        obs=obs)


def capture_chaos_trace(name: str, engine: str, seed: int = 0,
                        n_jobs: Optional[int] = None) -> Dict:
    """Spied chaos run: full event log + disruption log + per-event audits.

    The returned dict is JSON-round-trip normalized, so ``==`` between
    engines (or against the golden fixture) is a bit-exact diff.  Spying
    ``on_unbind`` intentionally forces the object-path eviction — the
    unspied column fast path is exercised by `run_chaos_cell` and by the
    audits in ``scripts/chaos.py --smoke``.
    """
    from repro.core import reset_id_counters
    from repro.core.experiment import build_simulation

    reset_id_counters()
    sim = build_simulation(chaos_spec(name, seed=seed, n_jobs=n_jobs,
                                      engine=engine))
    binds, evictions, completions = [], [], []
    cluster = sim.cluster
    inner_bind, inner_unbind = cluster.on_bind, cluster.on_unbind
    inner_complete = cluster.on_complete

    def on_bind(pod):
        binds.append([pod.uid, pod.incarnation, pod.node_id, pod.bound_time])
        inner_bind(pod)

    def on_unbind(pod):
        evictions.append([pod.uid, pod.incarnation, pod.pending_since])
        inner_unbind(pod)

    def on_complete(pod):
        completions.append([pod.uid, pod.node_id, pod.finish_time])
        inner_complete(pod)

    cluster.on_bind, cluster.on_unbind = on_bind, on_unbind
    cluster.on_complete = on_complete

    audits = [0]

    def on_disruption(s, kind):
        if s.cluster.pod_store is not None:
            s.cluster.pod_store.audit_columns(s.cluster)
        else:
            s.cluster.check_invariants(deep=True)
        audits[0] += 1

    sim.on_disruption = on_disruption
    result = sim.run()
    trace = {
        "scenario": name, "seed": seed, "binds": binds,
        "evictions": evictions, "completions": completions,
        "scale_events": [[n.node_id, n.terminate_time]
                         for n in cluster.terminated],
        "disruption_log": [list(e[:3]) + [list(e[3])]
                           for e in sim.disruption_log],
        "audits": audits[0],
        "result": dataclasses.asdict(result),
    }
    return json.loads(json.dumps(trace))


def _recovery_times(binds: List[List], disruption_log: List) -> List[float]:
    """Seconds from each capacity-loss event until its last victim pod is
    re-bound (victims that never re-bind — e.g. the run drained — are
    skipped rather than scored 0)."""
    out = []
    for t, kind, subject, payload in disruption_log:
        if kind == "node_fail":
            victims = set(payload)        # payload = evicted pod uids
        elif kind == "pod_crash":
            victims = {subject}           # subject = the crashed pod's uid
        else:
            continue   # zone_outage fans out into per-node node_fail entries
        if not victims:
            continue
        per_victim = {}
        for uid, _inc, _node, bt in binds:
            if uid in victims and bt > t:
                per_victim.setdefault(uid, bt)   # first re-bind after t
        if per_victim and len(per_victim) == len(victims):
            out.append(max(per_victim.values()) - t)
    return out


def run_chaos_cell(name: str, seed: int = 0, n_jobs: Optional[int] = None,
                   engine: Optional[str] = None) -> Dict:
    """One resilience row: the disrupted run's recovery/lost-work metrics
    plus the cost delta against the undisturbed baseline of the same
    trace."""
    from repro.core import reset_id_counters
    from repro.core.experiment import run_experiment

    t0 = time.perf_counter()
    trace = capture_chaos_trace(name, engine or "array", seed=seed,
                                n_jobs=n_jobs)
    wall = time.perf_counter() - t0
    reset_id_counters()
    baseline = run_experiment(chaos_spec(name, seed=seed, n_jobs=n_jobs,
                                         engine=engine,
                                         with_disruptions=False))
    r = trace["result"]
    recoveries = _recovery_times(trace["binds"], trace["disruption_log"])
    return {
        "scenario": name, "seed": seed, "engine": engine or "array",
        "completed": r["completed"],
        "failures_injected": r["failures_injected"],
        "preemption_notices": r["preemption_notices"],
        "evictions": r["evictions"],
        "lost_work_s": round(r["lost_work_s"], 3),
        "disruption_events": len(trace["disruption_log"]),
        "audits": trace["audits"],
        "recovery_mean_s": round(float(np.mean(recoveries)), 3)
        if recoveries else 0.0,
        "recovery_max_s": round(float(np.max(recoveries)), 3)
        if recoveries else 0.0,
        "cost": round(r["cost"], 3),
        "cost_baseline": round(baseline.cost, 3),
        "cost_delta": round(r["cost"] - baseline.cost, 3),
        "duration_s": round(r["duration_s"], 1),
        "duration_baseline_s": round(baseline.duration_s, 1),
        "wall_s": round(wall, 3),
    }
