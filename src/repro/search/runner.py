"""Parallel cell runner: scheduler × autoscaler × scenario grid cells.

One *cell* is one fully-specified experiment — a scenario family replayed
under one policy configuration.  `CellSpec` is a frozen, hashable,
**picklable** description of a cell (every field is a primitive or a
tuple), `run_cell` executes it, and `run_cells` fans a list of cells over
a `concurrent.futures` process pool.

The contract that makes the pool safe is hermeticity: `run_cell` resets
the global id counters and builds the scenario trace from its
``(scenario, seed, n_jobs)`` key, so a cell's result depends only on its
own spec — not on which process runs it, what ran in that process before,
or what order the pool completes in.  `run_cells` therefore guarantees

* **bit-identical results** to the serial path (``workers <= 1`` runs the
  exact same `run_cell` inline), and
* **stable ordering**: results are returned in submission order
  regardless of completion order (futures are consumed in the order the
  cells were given, never as-completed).

Traces are memoized per *process* keyed ``(scenario, seed, n_jobs)`` —
replay is read-only, so a worker evaluating many policy configs on the
same scenario builds its trace once.  Memoizing per process (rather than
shipping TraceStores through pickle) also keeps task payloads tiny.
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import ExperimentSpec, reset_id_counters, run_experiment

# Test hook: when this env var names a cell label, `run_cell` hard-kills
# its process (`os._exit`, no exception, no cleanup) on that cell —
# tests/test_search_runner.py uses it to prove a worker crash surfaces a
# clear error instead of hanging the pool.
_CRASH_ENV = "REPRO_SEARCH_TEST_CRASH"

# Metrics copied off the ExperimentResult verbatim (no rounding: the
# serial/parallel bit-identity contract is on these exact floats).
_RESULT_FIELDS = (
    "completed", "cost", "duration_s", "mean_pending_s", "median_pending_s",
    "max_pending_s", "avg_ram_ratio", "avg_cpu_ratio", "avg_pods_per_node",
    "max_nodes", "node_seconds", "evictions", "scale_outs", "scale_ins",
    "failures_injected", "preemption_notices", "lost_work_s",
)


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One grid cell: a scenario replayed under one policy configuration.

    Every field is picklable by construction (strings, numbers, tuples);
    node templates travel as `NODE_TEMPLATES` names and chaos injector
    stacks are rebuilt worker-side from ``(scenario, seed)``.
    """

    scenario: str
    scheduler: str = "best-fit"
    autoscaler: str = "binding"
    rescheduler: str = "void"
    seed: int = 0
    n_jobs: Optional[int] = None
    engine: Optional[str] = None
    # Policy-search knobs (defaults = the paper's Table-4 behavior).
    scheduler_weights: Optional[Tuple[float, float, float]] = None
    max_pod_age_s: float = 60.0
    provisioning_interval_s: float = 60.0
    scale_out_bypass_util: Optional[float] = None
    scale_in_util_ceiling: Optional[float] = None
    template_name: Optional[str] = None
    initial_workers: int = 1
    # Predictive-autoscaler knobs (autoscaler="predictive"; see
    # repro.core.autoscaler.PredictiveAutoscaler).  The forecaster travels
    # as a builtin name ("ewma"; None = prediction disabled) so cells stay
    # picklable and are rebuilt fresh worker-side — forecasters are
    # stateful, a shared instance would leak rate history across cells.
    forecaster: Optional[str] = "ewma"
    forecast_bin_s: float = 30.0
    forecast_lead_s: float = 90.0
    forecast_headroom: float = 1.15
    forecast_conf_min: float = 0.35
    # With chaos=True the scenario must be a `CHAOS_SCENARIOS` name; the
    # worker wires in that scenario's seeded disruption injector stack
    # (fresh per run — injectors are stateful) so `lost_work_s` becomes a
    # meaningful objective.
    chaos: bool = False
    # Per-cell trace capture: a directory path (primitive, so cells stay
    # picklable) makes the worker run with the flight recorder attached
    # and export ``<obs_dir>/<label>.npz`` — recording is passive, so the
    # row's metrics stay bit-identical to an uninstrumented run.
    obs_dir: Optional[str] = None

    @property
    def label(self) -> str:
        """Stable human-readable cell id, used in errors and CSV lines."""
        parts = [self.scenario, self.scheduler, self.autoscaler,
                 self.rescheduler, f"seed{self.seed}"]
        if self.chaos:
            parts.append("chaos")
        return ".".join(parts)

    def to_experiment_spec(self, trace) -> ExperimentSpec:
        injector = None
        if self.chaos:
            from repro.scenarios.chaos import CHAOS_SCENARIOS
            injector = CHAOS_SCENARIOS[self.scenario].injector(self.seed)
        return ExperimentSpec(
            trace=trace, scheduler=self.scheduler, autoscaler=self.autoscaler,
            rescheduler=self.rescheduler, seed=self.seed, engine=self.engine,
            scheduler_weights=self.scheduler_weights,
            max_pod_age_s=self.max_pod_age_s,
            provisioning_interval_s=self.provisioning_interval_s,
            scale_out_bypass_util=self.scale_out_bypass_util,
            scale_in_util_ceiling=self.scale_in_util_ceiling,
            template_name=self.template_name,
            initial_workers=self.initial_workers,
            forecaster=self.forecaster,
            forecast_bin_s=self.forecast_bin_s,
            forecast_lead_s=self.forecast_lead_s,
            forecast_headroom=self.forecast_headroom,
            forecast_conf_min=self.forecast_conf_min,
            failure_injector=injector)


class CellError(RuntimeError):
    """A cell failed (worker exception or worker-process death); the
    message names the cell so a 500-cell search points at the culprit."""


_TRACE_CACHE: Dict[Tuple[str, int, Optional[int]], object] = {}


def _get_trace(scenario: str, seed: int, n_jobs: Optional[int]):
    key = (scenario, seed, n_jobs)
    trace = _TRACE_CACHE.get(key)
    if trace is None:
        from repro.scenarios import build_scenario
        trace = _TRACE_CACHE[key] = build_scenario(scenario, seed=seed,
                                                   n_jobs=n_jobs)
    return trace


def _infeasible(cell: CellSpec, trace) -> bool:
    """True when some pod in the trace cannot fit even an *empty* node of
    the cell's template — no amount of scaling ever places it, so the
    simulation would grind to ``max_sim_time_s`` launching nodes the
    whole way (the search's small-template axis makes this reachable).
    """
    if trace.n == 0:
        return False
    from repro.cloud.adapter import M2_SMALL, NODE_TEMPLATES
    template = (NODE_TEMPLATES[cell.template_name]
                if cell.template_name is not None else M2_SMALL)
    alloc = template.allocatable
    return bool(trace.cpu_m.max() > alloc.cpu_m
                or trace.mem_mb.max() > alloc.mem_mb)


def run_cell(cell: CellSpec) -> dict:
    """Execute one cell and return its metrics row.

    Fresh id counters per cell: tie-breaks (node ids order
    lexicographically) depend only on this cell's own run, which is what
    makes cells order- and process-independent.  Infeasible cells (a pod
    larger than the node template) short-circuit to a zeroed
    ``completed=False`` row instead of simulating a hopeless 48 h.
    """
    if os.environ.get(_CRASH_ENV) == cell.label:
        os._exit(3)  # simulate a hard worker death (OOM-kill, segfault)
    trace = _get_trace(cell.scenario, cell.seed, cell.n_jobs)
    if _infeasible(cell, trace):
        row = {"label": cell.label, "cell": dataclasses.asdict(cell),
               "n_jobs": trace.n, "infeasible": True}
        for field in _RESULT_FIELDS:
            row[field] = False if field == "completed" else 0
        row["wall_s"] = 0.0
        return row
    reset_id_counters()
    spec = cell.to_experiment_spec(trace)
    t0 = time.perf_counter()
    if cell.obs_dir is not None:
        from repro.obs import run_recorded
        result, recorder = run_recorded(spec)
        os.makedirs(cell.obs_dir, exist_ok=True)
        recorder.export(os.path.join(cell.obs_dir, f"{cell.label}.npz"))
    else:
        result = run_experiment(spec)
    wall = time.perf_counter() - t0
    row = {"label": cell.label, "cell": dataclasses.asdict(cell),
           "n_jobs": trace.n, "infeasible": False}
    for field in _RESULT_FIELDS:
        row[field] = getattr(result, field)
    row["wall_s"] = wall
    return row


def run_cells(cells: Sequence[CellSpec], workers=1,
              max_tasks_per_child: Optional[int] = None) -> List[dict]:
    """Run every cell; results come back in the order cells were given.

    ``workers <= 1`` runs serially in-process — the reference path the
    pool is tested bit-identical against.  With a pool, futures are
    consumed in submission order (not as-completed), so the output list
    is the same whichever worker finished first.  A failing cell raises
    `CellError` naming the cell; a dying worker (hard exit) raises
    `CellError` instead of hanging the remaining futures.

    ``workers="lanes"`` evaluates the list on the many-world lane engine
    (`repro.manyworld`): void/void static-cluster cells run batched in
    one JAX program per bucket, anything outside that envelope (and
    everything, when JAX is absent) falls back to the serial ``run_cell``
    — same rows, same order, bit-identical metrics (``wall_s`` becomes
    the lane's share of its batch).
    """
    cells = list(cells)
    if workers == "lanes":
        from repro.manyworld.evaluator import run_cells_lanes
        return run_cells_lanes(cells)
    if workers <= 1:
        rows = []
        for cell in cells:
            try:
                rows.append(run_cell(cell))
            except Exception as exc:
                raise CellError(f"cell {cell.label} failed: {exc!r}") from exc
        return rows
    kwargs = {}
    if max_tasks_per_child is not None:
        kwargs["max_tasks_per_child"] = max_tasks_per_child
    rows: List[dict] = []
    with ProcessPoolExecutor(max_workers=workers, **kwargs) as pool:
        futures = [(cell, pool.submit(run_cell, cell)) for cell in cells]
        for cell, future in futures:
            try:
                rows.append(future.result())
            except BrokenProcessPool as exc:
                raise CellError(
                    f"worker process died while running cell {cell.label}"
                    f" (or a cell batched with it); the pool is broken —"
                    f" remaining cells were not run") from exc
            except Exception as exc:
                raise CellError(
                    f"cell {cell.label} failed: {exc!r}") from exc
    return rows
