"""Typed parameter space for the policy search.

A `ParamSpace` is an ordered tuple of named parameters — continuous
(`FloatParam`) or categorical (`ChoiceParam`) — with seeded sampling,
validation, and an **exact** encoding to flat float vectors:

* a `FloatParam` gene stores the raw value (identity map);
* a `ChoiceParam` gene stores ``float(index)`` into its choices tuple.

Small integer indices and raw floats both round-trip through the vector
unchanged, so ``space.decode(space.encode(cfg)) == cfg`` holds *exactly*
(``==``, not approximately) — which is what lets the NSGA-II evaluation
cache key on vectors and lets golden fixtures pin configs bit-for-bit.

`default_space()` is the paper-policy search space: the weighted
scheduler's scoring weights, both rescheduler aggressiveness and
autoscaler rate/threshold knobs (Alg. 3–6), and the node-template mix
axis.  `to_cell_spec` maps a config dict onto a `runner.CellSpec`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

Value = Union[float, str]


@dataclasses.dataclass(frozen=True)
class FloatParam:
    """A bounded continuous parameter; values are raw floats in [lo, hi]."""

    name: str
    lo: float
    hi: float

    def __post_init__(self):
        if not self.lo < self.hi:
            raise ValueError(f"{self.name}: need lo < hi, got "
                             f"[{self.lo}, {self.hi}]")

    def sample(self, rng) -> float:
        return float(rng.uniform(self.lo, self.hi))

    def clip(self, v: float) -> float:
        return min(max(float(v), self.lo), self.hi)

    def validate(self, v) -> None:
        if not isinstance(v, float):
            raise TypeError(f"{self.name}: expected float, got {type(v)!r}")
        if not self.lo <= v <= self.hi:
            raise ValueError(f"{self.name}: {v} outside [{self.lo}, {self.hi}]")


@dataclasses.dataclass(frozen=True)
class ChoiceParam:
    """A categorical parameter; encoded as float(index) into `choices`."""

    name: str
    choices: Tuple[str, ...]

    def __post_init__(self):
        if len(self.choices) < 1:
            raise ValueError(f"{self.name}: empty choices")
        if len(set(self.choices)) != len(self.choices):
            raise ValueError(f"{self.name}: duplicate choices")

    def sample(self, rng) -> str:
        return self.choices[int(rng.integers(len(self.choices)))]

    def validate(self, v) -> None:
        if v not in self.choices:
            raise ValueError(f"{self.name}: {v!r} not in {self.choices}")


Param = Union[FloatParam, ChoiceParam]


class ParamSpace:
    """An ordered, named parameter space with exact vector encoding."""

    def __init__(self, params: Sequence[Param]):
        self.params: Tuple[Param, ...] = tuple(params)
        names = [p.name for p in self.params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate parameter names in {names}")
        self.names: Tuple[str, ...] = tuple(names)

    def __len__(self) -> int:
        return len(self.params)

    def validate(self, cfg: Dict[str, Value]) -> None:
        """Raise unless `cfg` has exactly this space's keys, all in-range."""
        extra = set(cfg) - set(self.names)
        missing = set(self.names) - set(cfg)
        if extra or missing:
            raise ValueError(f"config keys mismatch: extra={sorted(extra)} "
                             f"missing={sorted(missing)}")
        for p in self.params:
            p.validate(cfg[p.name])

    def sample(self, rng) -> Dict[str, Value]:
        """One uniform config.  Draws one value per parameter *in space
        order*, so the stream of configs is a pure function of the rng
        state (sampling is part of the search's determinism contract)."""
        return {p.name: p.sample(rng) for p in self.params}

    def encode(self, cfg: Dict[str, Value]) -> Tuple[float, ...]:
        self.validate(cfg)
        vec = []
        for p in self.params:
            if isinstance(p, FloatParam):
                vec.append(float(cfg[p.name]))
            else:
                vec.append(float(p.choices.index(cfg[p.name])))
        return tuple(vec)

    def decode(self, vec: Sequence[float]) -> Dict[str, Value]:
        if len(vec) != len(self.params):
            raise ValueError(f"vector length {len(vec)} != space size "
                             f"{len(self.params)}")
        cfg: Dict[str, Value] = {}
        for p, v in zip(self.params, vec):
            if isinstance(p, FloatParam):
                cfg[p.name] = p.clip(v)
            else:
                idx = int(round(v))
                if not 0 <= idx < len(p.choices):
                    raise ValueError(f"{p.name}: index {v} out of range")
                cfg[p.name] = p.choices[idx]
        return cfg

    def bounds(self) -> Tuple[Tuple[float, float], ...]:
        """Per-gene (lo, hi) in vector coordinates — choice genes span
        their index range (used by crossover/mutation clipping)."""
        out = []
        for p in self.params:
            if isinstance(p, FloatParam):
                out.append((p.lo, p.hi))
            else:
                out.append((0.0, float(len(p.choices) - 1)))
        return tuple(out)


def default_space() -> ParamSpace:
    """The paper-policy search space (ISSUE: weighted-scheduler scoring
    weights, rescheduler aggressiveness, autoscaler thresholds/rate
    limits, node-template mix).

    Threshold ranges deliberately extend past the feasible utilization
    band [0, 1]: ``scale_out_bypass_util`` at its upper bound never
    fires (pure Alg. 5 rate limiting) and ``scale_in_util_ceiling`` at
    its upper bound always consolidates (pure Alg. 6) — the paper's
    behavior is *inside* the space, not a special case bolted on.
    """
    return ParamSpace((
        FloatParam("w_pack", 0.0, 1.0),
        FloatParam("w_lr", 0.0, 1.0),
        FloatParam("w_bal", 0.0, 1.0),
        FloatParam("max_pod_age_s", 0.0, 240.0),
        FloatParam("provisioning_interval_s", 10.0, 240.0),
        FloatParam("scale_out_bypass_util", 0.5, 2.0),
        FloatParam("scale_in_util_ceiling", 0.05, 2.0),
        ChoiceParam("rescheduler", ("void", "binding", "non-binding")),
        ChoiceParam("autoscaler", ("binding", "non-binding")),
        ChoiceParam("template", ("m2.tiny", "m2.small", "m2.medium")),
    ))


def predictive_space() -> ParamSpace:
    """`default_space()` widened with the predictive autoscaler and its
    lead-time / headroom knobs (ISSUE: forecast-ahead scaling as a search
    axis).

    A separate constructor rather than a widened `default_space()`: the
    NSGA-II golden fixture pins configs drawn from the default space, and
    sampling draws one value per parameter in space order — adding
    parameters (or a third autoscaler choice) would shift that stream and
    silently invalidate the fixture.  The extra knobs are inert for
    non-predictive autoscaler genes, mirroring how the threshold knobs of
    `default_space()` are inert at their paper-behavior bounds.
    """
    params = []
    for p in default_space().params:
        if p.name == "autoscaler":
            params.append(ChoiceParam(
                "autoscaler", ("binding", "non-binding", "predictive")))
        else:
            params.append(p)
    params.append(FloatParam("forecast_lead_s", 30.0, 240.0))
    params.append(FloatParam("forecast_headroom", 1.0, 2.0))
    return ParamSpace(params)


# Table-4 defaults expressed as a point of `default_space()` — the
# paper's Alg. 3–6 chain (non-binding rescheduler, binding autoscaler,
# 60 s knobs, m2.small workers).  Thresholds sit at the bounds where
# they reproduce the unconditional paper behavior; weights (1, 0, 0)
# make the weighted scheduler rank nodes like most-allocated packing.
PAPER_DEFAULT_CONFIG: Dict[str, Value] = {
    "w_pack": 1.0, "w_lr": 0.0, "w_bal": 0.0,
    "max_pod_age_s": 60.0,
    "provisioning_interval_s": 60.0,
    "scale_out_bypass_util": 2.0,
    "scale_in_util_ceiling": 2.0,
    "rescheduler": "non-binding",
    "autoscaler": "binding",
    "template": "m2.small",
}


def to_cell_spec(cfg: Dict[str, Value], scenario: str, seed: int = 0,
                 n_jobs: Optional[int] = None, engine: Optional[str] = None,
                 chaos: bool = False):
    """Map a `default_space()` config onto a runnable `CellSpec`.

    The scheduler is always the weighted scorer; an all-zero weight
    corner (reachable only by mutation clipping every weight to its
    floor) falls back to pure packing rather than constructing an
    unnormalizable scheduler.
    """
    from repro.search.runner import CellSpec
    weights = (cfg["w_pack"], cfg["w_lr"], cfg["w_bal"])
    if sum(weights) <= 0.0:
        weights = (1.0, 0.0, 0.0)
    return CellSpec(
        scenario=scenario, scheduler="weighted",
        autoscaler=cfg["autoscaler"], rescheduler=cfg["rescheduler"],
        seed=seed, n_jobs=n_jobs, engine=engine,
        scheduler_weights=weights,
        max_pod_age_s=cfg["max_pod_age_s"],
        provisioning_interval_s=cfg["provisioning_interval_s"],
        scale_out_bypass_util=cfg["scale_out_bypass_util"],
        scale_in_util_ceiling=cfg["scale_in_util_ceiling"],
        template_name=cfg["template"], chaos=chaos,
        initial_workers=3 if chaos else 1,
        # predictive_space() knobs; absent (default_space configs) they
        # fall back to the CellSpec defaults, which match the
        # PredictiveAutoscaler constructor.
        forecast_lead_s=float(cfg.get("forecast_lead_s", 90.0)),
        forecast_headroom=float(cfg.get("forecast_headroom", 1.15)))
