"""Seeded NSGA-II over policy configurations.

Multi-objective search (Deb et al. 2002) for scheduler/rescheduler/
autoscaler policies: fast non-dominated sorting, crowding distance with
``+inf`` boundary points, crowded-comparison binary tournaments, SBX
crossover on continuous genes + uniform swap on categorical genes, and
bounded polynomial mutation (categoricals re-draw uniformly).

Determinism contract: every stochastic step draws from one
``np.random.Generator(PCG64(seed))`` owned by the main process, and all
evaluation goes through `repro.search.runner` whose cells are hermetic —
so the whole search is a pure function of ``(space, scenarios, seed,
generations, pop_size, ...)``, and the Pareto front is bit-identical
whether cells run serially or on a process pool.

Objectives are minimized; utilization enters negated (maximize) as
``neg_avg_ram_ratio``.  Each config's objective vector is the *mean over
scenario families* of the per-scenario metric — one policy has to do
well across diurnal, flash-crowd MMPP, heavy-tail, ... simultaneously,
not overfit one trace.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.search.paramspace import (ChoiceParam, PAPER_DEFAULT_CONFIG,
                                     ParamSpace, to_cell_spec)
from repro.search.runner import run_cells

Vector = Tuple[float, ...]

# Objective name -> (ExperimentResult row field, sign).  All minimized.
OBJECTIVES: Dict[str, Tuple[str, float]] = {
    "cost": ("cost", 1.0),
    "mean_pending_s": ("mean_pending_s", 1.0),
    "neg_avg_ram_ratio": ("avg_ram_ratio", -1.0),
    "lost_work_s": ("lost_work_s", 1.0),   # chaos cells only (else 0)
}
DEFAULT_OBJECTIVES = ("cost", "mean_pending_s", "neg_avg_ram_ratio")

# Added once per scenario a config fails to complete on: large enough to
# push any incomplete config behind every complete one on every axis,
# finite so crowding-distance normalization stays well-defined.
INCOMPLETE_PENALTY = 1e6


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff `a` is no worse than `b` everywhere and better somewhere
    (minimization)."""
    return (all(x <= y for x, y in zip(a, b))
            and any(x < y for x, y in zip(a, b)))


def fast_non_dominated_sort(objectives: Sequence[Sequence[float]]
                            ) -> List[List[int]]:
    """Partition indices into Pareto fronts, best first.

    Every index appears in exactly one front; front 0 is the
    non-dominated set; each member of front k is dominated by at least
    one member of front k-1.  Indices within a front stay ascending.
    """
    n = len(objectives)
    dominated_by: List[List[int]] = [[] for _ in range(n)]
    dom_count = [0] * n
    for i in range(n):
        for j in range(i + 1, n):
            if dominates(objectives[i], objectives[j]):
                dominated_by[i].append(j)
                dom_count[j] += 1
            elif dominates(objectives[j], objectives[i]):
                dominated_by[j].append(i)
                dom_count[i] += 1
    fronts = [[i for i in range(n) if dom_count[i] == 0]]
    while fronts[-1]:
        nxt = []
        for i in fronts[-1]:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        fronts.append(sorted(nxt))
    return fronts[:-1]


def crowding_distance(objectives: Sequence[Sequence[float]],
                      front: Sequence[int]) -> List[float]:
    """Per-member crowding distance, aligned with `front`'s order.

    Boundary members of every objective get ``+inf`` (they are always
    preserved); interior members accumulate normalized neighbor gaps.
    Ties in an objective sort break on index, keeping the result a pure
    function of the inputs.
    """
    k = len(front)
    dist = [0.0] * k
    if k <= 2:
        return [math.inf] * k
    for field_idx in range(len(objectives[front[0]])):
        order = sorted(range(k),
                       key=lambda i: (objectives[front[i]][field_idx],
                                      front[i]))
        lo = objectives[front[order[0]]][field_idx]
        hi = objectives[front[order[-1]]][field_idx]
        dist[order[0]] = dist[order[-1]] = math.inf
        span = hi - lo
        if span <= 0.0:
            continue
        for pos in range(1, k - 1):
            prev_v = objectives[front[order[pos - 1]]][field_idx]
            next_v = objectives[front[order[pos + 1]]][field_idx]
            if not math.isinf(dist[order[pos]]):
                dist[order[pos]] += (next_v - prev_v) / span
    return dist


def _tournament(rng, ranks: Sequence[int], crowd: Sequence[float]) -> int:
    """Binary crowded-comparison tournament: lower rank wins, then higher
    crowding, then lower index (deterministic tie-break)."""
    i = int(rng.integers(len(ranks)))
    j = int(rng.integers(len(ranks)))
    a = (ranks[i], -crowd[i], i)
    b = (ranks[j], -crowd[j], j)
    return i if a <= b else j


def sbx_crossover(rng, v1: Vector, v2: Vector, space: ParamSpace,
                  eta: float = 15.0, prob: float = 0.9
                  ) -> Tuple[Vector, Vector]:
    """Simulated binary crossover on float genes, uniform swap on choice
    genes; children are clipped to the space's vector bounds."""
    c1, c2 = list(v1), list(v2)
    if rng.random() < prob:
        for i, ((lo, hi), p) in enumerate(zip(space.bounds(), space.params)):
            if isinstance(p, ChoiceParam):
                if rng.random() < 0.5:
                    c1[i], c2[i] = c2[i], c1[i]
                continue
            if rng.random() < 0.5:
                continue
            x1, x2 = c1[i], c2[i]
            if abs(x1 - x2) < 1e-14:
                continue
            u = rng.random()
            if u <= 0.5:
                beta = (2.0 * u) ** (1.0 / (eta + 1.0))
            else:
                beta = (1.0 / (2.0 * (1.0 - u))) ** (1.0 / (eta + 1.0))
            a = 0.5 * ((1.0 + beta) * x1 + (1.0 - beta) * x2)
            b = 0.5 * ((1.0 - beta) * x1 + (1.0 + beta) * x2)
            c1[i] = min(max(a, lo), hi)
            c2[i] = min(max(b, lo), hi)
    return tuple(c1), tuple(c2)


def mutate(rng, vec: Vector, space: ParamSpace, eta: float = 20.0,
           prob: Optional[float] = None) -> Vector:
    """Bounded polynomial mutation on float genes; choice genes re-draw
    uniformly.  Output stays inside the space's vector bounds."""
    if prob is None:
        prob = 1.0 / len(vec)
    out = list(vec)
    for i, ((lo, hi), p) in enumerate(zip(space.bounds(), space.params)):
        if rng.random() >= prob:
            continue
        if isinstance(p, ChoiceParam):
            out[i] = float(rng.integers(len(p.choices)))
            continue
        u = rng.random()
        if u < 0.5:
            delta = (2.0 * u) ** (1.0 / (eta + 1.0)) - 1.0
        else:
            delta = 1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta + 1.0))
        out[i] = min(max(out[i] + delta * (hi - lo), lo), hi)
    return tuple(out)


@dataclasses.dataclass
class Individual:
    vector: Vector
    config: Dict[str, object]
    objectives: Tuple[float, ...]
    per_scenario: Dict[str, dict]


@dataclasses.dataclass
class SearchResult:
    front: List[Individual]          # final non-dominated set, vector-sorted
    population: List[Individual]     # final population (may repeat configs)
    history: List[dict]              # per-generation stats
    objectives: Tuple[str, ...]
    scenarios: Tuple[str, ...]
    seed: int
    evaluations: int                 # distinct configs actually simulated


def _canon(space: ParamSpace, vec: Vector) -> Vector:
    # decode→encode snaps mutated choice genes to exact indices and clips
    # floats, so the evaluation cache keys on canonical vectors and
    # encode/decode stay exact inverses on everything we evaluate.
    return space.encode(space.decode(vec))


def run_search(space: ParamSpace, scenarios: Sequence[str], *,
               generations: int = 8, pop_size: int = 12, seed: int = 0,
               workers: int = 1, n_jobs: Optional[int] = None,
               engine: Optional[str] = None,
               objectives: Sequence[str] = DEFAULT_OBJECTIVES,
               chaos: bool = False, warm_start: bool = True,
               log: Optional[Callable[[str], None]] = None) -> SearchResult:
    """Run a seeded NSGA-II search; see module docstring for the
    determinism contract.  ``workers`` only changes wall-clock time."""
    if pop_size < 2:
        raise ValueError("pop_size must be >= 2")
    for name in objectives:
        if name not in OBJECTIVES:
            raise KeyError(f"unknown objective {name!r}; one of "
                           f"{sorted(OBJECTIVES)}")
    scenarios = tuple(scenarios)
    objectives = tuple(objectives)
    rng = np.random.Generator(np.random.PCG64(seed))
    cache: Dict[Vector, Tuple[Tuple[float, ...], Dict[str, dict]]] = {}

    def evaluate(vectors: Sequence[Vector]) -> None:
        todo = [v for v in dict.fromkeys(vectors) if v not in cache]
        if not todo:
            return
        cells = [to_cell_spec(space.decode(v), sc, seed=seed, n_jobs=n_jobs,
                              engine=engine, chaos=chaos)
                 for v in todo for sc in scenarios]
        rows = run_cells(cells, workers=workers)
        for i, v in enumerate(todo):
            chunk = rows[i * len(scenarios):(i + 1) * len(scenarios)]
            per_scenario = dict(zip(scenarios, chunk))
            objs = []
            for name in objectives:
                field, sign = OBJECTIVES[name]
                objs.append(math.fsum(sign * row[field] for row in chunk)
                            / len(chunk))
            penalty = INCOMPLETE_PENALTY * sum(
                not row["completed"] for row in chunk)
            cache[v] = (tuple(o + penalty for o in objs), per_scenario)

    def make_individual(vec: Vector) -> Individual:
        objs, per_scenario = cache[vec]
        return Individual(vector=vec, config=space.decode(vec),
                          objectives=objs, per_scenario=per_scenario)

    pop_vecs: List[Vector] = []
    if warm_start:
        # Individual 0 is the paper's Table-4 chain expressed in this
        # space, so the front can only match or beat the paper defaults.
        pop_vecs.append(space.encode(PAPER_DEFAULT_CONFIG))
    while len(pop_vecs) < pop_size:
        pop_vecs.append(space.encode(space.sample(rng)))
    evaluate(pop_vecs)

    history: List[dict] = []
    for gen in range(generations):
        objs = [cache[v][0] for v in pop_vecs]
        fronts = fast_non_dominated_sort(objs)
        ranks = [0] * len(pop_vecs)
        crowd = [0.0] * len(pop_vecs)
        for r, front in enumerate(fronts):
            dists = crowding_distance(objs, front)
            for idx, d in zip(front, dists):
                ranks[idx] = r
                crowd[idx] = d

        children: List[Vector] = []
        while len(children) < pop_size:
            p1 = pop_vecs[_tournament(rng, ranks, crowd)]
            p2 = pop_vecs[_tournament(rng, ranks, crowd)]
            c1, c2 = sbx_crossover(rng, p1, p2, space)
            children.append(_canon(space, mutate(rng, c1, space)))
            if len(children) < pop_size:
                children.append(_canon(space, mutate(rng, c2, space)))
        evaluate(children)

        combined = pop_vecs + children
        comb_objs = [cache[v][0] for v in combined]
        next_vecs: List[Vector] = []
        for front in fast_non_dominated_sort(comb_objs):
            if len(next_vecs) + len(front) <= pop_size:
                next_vecs.extend(front)
            else:
                dists = crowding_distance(comb_objs, front)
                # Highest crowding first; index breaks ties exactly.
                order = sorted(range(len(front)),
                               key=lambda i: (-dists[i], front[i]))
                keep = order[:pop_size - len(next_vecs)]
                next_vecs.extend(front[i] for i in keep)
                break
        pop_vecs = [combined[i] for i in next_vecs]

        final_objs = [cache[v][0] for v in pop_vecs]
        front0 = fast_non_dominated_sort(final_objs)[0]
        stats = {"generation": gen, "front_size": len(front0),
                 "evaluations": len(cache)}
        for k, name in enumerate(objectives):
            stats[f"best_{name}"] = min(o[k] for o in final_objs)
        history.append(stats)
        if log is not None:
            best = ", ".join(f"{name}={stats[f'best_{name}']:.4g}"
                             for name in objectives)
            log(f"gen {gen}: front={len(front0)} evals={len(cache)} {best}")

    final_objs = [cache[v][0] for v in pop_vecs]
    front_idx = fast_non_dominated_sort(final_objs)[0]
    front_vecs = sorted(set(pop_vecs[i] for i in front_idx))
    return SearchResult(
        front=[make_individual(v) for v in front_vecs],
        population=[make_individual(v) for v in pop_vecs],
        history=history, objectives=objectives, scenarios=scenarios,
        seed=seed, evaluations=len(cache))
