"""Policy search: parallel cell runner + multi-objective NSGA-II.

* `repro.search.runner` — hermetic `CellSpec` cells on a process pool,
  bit-identical to the serial path with stable result ordering;
* `repro.search.paramspace` — typed parameter space with exact
  encode/decode to flat vectors and seeded sampling;
* `repro.search.nsga2` — seeded NSGA-II over (cost, mean pending time,
  −utilization) across scenario families;
* `repro.search.report` — Pareto-front JSON artifact + "beats the
  paper's defaults by X% on scenario Y" comparison.
"""
from repro.search.nsga2 import (DEFAULT_OBJECTIVES, Individual, SearchResult,
                                crowding_distance, dominates,
                                fast_non_dominated_sort, mutate, run_search,
                                sbx_crossover)
from repro.search.paramspace import (ChoiceParam, FloatParam,
                                     PAPER_DEFAULT_CONFIG, ParamSpace,
                                     default_space, predictive_space,
                                     to_cell_spec)
from repro.search.report import baseline_rows, build_report, summarize
from repro.search.runner import CellError, CellSpec, run_cell, run_cells

__all__ = [
    "CellError", "CellSpec", "ChoiceParam", "DEFAULT_OBJECTIVES",
    "FloatParam", "Individual", "PAPER_DEFAULT_CONFIG", "ParamSpace",
    "SearchResult", "baseline_rows", "build_report", "crowding_distance",
    "default_space", "dominates", "fast_non_dominated_sort", "mutate",
    "predictive_space",
    "run_cell", "run_cells", "run_search", "sbx_crossover", "summarize",
    "to_cell_spec",
]
