"""Pareto-front artifact + paper-baseline comparison report.

`baseline_rows` runs the paper's actual Table-4 default chain (Alg. 2
best-fit scheduler, Alg. 3/4 non-binding rescheduler, Alg. 5/6 binding
autoscaler, 60 s knobs, m2.small workers) on the search's scenarios —
note this is the *real* best-fit scheduler, not its weighted-scorer
approximation, so the comparison is against the paper's own chain.

`build_report` turns a `SearchResult` into a JSON-serializable dict:

* ``front`` — every non-dominated config with its vector, decoded
  parameters, aggregate objectives, and per-scenario metrics;
* ``baseline`` — the paper default's per-scenario metrics;
* ``dominations`` — per scenario, which searched configs beat the paper
  default on *all three* axes (cost, mean pending time, utilization)
  simultaneously, with the cost delta in percent — the "beats the
  paper's Alg. 5/6 defaults by X% on scenario Y" line.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.search.nsga2 import SearchResult
from repro.search.runner import CellSpec, run_cells

# The paper's default chain, as an actual CellSpec (per scenario).
PAPER_BASELINE = dict(scheduler="best-fit", rescheduler="non-binding",
                      autoscaler="binding", max_pod_age_s=60.0,
                      provisioning_interval_s=60.0)

# A searched config must beat the baseline on every one of these axes at
# once to count as dominating (sign: minimize; utilization negated).
_DOMINATION_AXES = (("cost", 1.0), ("mean_pending_s", 1.0),
                    ("avg_ram_ratio", -1.0))


def baseline_cells(scenarios: Sequence[str], seed: int = 0,
                   n_jobs: Optional[int] = None,
                   engine: Optional[str] = None,
                   chaos: bool = False) -> List[CellSpec]:
    return [CellSpec(scenario=sc, seed=seed, n_jobs=n_jobs, engine=engine,
                     chaos=chaos, initial_workers=3 if chaos else 1,
                     **PAPER_BASELINE)
            for sc in scenarios]


def baseline_rows(scenarios: Sequence[str], seed: int = 0,
                  n_jobs: Optional[int] = None, engine: Optional[str] = None,
                  chaos: bool = False, workers: int = 1) -> Dict[str, dict]:
    cells = baseline_cells(scenarios, seed=seed, n_jobs=n_jobs,
                           engine=engine, chaos=chaos)
    rows = run_cells(cells, workers=workers)
    return dict(zip(scenarios, rows))


def _beats(row: dict, base: dict) -> bool:
    """Strict per-scenario Pareto domination over the baseline row."""
    no_worse = all(sign * row[f] <= sign * base[f]
                   for f, sign in _DOMINATION_AXES)
    better = any(sign * row[f] < sign * base[f]
                 for f, sign in _DOMINATION_AXES)
    return no_worse and better


def build_report(result: SearchResult, baseline: Dict[str, dict]) -> dict:
    """JSON-serializable search artifact (see module docstring)."""
    front = []
    for ind in result.front:
        front.append({
            "vector": list(ind.vector),
            "config": ind.config,
            "objectives": dict(zip(result.objectives, ind.objectives)),
            "per_scenario": {
                sc: {k: row[k] for k in ("cost", "mean_pending_s",
                                         "avg_ram_ratio", "lost_work_s",
                                         "completed")}
                for sc, row in ind.per_scenario.items()},
        })

    dominations = []
    for scenario, base in baseline.items():
        for i, ind in enumerate(result.front):
            row = ind.per_scenario.get(scenario)
            if row is None or not row["completed"] or not _beats(row, base):
                continue
            cost_delta_pct = (100.0 * (base["cost"] - row["cost"])
                              / base["cost"]) if base["cost"] else 0.0
            dominations.append({
                "scenario": scenario, "front_index": i,
                "config": ind.config,
                "cost_delta_pct": cost_delta_pct,
                "searched": {f: row[f] for f, _ in _DOMINATION_AXES},
                "paper_default": {f: base[f] for f, _ in _DOMINATION_AXES},
            })
    dominations.sort(key=lambda d: -d["cost_delta_pct"])

    return {
        "objectives": list(result.objectives),
        "scenarios": list(result.scenarios),
        "seed": result.seed,
        "evaluations": result.evaluations,
        "history": result.history,
        "front": front,
        "baseline": {sc: {k: row[k] for k in ("cost", "mean_pending_s",
                                              "avg_ram_ratio", "lost_work_s",
                                              "completed")}
                     for sc, row in baseline.items()},
        "dominations": dominations,
    }


def summarize(report: dict) -> List[str]:
    """Human-readable lines for the CLI ("beats the paper's defaults by
    X% on scenario Y")."""
    lines = [f"Pareto front: {len(report['front'])} configs "
             f"({report['evaluations']} distinct configs simulated, "
             f"seed {report['seed']})"]
    if not report["dominations"]:
        lines.append("no searched config strictly dominates the paper "
                     "default on any scenario (front still traces the "
                     "cost/latency/utilization trade-off)")
        return lines
    seen = set()
    for dom in report["dominations"]:
        if dom["scenario"] in seen:
            continue
        seen.add(dom["scenario"])
        cfg = dom["config"]
        lines.append(
            f"beats the paper's Alg. 5/6 defaults by "
            f"{dom['cost_delta_pct']:.1f}% cost on {dom['scenario']} "
            f"(also no worse on pending time and utilization) — "
            f"rescheduler={cfg['rescheduler']} autoscaler={cfg['autoscaler']}"
            f" template={cfg['template']}")
    return lines
