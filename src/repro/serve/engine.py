"""Serving engine: jitted prefill/decode + slot-based continuous batching.

`ServeEngine` is the long-running *service* job the orchestrator deploys
(paper: an nginx deployment; fleet: an LLM endpoint).  Design:

* fixed decode batch of ``num_slots`` (static shapes — one compiled decode
  step regardless of arrival pattern),
* per-request prefill (B=1) whose cache rows are inserted into the batched
  decode state (continuous batching, vLLM-style at slot granularity),
* per-example cache positions, so slots at different generation depths
  coexist in one decode step,
* `snapshot()/restore()` — the *moveable service* contract: the orchestrator
  can evict the engine and recreate it elsewhere without losing in-flight
  generation state.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve.sampling import SamplingConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 4
    cache_len: int = 256
    sampling: SamplingConfig = dataclasses.field(default_factory=SamplingConfig)
    eos_id: int = -1                   # -1: only stop on max_new_tokens


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None,
                 clock: Callable[[], float] = time.time):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.extra = extra_inputs or {}
        # Injectable wall clock: request timestamps (first_token_at /
        # done_at) come from here, so tests can drive a deterministic
        # virtual clock instead of sleeping on real time.
        self.clock = clock
        B = ecfg.num_slots
        self.states = tf.init_decode_state(cfg, B, ecfg.cache_len,
                                           dtype=jnp.dtype(cfg.dtype))
        self.last_tokens = jnp.zeros((B, 1), jnp.int32)
        self.active: List[Optional[Request]] = [None] * B
        self.remaining = np.zeros((B,), np.int32)
        self.rng = jax.random.key(0)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # -- jitted cores ------------------------------------------------------------
    def _decode_impl(self, params, tokens, states, rng):
        logits, new_states = tf.decode_step(params, tokens, states, self.cfg)
        rng, sub = jax.random.split(rng)
        nxt = sample(sub, logits, dataclasses.replace(
            self.ecfg.sampling, vocab_size=self.cfg.vocab_size))
        return nxt[:, None], new_states, rng

    def _prefill_impl(self, params, batch):
        return tf.prefill(params, batch, self.cfg, self.ecfg.cache_len)

    # -- slot management -----------------------------------------------------------
    def _insert_slot(self, slot: int, row_states, first_token: int) -> None:
        # Every decode-state leaf keeps its batch dim in the same position as
        # the B=1 prefill row state; locate it by the size-1 axis and insert.
        def ins(b, r):
            # b: (..., B, ...) with batch at axis (r.ndim - b.ndim + ...)
            # prefill row state has batch dim of size 1 in the same position.
            axis = _batch_axis(b, r)
            idx = [slice(None)] * b.ndim
            idx[axis] = slice(slot, slot + 1)
            return b.at[tuple(idx)].set(r.astype(b.dtype))

        self.states = jax.tree.map(ins, self.states, row_states)
        self.last_tokens = self.last_tokens.at[slot, 0].set(first_token)

    def admit(self, req: Request) -> bool:
        """Prefill the request and place it into a free slot."""
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free:
            return False
        slot = free[0]
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        for k, v in self.extra.items():
            batch[k] = jnp.asarray(v)[None]
        logits, row_states = self._prefill(self.params, batch)
        first = int(jnp.argmax(
            jnp.where(jnp.arange(logits.shape[-1]) < self.cfg.vocab_size,
                      logits[0].astype(jnp.float32), -1e30)))
        self._insert_slot(slot, row_states, first)
        req.tokens.append(first)
        req.first_token_at = self.clock()
        self.active[slot] = req
        self.remaining[slot] = req.max_new_tokens - 1
        return True

    def step(self) -> List[Request]:
        """One batched decode step; returns requests finished this step."""
        if not any(r is not None for r in self.active):
            return []
        self.last_tokens, self.states, self.rng = self._decode(
            self.params, self.last_tokens, self.states, self.rng)
        out = np.asarray(self.last_tokens[:, 0])
        finished: List[Request] = []
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(out[slot])
            req.tokens.append(tok)
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or tok == self.ecfg.eos_id:
                req.done_at = self.clock()
                finished.append(req)
                self.active[slot] = None
        return finished

    # -- the moveable-service contract ---------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        import copy
        return {
            "states": jax.tree.map(np.asarray, self.states),
            "last_tokens": np.asarray(self.last_tokens),
            "active": copy.deepcopy(self.active),   # frozen in-flight state
            "remaining": self.remaining.copy(),
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        self.states = jax.tree.map(jnp.asarray, snap["states"])
        self.last_tokens = jnp.asarray(snap["last_tokens"])
        self.active = list(snap["active"])
        self.remaining = snap["remaining"].copy()


def _batch_axis(batched: jax.Array, row: jax.Array) -> int:
    """Find the batch axis: first axis where row has size 1 and batched is
    larger (row comes from a B=1 prefill; a leading scan axis matches)."""
    for ax in range(batched.ndim):
        if row.shape[ax] == 1 and batched.shape[ax] > 1:
            return ax
        if row.shape[ax] != batched.shape[ax]:
            raise ValueError(f"incompatible state shapes {batched.shape} "
                             f"vs {row.shape}")
    raise ValueError(f"no batch axis in {batched.shape} vs {row.shape}")


def run_server(engine: ServeEngine, requests: List[Request],
               log: Callable[[str], None] = print,
               clock: Optional[Callable[[], float]] = None,
               sleep: Callable[[float], None] = time.sleep
               ) -> Dict[str, float]:
    """Drive the engine over a request list (arrival times respected via
    submitted_at ordering); returns latency/throughput metrics.

    ``clock``/``sleep`` default to wall time; a test can pass a virtual
    clock (and a sleep that advances it) for a deterministic run — the
    engine's own timestamps follow ``engine.clock``, which defaults to the
    same ``clock`` when one is given here."""
    if clock is None:
        clock = engine.clock
    else:
        engine.clock = clock
    pending = sorted(requests, key=lambda r: r.submitted_at)
    t0 = clock()
    done: List[Request] = []
    qi = 0
    while len(done) < len(requests):
        now = clock() - t0
        while qi < len(pending) and pending[qi].submitted_at <= now:
            if engine.admit(pending[qi]):
                qi += 1
            else:
                break
        finished = engine.step()
        done.extend(finished)
        if not finished and qi < len(pending) and \
           not any(engine.active):
            # idle: jump to next arrival
            sleep(max(0.0, pending[qi].submitted_at - (clock() - t0)))
    total_tokens = sum(len(r.tokens) for r in done)
    dt = clock() - t0
    ttfts = [r.first_token_at - t0 - r.submitted_at for r in done
             if r.first_token_at]
    return {"requests": len(done), "tokens": total_tokens,
            "elapsed_s": dt, "tokens_per_s": total_tokens / max(dt, 1e-9),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0}
