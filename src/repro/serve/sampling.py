"""Token sampling: greedy / temperature / top-k (jit-friendly)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> full softmax
    vocab_size: Optional[int] = None   # mask padded columns


def sample(rng: jax.Array, logits: jax.Array,
           cfg: SamplingConfig) -> jax.Array:
    """logits: (B, Vp) -> (B,) int32."""
    lf = logits.astype(jnp.float32)
    if cfg.vocab_size is not None and cfg.vocab_size < lf.shape[-1]:
        col = jnp.arange(lf.shape[-1])
        lf = jnp.where(col[None, :] < cfg.vocab_size, lf, -1e30)
    if cfg.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    lf = lf / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(lf, axis=-1)[:, -cfg.top_k][:, None]
        lf = jnp.where(lf >= kth, lf, -1e30)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)
