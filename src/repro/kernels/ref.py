"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
used by the interpret=True shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  sm_scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, T, hd); k/v: (B, Hkv, S, hd).  f32 softmax, GQA repeat."""
    B, Hq, T, hd = q.shape
    _, Hkv, S, _ = k.shape
    if sm_scale is None:
        sm_scale = hd ** -0.5
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    ti = jnp.arange(T)[:, None]
    si = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask = mask & (si <= ti)
    if window > 0:
        mask = mask & (si > ti - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential h_t = a_t*h_{t-1} + b_t (f32 state), shape (B, T, R)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(af, 1, 0),
                                    jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
