"""Jitted public wrappers around the Pallas TPU kernels.

On a TPU backend these call the compiled kernels; everywhere else they fall
back to the jnp oracle (`ref.py`) unless interpret-mode is forced — which is
how the CPU test suite validates the kernel bodies instruction-by-
instruction (`interpret=True` executes the Pallas program in Python).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "sm_scale",
                                             "force", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    sm_scale: Optional[float] = None,
                    force: bool = False,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, T, hd); k/v: (B, Hkv, S, hd) -> (B, Hq, T, hd)."""
    if interpret or force or _on_tpu():
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   sm_scale=sm_scale, interpret=interpret
                                   or not _on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              sm_scale=sm_scale)


@functools.partial(jax.jit, static_argnames=("force", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, force: bool = False,
               interpret: bool = False) -> jax.Array:
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + b_t; (B, T, R)."""
    if interpret or force or _on_tpu():
        return _rg.rglru_scan(a, b, interpret=interpret or not _on_tpu())
    return _ref.rglru_scan_ref(a, b)
