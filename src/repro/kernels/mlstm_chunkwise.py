"""Pallas TPU kernel for the chunkwise-parallel mLSTM (xLSTM matrix memory).

Same math as `repro.models.xlstm._mlstm_chunkwise` (the jnp oracle for this
kernel): an outer sequential walk over chunks carries the stabilized matrix
memory (C, n, m) in VMEM scratch; within a chunk everything is a masked
MXU matmul against the cumulative log-gates.

TPU mapping: grid = (batch, heads, chunks) with the chunk dimension
`arbitrary` (sequential); per-(b,h) the C scratch is a (dk, dv) f32 tile —
VMEM-resident across the whole sequence walk, never touching HBM between
chunks (the HBM traffic is exactly q/k/v/gates in and h out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept either
# so the kernels load on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_CHUNK = 64
NEG_BIG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
                  c_scr, n_scr, m_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_BIG)

    q = q_ref[0, 0].astype(jnp.float32)               # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)               # (L, dk)
    v = v_ref[0, 0].astype(jnp.float32)               # (L, dv)
    ii = i_ref[0, 0].astype(jnp.float32)              # (L,)
    ff = f_ref[0, 0].astype(jnp.float32)              # (L,)

    flog = jax.nn.log_sigmoid(ff)
    b = jnp.cumsum(flog)                              # (L,)
    g = b[-1]
    C, n, m = c_scr[...], n_scr[...], m_scr[...][0]

    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = idx >= jdx

    log_a = b + m                                     # (L,)
    D = b[:, None] - b[None, :] + ii[None, :]
    D = jnp.where(tri, D, NEG_BIG)
    m_i = jnp.maximum(jnp.maximum(log_a, jnp.max(D, axis=-1)), NEG_BIG)
    inter_w = jnp.exp(log_a - m_i)                    # (L,)
    Sij = jnp.exp(D - m_i[:, None])                   # (L,L)
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    num = (inter_w[:, None] * jax.lax.dot_general(
        q, C, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
        + jax.lax.dot_general(Sij * qk, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32))
    den = inter_w * (q @ n) + jnp.sum(Sij * qk, axis=-1)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[:, None]
    o_ref[0, 0] = h.astype(o_ref.dtype)

    # state update (stabilized)
    w_j = g - b + ii                                  # (L,)
    m_new = jnp.maximum(jnp.maximum(g + m, jnp.max(w_j)), NEG_BIG)
    scale_old = jnp.exp(g + m - m_new)
    wj = jnp.exp(w_j - m_new)
    c_scr[...] = scale_old * C + jax.lax.dot_general(
        k * wj[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_scr[...] = scale_old * n + jnp.sum(k * wj[:, None], axis=0)
    m_scr[...] = jnp.full_like(m_scr, m_new)


def mlstm_chunkwise(q: jax.Array, k: jax.Array, v: jax.Array,
                    i_raw: jax.Array, f_raw: jax.Array, *,
                    chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False) -> jax.Array:
    """q,k,v: (B, H, T, dh); i_raw/f_raw: (B, H, T) -> h: (B, H, T, dh)."""
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    grid = (B, H, T // L)

    kernel = functools.partial(_mlstm_kernel, chunk=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, dv), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, i_raw, f_raw)
