"""Pallas TPU flash attention: blockwise online-softmax, causal + GQA +
sliding window.

TPU-native design (not a CUDA port — DESIGN.md §8):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks dimension is
    `arbitrary` (sequential) so the online-softmax running state lives in
    VMEM scratch across kv steps — HBM→VMEM staging replaces shared-memory
    tiling, and there is no warp-level anything.
  * q/k/v tiles are MXU-aligned (block sizes multiples of 128 where the
    sequence allows; head_dim 64-256 is fine as the contracted dim).
  * GQA is free: the k/v BlockSpec index_map maps q-head h to kv-head
    h // q_per_kv — no repeated k/v materialization.
  * causal + window masking is done on global indices derived from
    program_ids; fully-masked (q,k) tile pairs are skipped via pl.when.

Numerics: f32 accumulation of logits/softmax state regardless of input
dtype; output cast back to the query dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept either
# so the kernels load on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, sm_scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Skip tiles that the causal/window mask fully zeroes.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                # (BQ, BK)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = ki < seq_len
        if causal:
            mask = jnp.logical_and(mask, ki <= qi)
        if window > 0:
            mask = jnp.logical_and(mask, ki > qi - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (BQ,)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        # renormalize the running state
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    sm_scale: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, T, hd); k/v: (B, Hkv, S, hd); Hq % Hkv == 0.

    Returns (B, Hq, T, hd) in q.dtype.
    """
    B, Hq, T, hd = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    q_per_kv = Hq // Hkv
    if sm_scale is None:
        sm_scale = hd ** -0.5
    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    grid = (B, Hq, T // bq, S // bk)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_len=S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, qkv=q_per_kv:
                         (b, h // qkv, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, qkv=q_per_kv:
                         (b, h // qkv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
