"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

Computes h_t = a_t * h_{t-1} + b_t over the time axis, with the carry state
held in VMEM scratch across sequential time-chunk grid steps:

  grid = (batch, channel_blocks, time_chunks); the last dimension is
  `arbitrary` (sequential), so each (b, rblk) pair walks its time chunks in
  order while `h` persists in a (1, block_r) f32 scratch.  Inside a chunk the
  recurrence runs as a fori_loop over rows of the VMEM-resident tile —
  per-step work is a fused multiply-add over `block_r` lanes (VPU-friendly,
  lanes a multiple of 128).

This is the TPU adaptation of a GPU scan kernel: no warp shuffles/shared
memory — the parallelism is (batch × channels) across the grid and 8x128
vector lanes within, with HBM→VMEM tiling over time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept either
# so the kernels load on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BLOCK_R = 512
DEFAULT_CHUNK_T = 256


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk_t, body, h_scr[0])
    h_scr[0] = h


def rglru_scan(a: jax.Array, b: jax.Array, *,
               block_r: int = DEFAULT_BLOCK_R,
               chunk_t: int = DEFAULT_CHUNK_T,
               interpret: bool = False) -> jax.Array:
    """a, b: (B, T, R) -> h: (B, T, R) with h_t = a_t*h_{t-1} + b_t."""
    B, T, R = a.shape
    br = min(block_r, R)
    ct = min(chunk_t, T)
    assert R % br == 0 and T % ct == 0, (R, br, T, ct)
    grid = (B, R // br, T // ct)

    kernel = functools.partial(_rglru_kernel, chunk_t=ct)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, br), lambda bb, rr, tt: (bb, tt, rr)),
            pl.BlockSpec((1, ct, br), lambda bb, rr, tt: (bb, tt, rr)),
        ],
        out_specs=pl.BlockSpec((1, ct, br), lambda bb, rr, tt: (bb, tt, rr)),
        out_shape=jax.ShapeDtypeStruct((B, T, R), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, br), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
