"""Post-SPMD HLO text analysis: collective bytes with while-loop awareness.

`compiled.cost_analysis()` counts a `while` (scan) body once, not ×trip-count
(measured; DESIGN.md §6), and provides no per-collective breakdown at all —
so we parse the compiled HLO text:

* every `all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute` op contributes its (per-device, post-SPMD) result
  bytes;
* `while` ops multiply their body's total by the trip count, which XLA
  materializes as the `s32[] constant(N)` bound in the loop's condition
  computation (largest s32 constant there — loop bounds dominate the 0/1
  step constants).

Everything is per-device; multiply by chip count for fleet totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\dm\d(?:fn)?)?)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%([\w.\-]+), body=%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\] constant\((\d+)\)")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\S.*?)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    collectives: List[Tuple[str, int]]
    whiles: List[Tuple[str, str]]      # (condition, body)
    constants: List[int]


def _parse_computations(hlo_text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    current: Optional[_Comp] = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip()) if "{" in line else None
        if m and not line.startswith(" "):
            current = _Comp(m.group(1), [], [], [])
            comps[current.name] = current
            continue
        if current is None:
            continue
        s = line.strip()
        cm = _COLL_RE.match(s)
        if cm:
            kind = cm.group(2).replace("-start", "")
            current.collectives.append((kind, shape_bytes(cm.group(1))))
        wm = _WHILE_RE.search(s)
        if wm:
            current.whiles.append((wm.group(1), wm.group(2)))
        for c in _CONST_RE.findall(s):
            current.constants.append(int(c))
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by kind, while-trip-count aware."""
    comps = _parse_computations(hlo_text)

    def trip_count(cond_name: str) -> int:
        cond = comps.get(cond_name)
        if cond is None or not cond.constants:
            return 1
        return max(max(cond.constants), 1)

    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack:
            return {}
        comp = comps.get(name)
        if comp is None:
            return {}
        acc: Dict[str, float] = {}
        for kind, nbytes in comp.collectives:
            acc[kind] = acc.get(kind, 0.0) + nbytes
        for cond, body in comp.whiles:
            trips = trip_count(cond)
            sub = total(body, stack + (name,))
            for kind, nbytes in sub.items():
                acc[kind] = acc.get(kind, 0.0) + trips * nbytes
        memo[name] = acc
        return acc

    # entry computation: the last computation defined, or the one named in
    # the ENTRY line; identify via "ENTRY" marker.
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None:   # fall back: whichever computation no one calls
        called = {b for c in comps.values() for _, b in c.whiles}
        candidates = [n for n in comps if n not in called]
        entry = candidates[-1] if candidates else next(iter(comps), None)
    out = dict(total(entry)) if entry else {}
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def while_trip_counts(hlo_text: str) -> List[int]:
    """All loop trip counts found (diagnostics)."""
    comps = _parse_computations(hlo_text)
    out = []
    for comp in comps.values():
        for cond, _ in comp.whiles:
            c = comps.get(cond)
            out.append(max(c.constants) if c and c.constants else 1)
    return out
