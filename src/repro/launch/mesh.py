"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization, and smoke tests must keep seeing the single real CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic resizing, tests)."""
    return jax.make_mesh(shape, axes)


def local_mesh():
    """Whatever devices exist locally, as a 1-D (data,) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
