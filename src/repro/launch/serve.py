"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Boots a `ServeEngine` (continuous batching) on a reduced config and drives a
synthetic request stream, printing latency/throughput — the *service* job
kind the orchestrator deploys.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import transformer as tf
from repro.models.params import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine, run_server
from repro.serve.sampling import SamplingConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="deepseek-7b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mean-interarrival-s", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    params = init_params(jax.random.key(args.seed), tf.model_specs(cfg),
                         cfg.param_dtype)
    extra = {}
    if cfg.family == "vlm":
        extra["pixel_embeds"] = 0.02 * np.random.default_rng(0).standard_normal(
            (cfg.vision_prefix_len, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extra["audio_embeds"] = 0.02 * np.random.default_rng(0).standard_normal(
            (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    engine = ServeEngine(cfg, params, EngineConfig(
        num_slots=args.slots, cache_len=args.cache_len,
        sampling=SamplingConfig(temperature=args.temperature)),
        extra_inputs=extra)

    rng = np.random.default_rng(args.seed)
    t = 0.0
    requests = []
    for i in range(args.requests):
        t += float(rng.exponential(args.mean_interarrival_s))
        plen = int(rng.integers(4, 17))
        requests.append(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=args.max_new_tokens, submitted_at=t))
    metrics = run_server(engine, requests)
    print(f"[serve] {metrics}")


if __name__ == "__main__":
    main()
