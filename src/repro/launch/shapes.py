"""Assigned input-shape suites and `input_specs()` (ShapeDtypeStruct
stand-ins — weak-type-correct, shardable, no device allocation).

Four shapes per LM architecture (40 cells total):
  train_4k     seq 4096,   global batch 256   -> train_step
  prefill_32k  seq 32768,  global batch 32    -> serve prefill
  decode_32k   cache 32768, global batch 128  -> serve decode (1 new token)
  long_500k    cache 524288, global batch 1   -> decode, sub-quadratic only

`long_500k` is skipped for pure full-attention archs (documented in
DESIGN.md §5); `[audio]`/`[vlm]` input specs carry stubbed frame/patch
embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("full quadratic attention: a 500k-token KV cache/"
                       "attention row is out of scope by design (DESIGN.md §5)")
    return True, ""


def cells(archs: List[str]) -> List[Tuple[str, str]]:
    from repro.configs import get_config
    out = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if applicable(cfg, shape)[0]:
                out.append((arch, shape.name))
    return out


# --------------------------------------------------------------------------- #
# input specs (ShapeDtypeStructs)
# --------------------------------------------------------------------------- #

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    accum = cfg.train_accum
    assert B % max(accum, 1) == 0, (B, accum)
    lead = (accum,) if accum > 1 else ()
    B = B // max(accum, 1)
    S_text = S - cfg.vision_prefix_len if cfg.family == "vlm" else S
    batch = {
        "tokens": _sds(lead + (B, S_text), jnp.int32),
        "labels": _sds(lead + (B, S_text), jnp.int32),
        "loss_mask": _sds(lead + (B, S_text), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["pixel_embeds"] = _sds(
            lead + (B, cfg.vision_prefix_len, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = _sds(
            lead + (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    S_text = S - cfg.vision_prefix_len if cfg.family == "vlm" else S
    batch = {"tokens": _sds((B, S_text), jnp.int32)}
    if cfg.family == "vlm":
        batch["pixel_embeds"] = _sds((B, cfg.vision_prefix_len, cfg.d_model),
                                     cfg.dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                     cfg.dtype)
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[Dict, object]:
    """(token specs, decode-state specs) for one decode step."""
    B, S = shape.global_batch, shape.seq_len
    tokens = _sds((B, 1), jnp.int32)
    # production decode waves advance uniformly -> scalar positions (the
    # per-example variant exists for the continuous-batching engine)
    states = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, B, S, dtype=jnp.dtype(cfg.dtype),
                                     per_example_pos=False))
    return tokens, states


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict:
    """All model inputs for an (arch, shape) cell, as ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name} skipped: {why}")
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    tokens, states = decode_input_specs(cfg, shape)
    return {"tokens": tokens, "states": states}
