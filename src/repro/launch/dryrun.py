import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the production dry-run needs 512
# placeholder host devices to build the 2x16x16 multi-pod mesh.

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, list_archs              # noqa: E402
from repro.configs.base import ArchConfig                      # noqa: E402
from repro.distributed.sharding import (DEFAULT_RULES, ShardingCtx,  # noqa: E402
                                        sharding_ctx, tree_shardings)
from repro.launch import shapes as shp                         # noqa: E402
from repro.launch.hlo_analysis import collective_bytes         # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models import transformer as tf                     # noqa: E402
from repro.train.optimizer import OptimizerConfig              # noqa: E402
from repro.train.train_step import (init_train_state, make_train_step,  # noqa: E402
                                    train_state_axes)

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, build the jitted step for the
production mesh — single-pod (16, 16) and multi-pod (2, 16, 16) — then
``.lower().compile()`` and record:

  * ``compiled.memory_analysis()``  (proves the cell fits per-device HBM),
  * ``compiled.cost_analysis()``    (per-device FLOPs/bytes, scan-body-once),
  * collective bytes parsed from the compiled HLO (while-trip aware).

Artifacts land in ``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` and feed
``benchmarks/roofline.py`` (§Roofline) and EXPERIMENTS.md §Dry-run.
"""

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _batch_axes_tree(batch_specs: Dict, accum: int = 1) -> Dict:
    lead = (None,) if accum > 1 else ()
    return {k: lead + ("act_batch",) + (None,) * (len(v.shape) - 1 - len(lead))
            for k, v in batch_specs.items()}


def build_lowered(cfg: ArchConfig, shape_name: str, mesh,
                  rules: Optional[Dict] = None,
                  remat: bool = True, donate: bool = True):
    """Returns (lowered, meta) for one cell on one mesh."""
    rules = dict(rules or DEFAULT_RULES)
    rules.update(dict(cfg.rule_overrides))
    ctx = ShardingCtx(mesh, rules)
    shape = shp.SHAPES[shape_name]
    specs = shp.input_specs(cfg, shape_name)

    with sharding_ctx(mesh, rules):
        if shape.kind == "train":
            state_shapes = jax.eval_shape(
                lambda: init_train_state(jax.random.key(0), cfg))
            state_sh = tree_shardings(ctx, state_shapes,
                                      train_state_axes(cfg))
            batch = specs["batch"]
            accum = cfg.train_accum
            batch_sh = tree_shardings(ctx, batch,
                                      _batch_axes_tree(batch, accum))
            step = make_train_step(cfg, OptimizerConfig(), accum=accum,
                                   remat=remat)
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_shapes, batch)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                lambda: __import_params(cfg))
            from repro.models.params import param_axes
            params_sh = tree_shardings(ctx, params_shapes,
                                       param_axes(tf.model_specs(cfg)))
            batch = specs["batch"]
            batch_sh = tree_shardings(ctx, batch, _batch_axes_tree(batch))

            def prefill_fn(params, batch):
                return tf.prefill(params, batch, cfg, shape.seq_len)

            fn = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
            lowered = fn.lower(params_shapes, batch)
        else:   # decode
            params_shapes = jax.eval_shape(
                lambda: __import_params(cfg))
            from repro.models.params import param_axes
            params_sh = tree_shardings(ctx, params_shapes,
                                       param_axes(tf.model_specs(cfg)))
            tokens, states = specs["tokens"], specs["states"]
            state_axes = tf.decode_state_axes(cfg)
            states_sh = tree_shardings(ctx, states, state_axes)
            tokens_sh = ctx.sharding_for(tokens.shape, ("act_batch", None))

            def decode_fn(params, tokens, states):
                return tf.decode_step(params, tokens, states, cfg)

            fn = jax.jit(decode_fn,
                         in_shardings=(params_sh, tokens_sh, states_sh),
                         out_shardings=(None, states_sh),
                         donate_argnums=(2,) if donate else ())
            lowered = fn.lower(params_shapes, tokens, states)
    return lowered


def __import_params(cfg: ArchConfig):
    # Serving runs on inference-cast weights (bf16), the production norm —
    # training keeps cfg.param_dtype (f32 masters).
    from repro.models.params import param_shapes
    return param_shapes(tf.model_specs(cfg), cfg.dtype)


def analyze_compiled(compiled) -> Dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes,
        },
        "cost": {"flops_per_device": float(ca.get("flops", 0.0)),
                 "bytes_per_device": float(ca.get("bytes accessed", 0.0))},
        "collectives_per_device": coll,
        "hlo_bytes": len(txt),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, remat: bool = True,
             rules: Optional[Dict] = None, tag: str = "",
             cfg_overrides: Optional[Dict] = None) -> Dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh_name = "multi" if multi_pod else "single"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = build_lowered(cfg, shape_name, mesh, rules=rules, remat=remat)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    result = analyze_compiled(compiled)
    result.update({
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "ok": True, "tag": tag,
    })
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
          f"(compile {t_compile:.1f}s, "
          f"temp {result['memory']['temp_bytes']/2**30:.2f} GiB/dev, "
          f"coll {result['collectives_per_device']['total']/2**30:.2f} "
          f"GiB/dev)")
    print(f"  memory_analysis: {compiled.memory_analysis()}")
    ca = compiled.cost_analysis() or {}
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e} (per device)")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    n_ok = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shape_names = (list(shp.SHAPES) if args.shape == "all"
                       else [args.shape])
        for shape_name in shape_names:
            ok, why = shp.applicable(cfg, shp.SHAPES[shape_name])
            if not ok:
                print(f"[dryrun] {arch} x {shape_name}: SKIP ({why})")
                n_skip += 1
                continue
            for multi_pod in meshes:
                try:
                    run_cell(arch, shape_name, multi_pod, out_dir=args.out)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape_name, multi_pod, str(e)))
    print(f"\n[dryrun] {n_ok} cells OK, {n_skip} documented skips, "
          f"{len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
