"""Training launcher: ``python -m repro.launch.train --arch <id> [--tiny]``.

Runs the real training loop (synthetic data) on the local devices; the full
production-mesh path is exercised via ``repro.launch.dryrun`` (this host has
one CPU device).  Checkpointing/resume flags expose the fault-tolerance
substrate the orchestrator drives.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, list_archs
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="deepseek-7b", choices=list_archs())
    ap.add_argument("--tiny", action="store_true", default=True,
                    help="use the reduced smoke config (default on CPU)")
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=args.lr, warmup_steps=args.warmup,
                        total_steps=args.steps),
        DataConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                   accum=args.accum, seed=args.seed),
        TrainerConfig(total_steps=args.steps,
                      checkpoint_every=args.checkpoint_every,
                      checkpoint_dir=args.checkpoint_dir, seed=args.seed),
    )
    result = trainer.run()
    print(f"[train] result: {result}")


if __name__ == "__main__":
    main()
