"""Orchestration launcher — the paper's system end to end.

``python -m repro.launch.orchestrate --workload slow --rescheduler
non-binding --autoscaler binding`` runs one experiment;
``--compare`` reproduces the Fig. 3 grid + the Fig. 4 K8s baseline for a
workload and prints the cost-reduction headline.
"""
from __future__ import annotations

import argparse

from repro.core import (ExperimentSpec, run_all_combos, run_experiment,
                        run_k8s_baseline)
from repro.core.failures import FailureInjector


def _print(r, k8s_cost=None) -> None:
    save = f"  save={100 * (1 - r.cost / k8s_cost):.1f}%" if k8s_cost else ""
    print(f"  {r.combo():10s} cost=${r.cost:8.2f} dur={r.duration_s:7.0f}s "
          f"medpend={r.median_pending_s:6.1f}s ram={r.avg_ram_ratio:.2f} "
          f"cpu={r.avg_cpu_ratio:.2f} pods/node={r.avg_pods_per_node:.2f} "
          f"maxN={r.max_nodes} evic={r.evictions}{save}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="mixed",
                    choices=["bursty", "slow", "mixed"])
    ap.add_argument("--rescheduler", default="non-binding",
                    choices=["void", "non-binding", "binding"])
    ap.add_argument("--autoscaler", default="binding",
                    choices=["void", "non-binding", "binding"])
    ap.add_argument("--scheduler", default="best-fit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", action="store_true",
                    help="run all 6 combos + the K8s static baseline")
    ap.add_argument("--failures", action="store_true",
                    help="inject node failures (fleet fault-tolerance demo)")
    args = ap.parse_args()

    injector = FailureInjector(mtbf_s=1800.0, seed=args.seed) \
        if args.failures else None

    if args.compare:
        print(f"[orchestrate] workload={args.workload} (Fig. 3 + Fig. 4)")
        k8s = run_k8s_baseline(args.workload, seed=args.seed)
        print(f"  K8S-static n={k8s.max_nodes} cost=${k8s.cost:8.2f} "
              f"dur={k8s.duration_s:7.0f}s")
        for r in run_all_combos(args.workload, seed=args.seed):
            _print(r, k8s.cost)
        return

    spec = ExperimentSpec(workload=args.workload, scheduler=args.scheduler,
                          rescheduler=args.rescheduler,
                          autoscaler=args.autoscaler, seed=args.seed,
                          failure_injector=injector)
    r = run_experiment(spec)
    print(f"[orchestrate] workload={args.workload} completed={r.completed} "
          f"failures={r.failures_injected}")
    _print(r)


if __name__ == "__main__":
    main()
