import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede any jax-importing module (same contract as dryrun.py).

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Dict, List, Tuple  # noqa: E402

import jax          # noqa: E402

from repro.configs import get_config, list_archs          # noqa: E402
from repro.configs.base import ArchConfig                  # noqa: E402
from repro.launch import shapes as shp                     # noqa: E402
from repro.launch.dryrun import build_lowered              # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402

"""Compositional cost probe (§Roofline methodology, DESIGN.md §6).

`compiled.cost_analysis()` counts a scan body once — so the full-model
FLOPs/bytes are extrapolated from two reduced-depth variants compiled with
*inlined* layers (`unroll_layers=True`):

    F(L_full) = F(La) + (F(Lb) - F(La)) / (Lb - La) x (L_full - La)

Each architecture family picks (La, Lb) = one and two repetitions of its
block pattern (the MoE first-dense layer and the whisper encoder scale along
with the probes, so the delta isolates exactly one pattern repetition).
Remat recompute is included — the probes differentiate through the same
checkpointed blocks the real step uses.
"""

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "costprobe")


def probe_configs(cfg: ArchConfig) -> Tuple[ArchConfig, ArchConfig, int, int]:
    """(cfg_a, cfg_b, La, Lb) reduced-depth inlined variants."""
    if cfg.family == "ssm":
        k = cfg.slstm_every or 1
        la, lb = k, 2 * k
    elif cfg.family == "hybrid":
        la, lb = cfg.rglru_pattern, 2 * cfg.rglru_pattern
    elif cfg.n_experts > 0 and cfg.first_k_dense:
        la, lb = cfg.first_k_dense + 1, cfg.first_k_dense + 2
    else:
        la, lb = 1, 2
    def mk(n):
        kw = dict(num_layers=n, unroll_layers=True)
        if cfg.is_encoder_decoder:
            kw["encoder_layers"] = n
        return dataclasses.replace(cfg, **kw)
    return mk(la), mk(lb), la, lb


def _cost(cfg: ArchConfig, shape_name: str, mesh) -> Dict[str, float]:
    compiled = build_lowered(cfg, shape_name, mesh).compile()
    ca = compiled.cost_analysis() or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def run_probe(arch: str, shape_name: str, out_dir: str) -> Dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    cfg_a, cfg_b, la, lb = probe_configs(cfg)
    t0 = time.time()
    fa = _cost(cfg_a, shape_name, mesh)
    fb = _cost(cfg_b, shape_name, mesh)
    n_steps = (cfg.num_layers - la) / (lb - la)
    full = {k: fa[k] + (fb[k] - fa[k]) * n_steps for k in fa}
    result = {
        "arch": arch, "shape": shape_name, "mesh": "single",
        "devices": mesh.devices.size,
        "probe_layers": [la, lb],
        "flops_per_device_a": fa["flops"], "flops_per_device_b": fb["flops"],
        "bytes_per_device_a": fa["bytes"], "bytes_per_device_b": fb["bytes"],
        "flops_per_device_full": full["flops"],
        "bytes_per_device_full": full["bytes"],
        "elapsed_s": round(time.time() - t0, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}.json"), "w") as f:
        json.dump(result, f, indent=1)
    print(f"[costprobe] {arch} x {shape_name}: "
          f"full flops/dev {full['flops']:.3e} bytes/dev {full['bytes']:.3e} "
          f"({result['elapsed_s']}s)")
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()
    archs = list_archs() if args.arch == "all" else [args.arch]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        names = list(shp.SHAPES) if args.shape == "all" else [args.shape]
        for shape_name in names:
            if not shp.applicable(cfg, shp.SHAPES[shape_name])[0]:
                continue
            try:
                run_probe(arch, shape_name, args.out)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape_name, str(e)))
    print(f"[costprobe] done, {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
