"""RG-LRU recurrent block (Griffin / RecurrentGemma, De et al. 2024).

Recurrence (diagonal, per channel):
    r_t = sigmoid(W_a x_t)                       (recurrence gate)
    i_t = sigmoid(W_x x_t)                       (input gate)
    log a_t = -c * softplus(Λ) * r_t             (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training evaluates the whole sequence with ``jax.lax.associative_scan`` in
f32 (O(T log T) work, fully parallel, shardable over batch and channels);
decode is the trivial one-step recurrence with state (B, R).

Block structure (Griffin recurrent block): two input projections — a GeLU
gate branch and a conv1d(4) → RG-LRU branch — multiplied and projected out.
Gate projections are block-diagonal (``RGLRU_BLOCKS`` blocks), following the
reference implementation; we use 16 blocks so the block dim shards cleanly
over a 16-way `model` axis (adaptation note in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.conv import (causal_conv1d, causal_conv1d_step,
                               conv_decode_init, conv_specs)
from repro.models.params import ParamSpec

RGLRU_BLOCKS = 16
RGLRU_C = 8.0


def _rnn_width(cfg: ArchConfig) -> int:
    return cfg.d_rnn or cfg.d_model


def rglru_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, r = cfg.d_model, _rnn_width(cfg)
    nb = RGLRU_BLOCKS
    rb = r // nb
    return {
        "w_in": ParamSpec((d, r), ("embed", "rnn")),
        "w_gate_branch": ParamSpec((d, r), ("embed", "rnn")),
        "conv": conv_specs(r, cfg.conv_width, "rnn"),
        "w_a": ParamSpec((nb, rb, rb), ("rnn_blocks", None, None)),
        "b_a": ParamSpec((nb, rb), ("rnn_blocks", None), init="zeros"),
        "w_x": ParamSpec((nb, rb, rb), ("rnn_blocks", None, None)),
        "b_x": ParamSpec((nb, rb), ("rnn_blocks", None), init="zeros"),
        "lam": ParamSpec((r,), ("rnn",), init="rglru_lambda"),
        "w_out": ParamSpec((r, d), ("rnn", "embed")),
    }


def _gates(p, xc: jax.Array, r: int) -> Tuple[jax.Array, jax.Array]:
    """Block-diagonal gate projections.  xc: (B, T, R) -> (r_t, i_t) f32."""
    B, T, _ = xc.shape
    nb = RGLRU_BLOCKS
    xb = xc.reshape(B, T, nb, r // nb)
    ra = jnp.einsum("btni,nij->btnj", xb, p["w_a"].astype(xc.dtype))
    ra = ra + p["b_a"].astype(xc.dtype)
    ri = jnp.einsum("btni,nij->btnj", xb, p["w_x"].astype(xc.dtype))
    ri = ri + p["b_x"].astype(xc.dtype)
    rec_gate = jax.nn.sigmoid(ra.reshape(B, T, r).astype(jnp.float32))
    in_gate = jax.nn.sigmoid(ri.reshape(B, T, r).astype(jnp.float32))
    return rec_gate, in_gate


def _coeffs(p, xc: jax.Array, r: int):
    """Returns (log_a, gated_input) both f32, shape (B, T, R)."""
    rec_gate, in_gate = _gates(p, xc, r)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rec_gate
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    gated = scale * in_gate * xc.astype(jnp.float32)
    return a, gated


def rglru_scan(p, xc: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence linear recurrence via associative scan (training)."""
    r = _rnn_width(cfg)
    a, b = _coeffs(p, xc, r)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xc.dtype)


def apply_rglru(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = x.dtype
    r = _rnn_width(cfg)
    branch = jnp.einsum("btd,dr->btr", x, p["w_in"].astype(dt))
    branch = shard(branch, ("act_batch", None, "act_rnn"))
    gate = jax.nn.gelu(jnp.einsum("btd,dr->btr", x,
                                  p["w_gate_branch"].astype(dt)))
    xc = causal_conv1d(p["conv"], branch)
    h = rglru_scan(p, xc, cfg)
    y = h * gate
    out = jnp.einsum("btr,rd->btd", y, p["w_out"].astype(dt))
    return shard(out, ("act_batch", "act_seq", "act_embed"))


def rglru_decode_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    r = _rnn_width(cfg)
    return {"h": jnp.zeros((batch, r), dtype),
            "conv": conv_decode_init(batch, r, cfg.conv_width, dtype=dtype)}


def apply_rglru_decode(p, x: jax.Array, cfg: ArchConfig, state: Dict
                       ) -> Tuple[jax.Array, Dict]:
    dt = x.dtype
    r = _rnn_width(cfg)
    branch = jnp.einsum("btd,dr->btr", x, p["w_in"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("btd,dr->btr", x,
                                  p["w_gate_branch"].astype(dt)))
    xc, conv_state = causal_conv1d_step(p["conv"], branch, state["conv"])
    a, b = _coeffs(p, xc, r)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    y = h[:, None, :].astype(dt) * gate
    out = jnp.einsum("btr,rd->btd", y, p["w_out"].astype(dt))
    return out, {"h": h, "conv": conv_state}
