"""Mixture-of-Experts layer (GShard-style grouped capacity-factor dispatch).

Design (DESIGN.md §4): tokens are split into *groups* (GShard's trick) so the
dispatch one-hot is (B, G, Sg, E, Cg) with total size B·T·k·cf·Sg — linear in
group size instead of quadratic in sequence length.  Every einsum keeps the
batch dim, so tokens stay sharded over ``(pod, data)`` and experts over
``model``; the cross-device traffic XLA inserts is the standard combine
all-reduce over `model` (same shape as a dense-TP FFN), visible in the
dry-run HLO.

Supports:
  * top-k routing, softmax-renormalized over the chosen experts,
  * per-group capacity-factor token dropping,
  * shared experts (DeepSeekMoE: always-on experts added to routed output),
  * the switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamSpec

MOE_GROUP_SIZE = 512   # tokens per dispatch group (GShard "groups")


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    specs: Dict[str, ParamSpec] = {
        "w_router": ParamSpec((d, e), ("embed", "expert")),
        "w_gate": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "w_down": ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts > 0:
        fs = f * cfg.n_shared_experts
        specs["shared_gate"] = ParamSpec((d, fs), ("embed", "mlp"))
        specs["shared_up"] = ParamSpec((d, fs), ("embed", "mlp"))
        specs["shared_down"] = ParamSpec((fs, d), ("mlp", "embed"))
    return specs


def group_capacity(cfg: ArchConfig, group_len: int) -> int:
    cap = int(group_len * cfg.experts_per_token * cfg.capacity_factor
              / cfg.n_experts)
    return max(cap, cfg.experts_per_token)


def apply_moe(p, x: jax.Array, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out, aux_loss)."""
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    Sg = min(MOE_GROUP_SIZE, T)
    assert T % Sg == 0, f"seq {T} not divisible by MoE group {Sg}"
    G = T // Sg
    C = group_capacity(cfg, Sg)
    dt = x.dtype

    xg = x.reshape(B, G, Sg, D)
    router_logits = jnp.einsum("bgsd,de->bgse", xg, p["w_router"].astype(dt))
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (B,G,Sg,K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=(0, 1, 2))                    # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), axis=(0, 1, 2))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # Capacity positions: tokens in order, k-choices in order, per group.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # (B,G,Sg,K,E)
    flat = onehot.reshape(B, G, Sg * K, E)
    pos_flat = jnp.cumsum(flat, axis=2) - flat
    pos = pos_flat.reshape(B, G, Sg, K, E)
    within = pos < C
    keep = onehot * within                                    # dropped -> 0
    # Compact over the k axis (an expert is picked at most once per token).
    keep_te = jnp.sum(keep, axis=3)                           # (B,G,Sg,E)
    pos_te = jnp.sum(pos * keep, axis=3).astype(jnp.int32)
    gate_te = jnp.sum(gate_vals[..., None] * keep, axis=3)    # (B,G,Sg,E)
    slot = jax.nn.one_hot(pos_te, C, dtype=jnp.float32)       # (B,G,Sg,E,C)
    dispatch = (keep_te[..., None] * slot).astype(dt)
    combine = (gate_te[..., None] * slot).astype(dt)
    dispatch = shard(dispatch, ("act_batch", None, None, "act_expert", None))
    combine = shard(combine, ("act_batch", None, None, "act_expert", None))

    # Dispatch -> per-expert FFN -> combine (batch dim kept throughout).
    xe = jnp.einsum("bgsec,bgsd->bgecd", dispatch, xg)        # (B,G,E,C,D)
    xe = shard(xe, ("act_batch", None, "act_expert", None, None))
    g = jnp.einsum("bgecd,edf->bgecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("bgecd,edf->bgecf", xe, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("bgecf,efd->bgecd", h, p["w_down"].astype(dt))
    ye = shard(ye, ("act_batch", None, "act_expert", None, None))
    out = jnp.einsum("bgsec,bgecd->bgsd", combine, ye).reshape(B, T, D)

    if cfg.n_shared_experts > 0:
        gs = jnp.einsum("btd,df->btf", x, p["shared_gate"].astype(dt))
        us = jnp.einsum("btd,df->btf", x, p["shared_up"].astype(dt))
        hs = shard(jax.nn.silu(gs) * us, ("act_batch", None, "act_mlp"))
        out = out + jnp.einsum("btf,fd->btd", hs, p["shared_down"].astype(dt))

    return (shard(out, ("act_batch", "act_seq", "act_embed")),
            aux.astype(jnp.float32))
