"""Parameter specs: shapes + logical sharding axes + initializers.

Each layer module declares ``specs(cfg) -> {name: ParamSpec}``; the model
assembles a nested spec tree from which we derive
  * initialized parameters (`init_params`),
  * `jax.ShapeDtypeStruct`s for allocation-free dry-run lowering
    (`param_shapes`),
  * the logical-axes tree consumed by `repro.distributed.sharding`
    (`param_axes`).

Stacked (scanned) segments prepend a `"layer"` axis to every spec.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | rglru_lambda
    scale: float = 1.0            # stddev multiplier for "normal"
    dtype: Optional[str] = None   # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def stacked(self, n: int) -> "ParamSpec":
        return dataclasses.replace(self, shape=(n,) + self.shape,
                                   axes=("layer",) + self.axes)


def _fan_in(shape: Tuple[int, ...]) -> int:
    return shape[0] if len(shape) <= 1 else int(np.prod(shape[:-1]))


def _init_leaf(key, spec: ParamSpec, default_dtype: str) -> jax.Array:
    dtype = jnp.dtype(spec.dtype or default_dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "rglru_lambda":
        # Griffin Λ init: a = exp(-c·softplus(Λ)) uniform in [0.9, 0.999].
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               0.9 ** 2, 0.999 ** 2)
        lam = jnp.log(jnp.expm1(-0.5 * jnp.log(u) / 8.0))
        return lam.astype(dtype)
    std = spec.scale / math.sqrt(max(_fan_in(spec.shape), 1))
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(rng: jax.Array, specs, param_dtype: str = "float32"):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    inited = [_init_leaf(k, s, param_dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, inited)


def param_shapes(specs, param_dtype: str = "float32"):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype or param_dtype)),
        specs, is_leaf=is_spec)


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def stack_specs(specs, n: int):
    """Prepend the scan ('layer') axis to every spec in a subtree."""
    return jax.tree.map(lambda s: s.stacked(n), specs, is_leaf=is_spec)
