"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan).

mLSTM recurrence (per head, stabilized in log space):
    C_t = f_t C_{t-1} + i_t  v_t k_t^T          (matrix memory, dk × dv)
    n_t = f_t n_{t-1} + i_t  k_t                 (normalizer)
    h_t = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))
with exponential input gate i_t = exp(ĩ_t), forget gate f_t = σ(f̃_t), and
running stabilizer m_t.  Training uses the chunkwise-parallel form: an outer
``lax.scan`` carries (C, n, m) across chunks; within a chunk everything is a
masked attention-like einsum with cumulative log-gates.  Decode is the plain
one-step recurrence.

Block structure (mLSTM): x → norm → up-proj (×proj_factor) with a SiLU gate
branch; causal conv1d(4) feeds q/k; cell output is gated and down-projected.
d_ff = 0 in the assigned config: there is no separate FFN block.

sLSTM keeps per-channel scalar memories with block-diagonal recurrent weights
(one block per head) and is evaluated with a sequential scan (no parallel
form exists — the recurrence is on h_{t-1}).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.conv import (causal_conv1d, causal_conv1d_step,
                               conv_decode_init, conv_specs)
from repro.models.params import ParamSpec

MLSTM_CHUNK = 64


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #

def _dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_up = int(cfg.d_model * cfg.proj_factor)
    heads = cfg.num_heads
    dh = d_up // heads
    return d_up, heads, dh


def mlstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_up, H, dh = _dims(cfg)
    return {
        "w_up": ParamSpec((d, d_up), ("embed", "rnn")),
        "w_gate": ParamSpec((d, d_up), ("embed", "rnn")),
        "conv": conv_specs(d_up, cfg.conv_width, "rnn"),
        "w_q": ParamSpec((d_up, H, dh), ("rnn", "heads", None)),
        "w_k": ParamSpec((d_up, H, dh), ("rnn", "heads", None)),
        "w_v": ParamSpec((d_up, H, dh), ("rnn", "heads", None)),
        "w_i": ParamSpec((d_up, H), ("rnn", "heads"), scale=0.1),
        "w_f": ParamSpec((d_up, H), ("rnn", "heads"), scale=0.1),
        "b_i": ParamSpec((H,), (None,), init="zeros"),
        # forget-gate bias init positive => long memory at init
        "b_f": ParamSpec((H,), (None,), init="ones", scale=3.0),
        "out_norm": {"scale": ParamSpec((d_up,), (None,), init="ones")},
        "w_down": ParamSpec((d_up, d), ("rnn", "embed")),
    }


def _mlstm_chunkwise(q, k, v, i_raw, f_raw, state=None, chunk=MLSTM_CHUNK):
    """q,k,v: (B,H,T,dh); i_raw,f_raw: (B,H,T).  Returns (h, state).

    state = (C: (B,H,dk,dv), n: (B,H,dk), m: (B,H)).
    """
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    NC = T // L
    f32 = jnp.float32

    qc = q.reshape(B, H, NC, L, dk).astype(f32)
    kc = k.reshape(B, H, NC, L, dk).astype(f32)
    vc = v.reshape(B, H, NC, L, dv).astype(f32)
    ic = i_raw.reshape(B, H, NC, L).astype(f32)
    flog = jax.nn.log_sigmoid(f_raw.astype(f32)).reshape(B, H, NC, L)
    b = jnp.cumsum(flog, axis=-1)              # within-chunk decay prefix
    g = b[..., -1]                             # total chunk decay (B,H,NC)

    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), f32)
        n0 = jnp.zeros((B, H, dk), f32)
        m0 = jnp.full((B, H), -jnp.inf, f32)
    else:
        C0, n0, m0 = (s.astype(f32) for s in state)

    idx = jnp.arange(L)
    tri = idx[:, None] >= idx[None, :]         # j <= i

    def step(carry, xs):
        C, n, m = carry
        qi, ki, vi, ii, bi, gi = xs            # (B,H,L,*) and (B,H)
        # log weights: inter (state) and intra (pairwise)
        log_a = bi + m[..., None]                              # (B,H,L)
        D = bi[..., :, None] - bi[..., None, :] + ii[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)                        # (B,H,L,L)
        m_intra = jnp.max(D, axis=-1)                          # (B,H,L)
        m_i = jnp.maximum(log_a, m_intra)
        m_i = jnp.maximum(m_i, -1e30)                          # avoid -inf-(-inf)
        inter_w = jnp.exp(log_a - m_i)                         # (B,H,L)
        Sij = jnp.exp(D - m_i[..., None])                      # (B,H,L,L)
        qk = jnp.einsum("bhid,bhjd->bhij", qi, ki)             # (B,H,L,L)
        num = (inter_w[..., None] * jnp.einsum("bhid,bhdv->bhiv", qi, C)
               + jnp.einsum("bhij,bhij,bhjv->bhiv", Sij, qk, vi))
        den = (inter_w * jnp.einsum("bhid,bhd->bhi", qi, n)
               + jnp.einsum("bhij,bhij->bhi", Sij, qk))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update (stabilized)
        w_j = gi[..., None] - bi + ii                          # (B,H,L)
        m_new = jnp.maximum(gi + m, jnp.max(w_j, axis=-1))
        m_new = jnp.maximum(m_new, -1e30)
        scale_old = jnp.exp(gi + m - m_new)
        wj = jnp.exp(w_j - m_new[..., None])
        C_new = (scale_old[..., None, None] * C
                 + jnp.einsum("bhj,bhjd,bhjv->bhdv", wj, ki, vi))
        n_new = scale_old[..., None] * n + jnp.einsum("bhj,bhjd->bhd", wj, ki)
        return (C_new, n_new, m_new), h

    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0),
          jnp.moveaxis(vc, 2, 0), jnp.moveaxis(ic, 2, 0),
          jnp.moveaxis(b, 2, 0), jnp.moveaxis(g, 2, 0))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, T, dv)
    return h, (C, n, m)


def mlstm_decode_step(q, k, v, i_raw, f_raw, state):
    """One-token recurrence.  q,k,v: (B,H,1,dh); gates (B,H,1)."""
    C, n, m = state
    f32 = jnp.float32
    q1, k1, v1 = (t[:, :, 0].astype(f32) for t in (q, k, v))
    ii = i_raw[:, :, 0].astype(f32)
    ff = jax.nn.log_sigmoid(f_raw[:, :, 0].astype(f32))
    m_new = jnp.maximum(ff + m, ii)
    f_st = jnp.exp(ff + m - m_new)
    i_st = jnp.exp(ii - m_new)
    C_new = f_st[..., None, None] * C + i_st[..., None, None] * \
        jnp.einsum("bhd,bhv->bhdv", k1, v1)
    n_new = f_st[..., None] * n + i_st[..., None] * k1
    num = jnp.einsum("bhd,bhdv->bhv", q1, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n_new))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return h[:, :, None, :], (C_new, n_new, m_new)


def _mlstm_qkv(p, x: jax.Array, cfg: ArchConfig, conv_state=None):
    """Shared pre-cell computation. Returns (q,k,v,i,f,gate,new_conv_state)."""
    dt = x.dtype
    d_up, H, dh = _dims(cfg)
    up = jnp.einsum("btd,du->btu", x, p["w_up"].astype(dt))
    up = shard(up, ("act_batch", None, "act_rnn"))
    gate = jax.nn.silu(jnp.einsum("btd,du->btu", x, p["w_gate"].astype(dt)))
    if conv_state is None:
        c = causal_conv1d(p["conv"], up)
        new_conv_state = None
    else:
        c, new_conv_state = causal_conv1d_step(p["conv"], up, conv_state)
    c = jax.nn.silu(c)
    q = jnp.einsum("btu,uhk->bhtk", c, p["w_q"].astype(dt))
    k = jnp.einsum("btu,uhk->bhtk", c, p["w_k"].astype(dt)) * (dh ** -0.5)
    v = jnp.einsum("btu,uhk->bhtk", up, p["w_v"].astype(dt))
    i_raw = jnp.einsum("btu,uh->bht", c, p["w_i"].astype(dt)) + \
        p["b_i"].astype(dt)[None, :, None]
    f_raw = jnp.einsum("btu,uh->bht", c, p["w_f"].astype(dt)) + \
        3.0 * p["b_f"].astype(dt)[None, :, None]
    return q, k, v, i_raw, f_raw, gate, up, new_conv_state


def _mlstm_out(p, h, gate, cfg: ArchConfig, dtype):
    """Head-merge + per-head norm + gating + down-projection."""
    B, H, T, dh = h.shape
    hm = jnp.moveaxis(h, 1, 2).reshape(B, T, H * dh)
    # simple RMS norm over the up dim (xLSTM uses multi-head layernorm)
    ms = jnp.mean(jnp.square(hm), axis=-1, keepdims=True)
    hm = hm * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]["scale"].astype(jnp.float32)
    hm = hm.astype(dtype) * gate
    out = jnp.einsum("btu,ud->btd", hm, p["w_down"].astype(dtype))
    return shard(out, ("act_batch", "act_seq", "act_embed"))


def apply_mlstm(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    q, k, v, i_raw, f_raw, gate, _, _ = _mlstm_qkv(p, x, cfg)
    h, _ = _mlstm_chunkwise(q, k, v, i_raw, f_raw)
    return _mlstm_out(p, h.astype(x.dtype), gate, cfg, x.dtype)


def mlstm_decode_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_up, H, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
        "conv": conv_decode_init(batch, d_up, cfg.conv_width, dtype=dtype),
    }


def apply_mlstm_decode(p, x: jax.Array, cfg: ArchConfig, state: Dict
                       ) -> Tuple[jax.Array, Dict]:
    q, k, v, i_raw, f_raw, gate, _, conv_state = _mlstm_qkv(
        p, x, cfg, conv_state=state["conv"])
    h, (C, n, m) = mlstm_decode_step(q, k, v, i_raw, f_raw,
                                     (state["C"], state["n"], state["m"]))
    out = _mlstm_out(p, h.astype(x.dtype), gate, cfg, x.dtype)
    return out, {"C": C, "n": n, "m": m, "conv": conv_state}


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #

def slstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    return {
        "w_x": ParamSpec((d, 4, d), ("embed", None, "rnn")),     # i,f,z,o
        "r_h": ParamSpec((H, dh, 4, dh), (None, None, None, None), scale=0.5),
        "bias": ParamSpec((4, d), (None, None), init="zeros"),
        "w_out": ParamSpec((d, d), ("rnn", "embed")),
    }


def _slstm_cell(gates, state):
    """gates: (B, 4, D) raw; state: dict(c,n,m,h) each (B, D) f32."""
    c, n, m, h = state
    i_raw, f_raw, z_raw, o_raw = (gates[:, j].astype(jnp.float32)
                                  for j in range(4))
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_raw) + m, i_raw)
    i_st = jnp.exp(i_raw - m_new)
    f_st = jnp.exp(jax.nn.log_sigmoid(f_raw) + m - m_new)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    c_new = f_st * c + i_st * z
    n_new = f_st * n + i_st
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def _slstm_gates(p, xt, h_prev, cfg: ArchConfig):
    """xt: (B, D); h_prev: (B, D) -> raw gates (B, 4, D)."""
    B, D = xt.shape
    H = cfg.num_heads
    dh = D // H
    gx = jnp.einsum("bd,dgk->bgk", xt, p["w_x"].astype(xt.dtype))
    hh = h_prev.reshape(B, H, dh).astype(xt.dtype)
    gh = jnp.einsum("bhk,hkgj->bghj", hh, p["r_h"].astype(xt.dtype))
    gh = gh.reshape(B, 4, D)
    return gx + gh + p["bias"].astype(xt.dtype)


SLSTM_TIME_CHUNK = 256


def apply_slstm(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Sequential scan over time, chunk-rematerialized: the backward pass
    recomputes within 256-step chunks instead of saving all T per-step
    states (which dominates HBM at train_4k batch sizes)."""
    B, T, D = x.shape
    f32 = jnp.float32
    state0 = (jnp.zeros((B, D), f32), jnp.zeros((B, D), f32),
              jnp.full((B, D), -1e30, f32), jnp.zeros((B, D), f32))

    def step(state, xt):
        gates = _slstm_gates(p, xt, state[3], cfg)
        new = _slstm_cell(gates, state)
        return new, new[3]

    chunk = SLSTM_TIME_CHUNK if T % SLSTM_TIME_CHUNK == 0 else T

    @jax.checkpoint
    def chunk_scan(state, xs_chunk):
        return jax.lax.scan(step, state, xs_chunk)

    xs = jnp.moveaxis(x, 1, 0).reshape(T // chunk, chunk, B, D)
    _, hs = jax.lax.scan(chunk_scan, state0, xs)
    h = jnp.moveaxis(hs.reshape(T, B, D), 0, 1).astype(x.dtype)
    out = jnp.einsum("btd,de->bte", h, p["w_out"].astype(x.dtype))
    return shard(out, ("act_batch", "act_seq", "act_embed"))


def slstm_decode_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    D = cfg.d_model
    return {"c": jnp.zeros((batch, D), dtype), "n": jnp.zeros((batch, D), dtype),
            "m": jnp.full((batch, D), -1e30, dtype),
            "h": jnp.zeros((batch, D), dtype)}


def apply_slstm_decode(p, x: jax.Array, cfg: ArchConfig, state: Dict
                       ) -> Tuple[jax.Array, Dict]:
    xt = x[:, 0]
    gates = _slstm_gates(p, xt, state["h"].astype(x.dtype), cfg)
    c, n, m, h = _slstm_cell(gates, (state["c"], state["n"], state["m"],
                                     state["h"]))
    out = jnp.einsum("bd,de->be", h.astype(x.dtype),
                     p["w_out"].astype(x.dtype))[:, None, :]
    return out, {"c": c, "n": n, "m": m, "h": h}
