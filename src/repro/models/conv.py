"""Depthwise causal 1-D convolution (shared by mLSTM and RG-LRU blocks).

Implemented as a sum of shifted inputs (width is tiny, typically 4), which
lowers to cheap adds/muls, shards trivially over batch/features, and has an
O(1) decode state (the last ``width-1`` inputs).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def conv_specs(channels: int, width: int, axis_name: str = "rnn"
               ) -> Dict[str, ParamSpec]:
    return {
        "w": ParamSpec((width, channels), ("conv", axis_name), scale=1.0),
        "b": ParamSpec((channels,), (axis_name,), init="zeros"),
    }


def causal_conv1d(p, x: jax.Array) -> jax.Array:
    """x: (B, T, C) -> (B, T, C); left-padded causal depthwise conv."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    out = x * w[width - 1]
    for j in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :x.shape[1], :]
        out = out + shifted * w[width - 1 - j]
    return out + p["b"].astype(x.dtype)


def conv_decode_init(batch: int, channels: int, width: int,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Decode state: the last width-1 inputs, shape (B, width-1, C)."""
    return jnp.zeros((batch, width - 1, channels), dtype)


def causal_conv1d_step(p, x: jax.Array, state: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  x: (B, 1, C); state: (B, width-1, C)."""
    w = p["w"].astype(x.dtype)
    window = jnp.concatenate([state, x], axis=1)          # (B, width, C)
    out = jnp.einsum("bwc,wc->bc", window, w)[:, None, :] + p["b"].astype(x.dtype)
    return out, window[:, 1:, :]
