"""Unified model assembly: all 10 assigned architectures build from here.

A model is: token embedding (+ modality-stub inputs), a list of *segments*
(scanned homogeneous superblocks, see `configs.base`), final norm, LM head.
Three execution modes share one block implementation:

  * ``forward_train``  — full-sequence teacher forcing; returns (logits, aux).
  * ``prefill``        — full sequence + per-layer decode state extraction.
  * ``decode_step``    — one new token against the decode state.

Whisper adds an encoder tower; InternVL2 prepends stubbed patch embeddings.
Scanned segments use ``jax.lax.scan`` over stacked params (compile time and
HBM friendly); training wraps the scan body in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockSpec, Segment
from repro.distributed.sharding import shard
from repro.models import layers, moe, rglru, xlstm
from repro.models.params import ParamSpec, stack_specs

VOCAB_PAD_MULTIPLE = 512   # Megatron-style padding so `vocab` shards cleanly


def padded_vocab(cfg: ArchConfig) -> int:
    v, m = cfg.vocab_size, VOCAB_PAD_MULTIPLE
    return (v + m - 1) // m * m


# --------------------------------------------------------------------------- #
# specs
# --------------------------------------------------------------------------- #

def _block_specs(blk: BlockSpec, cfg: ArchConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {"norm1": layers.norm_specs(cfg)}
    if blk.mixer in ("attn", "local_attn"):
        specs["mixer"] = layers.attn_specs(cfg)
    elif blk.mixer == "mlstm":
        specs["mixer"] = xlstm.mlstm_specs(cfg)
    elif blk.mixer == "slstm":
        specs["mixer"] = xlstm.slstm_specs(cfg)
    elif blk.mixer == "rglru":
        specs["mixer"] = rglru.rglru_specs(cfg)
    else:
        raise ValueError(blk.mixer)
    if blk.cross_attn:
        specs["norm_cross"] = layers.norm_specs(cfg)
        specs["cross"] = layers.cross_attn_specs(cfg)
    if blk.mlp == "dense":
        ff = None
        if cfg.n_experts > 0 and cfg.dense_d_ff:
            ff = cfg.dense_d_ff
        if not cfg.parallel_block:
            specs["norm2"] = layers.norm_specs(cfg)
        specs["mlp"] = layers.mlp_specs(cfg, ff)
    elif blk.mlp == "moe":
        specs["norm2"] = layers.norm_specs(cfg)
        specs["mlp"] = moe.moe_specs(cfg)
    return specs


def _tower_specs(plan: List[Segment], cfg: ArchConfig) -> List[Dict]:
    out = []
    for seg in plan:
        seg_specs = {f"block{j}": _block_specs(blk, cfg)
                     for j, blk in enumerate(seg.blocks)}
        if seg.repeats > 1:
            seg_specs = stack_specs(seg_specs, seg.repeats)
        out.append(seg_specs)
    return out


def model_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, vp = cfg.d_model, padded_vocab(cfg)
    specs: Dict[str, Any] = {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), scale=1.0),
        "final_norm": layers.norm_specs(cfg),
        "segments": _tower_specs(cfg.layer_plan(), cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, vp), ("embed", "vocab"))
    if cfg.is_encoder_decoder:
        specs["encoder"] = {
            "segments": _tower_specs(cfg.encoder_plan(), cfg),
            "final_norm": layers.norm_specs(cfg),
        }
    return specs


# --------------------------------------------------------------------------- #
# block application (train / prefill / decode share this)
# --------------------------------------------------------------------------- #

def _apply_mixer(blk: BlockSpec, p, h, cfg, positions, causal):
    if blk.mixer == "attn":
        return layers.attention(p["mixer"], h, cfg, positions=positions,
                                causal=causal, use_rope=cfg.use_rope)
    if blk.mixer == "local_attn":
        return layers.attention(p["mixer"], h, cfg, positions=positions,
                                causal=causal, window=cfg.sliding_window,
                                use_rope=cfg.use_rope)
    if blk.mixer == "mlstm":
        return xlstm.apply_mlstm(p["mixer"], h, cfg)
    if blk.mixer == "slstm":
        return xlstm.apply_slstm(p["mixer"], h, cfg)
    if blk.mixer == "rglru":
        return rglru.apply_rglru(p["mixer"], h, cfg)
    raise ValueError(blk.mixer)


def apply_block(blk: BlockSpec, p, x, cfg: ArchConfig, *, positions,
                causal: bool = True, enc_out=None):
    """Training/encoder forward. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(p["norm1"], x, cfg)
    mix = _apply_mixer(blk, p, h, cfg, positions, causal)
    if cfg.parallel_block and blk.mlp == "dense":
        x = x + mix + layers.apply_mlp(p["mlp"], h, cfg)
        return x, aux
    x = x + mix
    if blk.cross_attn:
        assert enc_out is not None
        hc = layers.apply_norm(p["norm_cross"], x, cfg)
        kv = layers.encode_cross_kv(p["cross"], enc_out, cfg)
        x = x + layers.cross_attention(p["cross"], hc, cfg, kv)
    if blk.mlp == "dense":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        x = x + layers.apply_mlp(p["mlp"], h2, cfg)
    elif blk.mlp == "moe":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        y, aux_moe = moe.apply_moe(p["mlp"], h2, cfg)
        x = x + y
        aux = aux + aux_moe
    return x, aux


# ----------------------------- decode state ---------------------------------- #

def init_block_state(blk: BlockSpec, cfg: ArchConfig, batch: int,
                     cache_len: int, dtype=jnp.bfloat16,
                     per_example_pos: bool = True) -> Dict:
    if blk.mixer == "attn":
        st = layers.init_kv_cache(cfg, batch, cache_len, dtype=dtype,
                                  per_example_pos=per_example_pos)
    elif blk.mixer == "local_attn":
        st = layers.init_kv_cache(cfg, batch, cache_len,
                                  window=cfg.sliding_window, dtype=dtype,
                                  per_example_pos=per_example_pos)
    elif blk.mixer == "mlstm":
        st = xlstm.mlstm_decode_init(cfg, batch)
    elif blk.mixer == "slstm":
        st = xlstm.slstm_decode_init(cfg, batch)
    elif blk.mixer == "rglru":
        st = rglru.rglru_decode_init(cfg, batch)
    else:
        raise ValueError(blk.mixer)
    if blk.cross_attn:
        hd = cfg.head_dim_
        st = dict(st)
        st["cross_k"] = jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads,
                                   hd), dtype)
        st["cross_v"] = jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads,
                                   hd), dtype)
    return st


def block_state_axes(blk: BlockSpec, cfg: ArchConfig) -> Dict:
    """Logical axes for decode state (dry-run in_shardings)."""
    if blk.mixer in ("attn", "local_attn"):
        ax = layers.cache_axes(cfg.kv_quant)
    elif blk.mixer == "mlstm":
        ax = {"C": ("act_batch", "act_heads", None, None),
              "n": ("act_batch", "act_heads", None),
              "m": ("act_batch", "act_heads"),
              "conv": ("act_batch", None, "act_rnn")}
    elif blk.mixer == "slstm":
        ax = {k: ("act_batch", "act_rnn") for k in ("c", "n", "m", "h")}
    elif blk.mixer == "rglru":
        ax = {"h": ("act_batch", "act_rnn"),
              "conv": ("act_batch", None, "act_rnn")}
    else:
        raise ValueError(blk.mixer)
    if blk.cross_attn:
        ax = dict(ax)
        ax["cross_k"] = ("act_batch", None, "act_kv_heads", None)
        ax["cross_v"] = ("act_batch", None, "act_kv_heads", None)
    return ax


def apply_block_decode(blk: BlockSpec, p, x, cfg: ArchConfig, state: Dict
                       ) -> Tuple[jax.Array, Dict]:
    h = layers.apply_norm(p["norm1"], x, cfg)
    cross = {k: state[k] for k in ("cross_k", "cross_v") if k in state}
    core = {k: v for k, v in state.items() if k not in cross}
    if blk.mixer == "attn":
        mix, core = layers.decode_attention(p["mixer"], h, cfg, core,
                                            use_rope=cfg.use_rope)
    elif blk.mixer == "local_attn":
        mix, core = layers.decode_attention(p["mixer"], h, cfg, core,
                                            window=cfg.sliding_window,
                                            use_rope=cfg.use_rope)
    elif blk.mixer == "mlstm":
        mix, core = xlstm.apply_mlstm_decode(p["mixer"], h, cfg, core)
    elif blk.mixer == "slstm":
        mix, core = xlstm.apply_slstm_decode(p["mixer"], h, cfg, core)
    elif blk.mixer == "rglru":
        mix, core = rglru.apply_rglru_decode(p["mixer"], h, cfg, core)
    else:
        raise ValueError(blk.mixer)
    if cfg.parallel_block and blk.mlp == "dense":
        x = x + mix + layers.apply_mlp(p["mlp"], h, cfg)
        return x, {**core, **cross}
    x = x + mix
    if blk.cross_attn:
        hc = layers.apply_norm(p["norm_cross"], x, cfg)
        x = x + layers.cross_attention(p["cross"], hc, cfg,
                                       (cross["cross_k"], cross["cross_v"]))
    if blk.mlp == "dense":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        x = x + layers.apply_mlp(p["mlp"], h2, cfg)
    elif blk.mlp == "moe":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        y, _ = moe.apply_moe(p["mlp"], h2, cfg)
        x = x + y
    return x, {**core, **cross}


def apply_block_prefill(blk: BlockSpec, p, x, cfg: ArchConfig, *, positions,
                        cache_len: int, enc_out=None
                        ) -> Tuple[jax.Array, Dict]:
    """Forward + decode-state extraction (serving prefill)."""
    B, S, _ = x.shape
    dt = x.dtype
    h = layers.apply_norm(p["norm1"], x, cfg)
    state: Dict = {}
    if blk.mixer in ("attn", "local_attn"):
        window = cfg.sliding_window if blk.mixer == "local_attn" else 0
        # recompute k/v (roped) to fill the cache buffer
        q, k, v = layers._project_qkv(p["mixer"], h, cfg, positions,
                                      cfg.use_rope)
        k = k.swapaxes(1, 2)          # -> (B, Kv, S, hd) cache layout
        v = v.swapaxes(1, 2)
        cache = layers.init_kv_cache(cfg, B, cache_len, window=window,
                                     dtype=dt)
        if cfg.kv_quant:
            k, ks_ = layers.quantize_kv(k)
            v, vs_ = layers.quantize_kv(v)
        W = cache["k"].shape[2]
        if window > 0 and S > W:
            ks, vs = k[:, :, S - W:], v[:, :, S - W:]
            slot0 = (S - W) % W
            # ring write: split at the wrap point
            first = W - slot0
            cache["k"] = cache["k"].at[:, :, slot0:].set(ks[:, :, :first]) \
                                    .at[:, :, :W - first].set(ks[:, :, first:])
            cache["v"] = cache["v"].at[:, :, slot0:].set(vs[:, :, :first]) \
                                    .at[:, :, :W - first].set(vs[:, :, first:])
        else:
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            if cfg.kv_quant:
                cache["k_scale"] = jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks_, (0, 0, 0))
                cache["v_scale"] = jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs_, (0, 0, 0))
        cache["pos"] = jnp.full((B,), S, jnp.int32)
        state = cache
        mix = layers.attention(p["mixer"], h, cfg, positions=positions,
                               causal=True, window=window,
                               use_rope=cfg.use_rope)
    elif blk.mixer == "mlstm":
        qq, kk, vv, i_raw, f_raw, gate, _, _ = xlstm._mlstm_qkv(
            p["mixer"], h, cfg)
        hh, (C, n, m) = xlstm._mlstm_chunkwise(qq, kk, vv, i_raw, f_raw)
        mix = xlstm._mlstm_out(p["mixer"], hh.astype(dt), gate, cfg, dt)
        d_up = int(cfg.d_model * cfg.proj_factor)
        up = jnp.einsum("btd,du->btu", h, p["mixer"]["w_up"].astype(dt))
        conv_tail = up[:, -(cfg.conv_width - 1):, :]
        state = {"C": C, "n": n, "m": m, "conv": conv_tail}
    elif blk.mixer == "slstm":
        # sequential anyway: run the scan and keep the final state
        mix = xlstm.apply_slstm(p["mixer"], h, cfg)
        state = _slstm_final_state(p["mixer"], h, cfg)
    elif blk.mixer == "rglru":
        mix, state = _rglru_prefill(p["mixer"], h, cfg)
    else:
        raise ValueError(blk.mixer)

    if cfg.parallel_block and blk.mlp == "dense":
        x = x + mix + layers.apply_mlp(p["mlp"], h, cfg)
        return x, state
    x = x + mix
    if blk.cross_attn:
        assert enc_out is not None
        hc = layers.apply_norm(p["norm_cross"], x, cfg)
        ck, cv = layers.encode_cross_kv(p["cross"], enc_out, cfg)
        x = x + layers.cross_attention(p["cross"], hc, cfg, (ck, cv))
        state = dict(state)
        state["cross_k"], state["cross_v"] = ck.astype(dt), cv.astype(dt)
    if blk.mlp == "dense":
        x = x + layers.apply_mlp(p["mlp"],
                                 layers.apply_norm(p["norm2"], x, cfg), cfg)
    elif blk.mlp == "moe":
        y, _ = moe.apply_moe(p["mlp"],
                             layers.apply_norm(p["norm2"], x, cfg), cfg)
        x = x + y
    return x, state


def _slstm_final_state(p, h, cfg):
    B, T, D = h.shape
    f32 = jnp.float32
    state0 = (jnp.zeros((B, D), f32), jnp.zeros((B, D), f32),
              jnp.full((B, D), -1e30, f32), jnp.zeros((B, D), f32))

    def step(state, xt):
        gates = xlstm._slstm_gates(p, xt, state[3], cfg)
        new = xlstm._slstm_cell(gates, state)
        return new, None

    (c, n, m, hh), _ = jax.lax.scan(step, state0, jnp.moveaxis(h, 1, 0))
    return {"c": c, "n": n, "m": m, "h": hh}


def _rglru_prefill(p, x, cfg):
    dt = x.dtype
    branch = jnp.einsum("btd,dr->btr", x, p["w_in"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("btd,dr->btr", x,
                                  p["w_gate_branch"].astype(dt)))
    xc = rglru.causal_conv1d(p["conv"], branch)
    h = rglru.rglru_scan(p, xc, cfg)
    y = h * gate
    out = jnp.einsum("btr,rd->btd", y, p["w_out"].astype(dt))
    state = {"h": h[:, -1].astype(jnp.float32),
             "conv": branch[:, -(cfg.conv_width - 1):, :]}
    return shard(out, ("act_batch", "act_seq", "act_embed")), state


# --------------------------------------------------------------------------- #
# towers (segment execution)
# --------------------------------------------------------------------------- #

def _segment_axes(cfg: ArchConfig, plan: List[Segment]) -> List:
    """Per-segment logical-axes trees for one *layer slice* (no stack dim)."""
    from repro.models.params import param_axes
    return [param_axes({f"block{j}": _block_specs(blk, cfg)
                        for j, blk in enumerate(seg.blocks)})
            for seg in plan]


def _shard_layer_params(layer_p, seg_axes):
    """Re-assert parameter shardings on a scanned layer slice.  Without this
    the SPMD partitioner hoists the FSDP all-gather out of the layer loop and
    materializes *every* layer's gathered weights at once (measured: +5 GiB
    on command-r-35b train_4k)."""
    from repro.distributed.sharding import current_ctx, shard
    if current_ctx() is None:
        return layer_p
    return jax.tree.map(lambda p, ax: shard(p, ax), layer_p, seg_axes)


def _run_tower_train(segments_p, plan: List[Segment], x, cfg, positions,
                     causal=True, enc_out=None, remat: bool = True,
                     seg_axes: Optional[List] = None):
    aux = jnp.zeros((), jnp.float32)
    for si, (seg, seg_p) in enumerate(zip(plan, segments_p)):
        def superblock(xx, layer_p):
            if seg_axes is not None:
                layer_p = _shard_layer_params(layer_p, seg_axes[si])
            ax = jnp.zeros((), jnp.float32)
            for j, blk in enumerate(seg.blocks):
                xx, a = apply_block(blk, layer_p[f"block{j}"], xx, cfg,
                                    positions=positions, causal=causal,
                                    enc_out=enc_out)
                ax = ax + a
            return xx, ax

        if cfg.gather_dtype:
            # cast the stacked layer params once (sharded, local) so every
            # FSDP all-gather inside the scan moves gather_dtype bytes
            gd = jnp.dtype(cfg.gather_dtype)
            seg_p = jax.tree.map(
                lambda v: v.astype(gd) if v.dtype == jnp.float32 else v,
                seg_p)
        body = jax.checkpoint(superblock) if remat else superblock
        if seg.repeats > 1 and not cfg.unroll_layers:
            x, auxes = jax.lax.scan(body, x, seg_p)
            aux = aux + jnp.sum(auxes)
        elif seg.repeats > 1:
            for i in range(seg.repeats):
                x, a = body(x, jax.tree.map(lambda v: v[i], seg_p))
                aux = aux + a
        else:
            x, a = body(x, seg_p)
            aux = aux + a
    return x, aux


def _run_tower_prefill(segments_p, plan, x, cfg, positions, cache_len,
                       enc_out=None):
    states: List[Any] = []
    for seg, seg_p in zip(plan, segments_p):
        def superblock(xx, layer_p):
            sts = {}
            for j, blk in enumerate(seg.blocks):
                xx, st = apply_block_prefill(blk, layer_p[f"block{j}"], xx,
                                             cfg, positions=positions,
                                             cache_len=cache_len,
                                             enc_out=enc_out)
                sts[f"block{j}"] = st
            return xx, sts

        if seg.repeats > 1 and not cfg.unroll_layers:
            x, seg_states = jax.lax.scan(superblock, x, seg_p)
        elif seg.repeats > 1:
            reps = []
            for i in range(seg.repeats):
                x, st = superblock(x, jax.tree.map(lambda v: v[i], seg_p))
                reps.append(st)
            seg_states = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
        else:
            x, seg_states = superblock(x, seg_p)
        states.append(seg_states)
    return x, states


def _run_tower_decode(segments_p, plan, x, cfg, states):
    new_states: List[Any] = []
    for seg, seg_p, seg_st in zip(plan, segments_p, states):
        def superblock(xx, layer):
            layer_p, layer_st = layer
            sts = {}
            for j, blk in enumerate(seg.blocks):
                xx, st = apply_block_decode(blk, layer_p[f"block{j}"], xx,
                                            cfg, layer_st[f"block{j}"])
                sts[f"block{j}"] = st
            return xx, sts

        if seg.repeats > 1:
            # Always unrolled, with in-place write-back: a lax.scan over
            # (cache_in -> cache_out) keeps BOTH full stacked caches live
            # (xs and ys buffers), and re-stacking per-layer outputs does
            # too — instead each layer's updated state is written back into
            # the original stacked buffer with dynamic_update_slice, a chain
            # XLA buffer-aliases in place.
            seg_new = seg_st
            for i in range(seg.repeats):
                layer_p = jax.tree.map(lambda v: v[i], seg_p)
                layer_st = jax.tree.map(lambda v: v[i], seg_new)
                x, st = superblock(x, (layer_p, layer_st))
                seg_new = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                        full, new[None].astype(full.dtype), i, axis=0),
                    seg_new, st)
        else:
            x, seg_new = superblock(x, (seg_p, seg_st))
        new_states.append(seg_new)
    return x, new_states


# --------------------------------------------------------------------------- #
# model entry points
# --------------------------------------------------------------------------- #

def _embed_inputs(params, batch: Dict, cfg: ArchConfig) -> jax.Array:
    tokens = batch["tokens"]
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    if cfg.family == "vlm" and "pixel_embeds" in batch:
        x = jnp.concatenate([batch["pixel_embeds"].astype(dt), x], axis=1)
    if cfg.family == "hybrid":
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)   # gemma-style scale
    if not cfg.use_rope:
        # sinusoidal absolute positions (whisper-style backbone adaptation)
        pos = layers.sinusoidal_embeddings(x.shape[1], cfg.d_model, dtype=dt)
        x = x + pos[None]
    return shard(x, ("act_batch", "act_seq", "act_embed"))


def _cache_pos(states: List) -> jax.Array:
    """Per-example decode positions (B,), read off the first attention cache
    (possibly stacked with a leading scan axis)."""
    for seg_states in states:
        for st in seg_states.values():
            if isinstance(st, dict) and "pos" in st:
                p = st["pos"]
                return jnp.reshape(p, (-1,))[:1][0] if p.ndim <= 1 \
                    else p.reshape(-1, p.shape[-1])[0]
    raise ValueError("no attention cache in decode state")


def _encode(params, batch, cfg: ArchConfig, remat=True):
    """Whisper encoder over stubbed frame embeddings."""
    dt = jnp.dtype(cfg.dtype)
    frames = batch["audio_embeds"].astype(dt)       # (B, S_enc, D)
    S = frames.shape[1]
    pos = layers.sinusoidal_embeddings(S, cfg.d_model, dtype=dt)
    x = frames + pos[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (frames.shape[0], S))
    enc = params["encoder"]
    x, _ = _run_tower_train(enc["segments"], cfg.encoder_plan(), x, cfg,
                            positions, causal=False, remat=remat,
                            seg_axes=_segment_axes(cfg, cfg.encoder_plan()))
    return layers.apply_norm(enc["final_norm"], x, cfg)


def _lm_logits(params, x, cfg: ArchConfig) -> jax.Array:
    x = layers.apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x,
                            params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("btd,dv->btv", x,
                            params["lm_head"].astype(x.dtype))
    return shard(logits, ("act_batch", None, "act_vocab"))


def forward_hidden(params, batch: Dict, cfg: ArchConfig, *,
                   remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full tower up to (and including) the final norm: (x, aux_loss).
    Used by the fused chunked-CE training path (never builds full logits)."""
    x = _embed_inputs(params, batch, cfg)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    enc_out = _encode(params, batch, cfg, remat=remat) \
        if cfg.is_encoder_decoder else None
    x, aux = _run_tower_train(params["segments"], cfg.layer_plan(), x, cfg,
                              positions, causal=True, enc_out=enc_out,
                              remat=remat,
                              seg_axes=_segment_axes(cfg, cfg.layer_plan()))
    return layers.apply_norm(params["final_norm"], x, cfg), aux


def head_weights(params, cfg: ArchConfig) -> jax.Array:
    """(D, Vp) output projection (shared with the embedding when tied)."""
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def forward_train(params, batch: Dict, cfg: ArchConfig, *, remat: bool = True
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,T,Vp), aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    enc_out = _encode(params, batch, cfg, remat=remat) \
        if cfg.is_encoder_decoder else None
    x, aux = _run_tower_train(params["segments"], cfg.layer_plan(), x, cfg,
                              positions, causal=True, enc_out=enc_out,
                              remat=remat,
                              seg_axes=_segment_axes(cfg, cfg.layer_plan()))
    return _lm_logits(params, x, cfg), aux


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, per_example_pos: bool = True
                      ) -> List:
    states = []
    for seg in cfg.layer_plan():
        seg_states = {}
        for j, blk in enumerate(seg.blocks):
            st = init_block_state(blk, cfg, batch, cache_len, dtype,
                                  per_example_pos=per_example_pos)
            if seg.repeats > 1:
                st = jax.tree.map(
                    lambda v: jnp.broadcast_to(v[None], (seg.repeats,) + v.shape),
                    st)
            seg_states[f"block{j}"] = st
        states.append(seg_states)
    return states


def decode_state_axes(cfg: ArchConfig) -> List:
    axes = []
    for seg in cfg.layer_plan():
        seg_axes = {}
        for j, blk in enumerate(seg.blocks):
            ax = block_state_axes(blk, cfg)
            if seg.repeats > 1:
                ax = jax.tree.map(lambda a: ("layer",) + a, ax,
                                  is_leaf=lambda t: isinstance(t, tuple))
            seg_axes[f"block{j}"] = ax
        axes.append(seg_axes)
    return axes


def prefill(params, batch: Dict, cfg: ArchConfig, cache_len: int
            ) -> Tuple[jax.Array, List]:
    """Full-sequence forward + decode-state construction.
    Returns (last-position logits (B, Vp), states)."""
    x = _embed_inputs(params, batch, cfg)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    enc_out = _encode(params, batch, cfg, remat=False) \
        if cfg.is_encoder_decoder else None
    x, states = _run_tower_prefill(params["segments"], cfg.layer_plan(), x,
                                   cfg, positions, cache_len, enc_out=enc_out)
    logits = _lm_logits(params, x[:, -1:], cfg)
    return logits[:, 0], states


def decode_step(params, tokens: jax.Array, states: List, cfg: ArchConfig
                ) -> Tuple[jax.Array, List]:
    """tokens: (B, 1) -> (logits (B, Vp), new states)."""
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    if cfg.family == "hybrid":
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if not cfg.use_rope:
        pos = _cache_pos(states)                       # (B,) or scalar
        half = cfg.d_model // 2
        freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / (half - 1))
        angles = jnp.reshape(pos, (-1, 1)).astype(jnp.float32) * freqs[None]
        pe = jnp.concatenate([jnp.sin(angles), jnp.cos(angles)],
                             axis=-1).astype(dt)
        x = x + pe[:, None, :]
    x = shard(x, ("act_batch", "act_seq", "act_embed"))
    x, new_states = _run_tower_decode(params["segments"], cfg.layer_plan(),
                                      x, cfg, states)
    logits = _lm_logits(params, x, cfg)
    return logits[:, 0], new_states
