"""Core transformer layers: norms, RoPE, MLP, attention (+ KV caches).

Layout conventions
  activations: (B, T, D);  q/k/v: (B, T, H, head_dim)
  KV cache: {"k","v": (B, Kv, S, hd), "pos": int32 (B,) or scalar}
            (local-attention ring buffer: position p lives in slot p % W;
             kv_quant adds int8 payloads + (B, Kv, S) f16 scales)

Sharding strategy (resolved via logical-axis rules, DESIGN.md §4):
  * train/prefill: k/v repeated to all q-heads; heads sharded over `model`
    (Megatron-style TP; activation-level head padding when the count does
    not divide the axis), batch over `(pod, data)`, params FSDP on `embed`.
  * decode: GQA einsum without the repeat; the cache shards over kv-heads
    when divisible, else over its sequence dim (flash-decode-like split:
    local compute + two small all-reduces for softmax stats/PV partials).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamSpec

NEG_INF = -2.0 ** 30   # large-but-finite: keeps bf16/f32 masking NaN-free


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #

def norm_specs(cfg: ArchConfig, d: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = d or cfg.d_model
    specs = {"scale": ParamSpec((d,), (None,), init="ones")}
    if cfg.norm_type == "layernorm":
        specs["bias"] = ParamSpec((d,), (None,), init="zeros")
    return specs


def apply_norm(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# --------------------------------------------------------------------------- #
# rotary position embeddings (half-rotation / NeoX style, partial supported)
# --------------------------------------------------------------------------- #

def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs      # (B,T,half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin,
                               x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated, x_pass], axis=-1)


# --------------------------------------------------------------------------- #
# MLP (gated SwiGLU / plain GeLU)
# --------------------------------------------------------------------------- #

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    specs: Dict[str, ParamSpec] = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    if cfg.mlp_bias:
        specs["b_up"] = ParamSpec((f,), (None,), init="zeros")
        specs["b_down"] = ParamSpec((d,), (None,), init="zeros")
    return specs


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def apply_mlp(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
    if "b_up" in p:
        h = h + p["b_up"].astype(x.dtype)
    if cfg.gated_mlp:
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype))
        h = _act(g, cfg.act) * h
    else:
        h = _act(h, cfg.act)
    h = shard(h, ("act_batch", None, "act_mlp"))
    out = jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))
    if "b_down" in p:
        out = out + p["b_down"].astype(x.dtype)
    return shard(out, ("act_batch", "act_seq", "act_embed"))


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #

def attn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    specs: Dict[str, ParamSpec] = {
        "w_q": ParamSpec((d, hq, hd), ("embed", "heads", "head_dim")),
        "w_k": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "w_v": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "w_o": ParamSpec((hq, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["b_q"] = ParamSpec((hq, hd), ("heads", "head_dim"), init="zeros")
        specs["b_k"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"),
                                 init="zeros")
        specs["b_v"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"),
                                 init="zeros")
    return specs


def _project_qkv(p, x: jax.Array, cfg: ArchConfig,
                 positions: jax.Array, use_rope: bool
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["w_q"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, p["w_k"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, p["w_v"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)
        k = k + p["b_k"].astype(dt)
        v = v + p["b_v"].astype(dt)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    q = q * (cfg.head_dim_ ** -0.5)
    return q, k, v


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(scores / cap) * cap if cap > 0 else scores


def _mha(q, k, v, mask, cfg: ArchConfig) -> jax.Array:
    """Full multi-head attention; k/v already repeated to all q heads.
    q,k,v: (B,T,H,hd) / (B,S,H,hd); mask: broadcastable to (B,1,T,S)."""
    scores = jnp.einsum("bthk,bshk->bhts", q, k,
                        preferred_element_type=jnp.float32)
    scores = _softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    w = shard(w, ("act_batch", "act_heads", "act_q_seq", None))
    return jnp.einsum("bhts,bshk->bthk", w, v)


def _mask(q_pos, k_pos, causal, window):
    """q_pos: (B,Tq); k_pos: (B,Skv) -> (B,1,Tq,Skv) bool."""
    ti = q_pos[:, :, None]
    si = k_pos[:, None, :]
    mask = jnp.ones(ti.shape[:2] + (si.shape[-1],), dtype=bool)
    if causal:
        mask = mask & (si <= ti)
    if window > 0:
        mask = mask & (si > ti - window)
    return mask[:, None, :, :]


def attention(p, x: jax.Array, cfg: ArchConfig, *, positions: jax.Array,
              causal: bool = True, window: int = 0, use_rope: bool = True
              ) -> jax.Array:
    """Training / prefill attention (full sequence).

    With cfg.attn_chunk > 0 (and divisible T), queries are processed in
    chunks via lax.scan — the (B,H,Tq,S) softmax tile is bounded at
    (B,H,chunk,S), the XLA-level analogue of the Pallas flash kernel
    (`repro.kernels.flash_attention` is the TPU-native version).
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, use_rope)
    # GQA: repeat kv to all query heads; shard the head axis over `model`.
    rep = cfg.q_per_kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    n_heads = cfg.num_heads
    if cfg.pad_heads_to and cfg.pad_heads_to > n_heads:
        # activation-level head padding: zero heads attend to nothing and
        # are sliced off after the PV product — buys clean head-sharding
        # for counts that do not divide the model axis (e.g. 40 -> 48).
        extra = cfg.pad_heads_to - n_heads
        pad = ((0, 0), (0, 0), (0, extra), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
        n_heads = cfg.pad_heads_to
    q = shard(q, ("act_batch", "act_q_seq", "act_heads", None))
    k = shard(k, ("act_batch", None, "act_heads", None))
    v = shard(v, ("act_batch", None, "act_heads", None))

    chunk = cfg.attn_chunk
    if chunk and T > chunk and T % chunk == 0:
        nq = T // chunk
        qs = jnp.moveaxis(q.reshape(B, nq, chunk, n_heads,
                                    cfg.head_dim_), 1, 0)
        ps = jnp.moveaxis(positions.reshape(B, nq, chunk), 1, 0)

        @jax.checkpoint
        def blk(carry, xs):
            q_blk, p_blk = xs
            q_blk = shard(q_blk, ("act_batch", "act_q_seq", "act_heads",
                                  None))
            o = _mha(q_blk, k, v, _mask(p_blk, positions, causal, window),
                     cfg)
            return carry, o

        _, outs = jax.lax.scan(blk, 0, (qs, ps))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, T, n_heads, cfg.head_dim_)
    else:
        out = _mha(q, k, v, _mask(positions, positions, causal, window), cfg)
    out = out[:, :, :cfg.num_heads]          # drop padded heads
    out = jnp.einsum("bthk,hkd->btd", out, p["w_o"].astype(x.dtype))
    return shard(out, ("act_batch", "act_seq", "act_embed"))


# ----------------------------- decode path ---------------------------------- #

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  window: int = 0, dtype=jnp.bfloat16,
                  per_example_pos: bool = True) -> Dict[str, jax.Array]:
    """Cache layout (B, Kv, S, hd): the kv-head dim precedes the sequence
    dim so that when Kv divides the `model` axis (MHA archs) the cache
    shards over heads — local attention math, zero softmax collectives —
    and otherwise falls back to flash-decode-style sequence sharding.

    With cfg.kv_quant the cache stores int8 payloads + per-(B,Kv,S) f16
    scales (symmetric max-abs over head_dim): 2.06x smaller than bf16, and
    the dequant folds into the attention einsums (scores scale per key slot;
    value scale folds into the softmax weights) so no bf16 copy of the
    cache ever materializes."""
    size = min(window, max_len) if window > 0 else max_len
    shape = (batch, cfg.num_kv_heads, size, cfg.head_dim_)
    pos_shape = (batch,) if per_example_pos else ()
    cache = {"pos": jnp.zeros(pos_shape, jnp.int32)}
    if cfg.kv_quant:
        cache.update({
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], jnp.float16),
            "v_scale": jnp.zeros(shape[:3], jnp.float16),
        })
    else:
        cache.update({"k": jnp.zeros(shape, dtype),
                      "v": jnp.zeros(shape, dtype)})
    return cache


CACHE_AXES = ("act_batch", "act_kv_heads", "act_kv_seq", None)


def cache_axes(quant: bool = False) -> Dict[str, tuple]:
    """Logical axes of the cache (for dry-run in_shardings)."""
    # pos is scalar in the uniform-wave (dry-run) states; the per-example
    # engine variant never goes through tree_shardings.
    ax = {"k": CACHE_AXES, "v": CACHE_AXES, "pos": ()}
    if quant:
        ax["k_scale"] = CACHE_AXES[:3]
        ax["v_scale"] = CACHE_AXES[:3]
    return ax


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., hd) -> (int8 payload, f16 max-abs scale over hd)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def decode_attention(p, x: jax.Array, cfg: ArchConfig, cache: Dict,
                     *, window: int = 0, use_rope: bool = True
                     ) -> Tuple[jax.Array, Dict]:
    """One-token attention against a (possibly ring-buffered) KV cache.

    x: (B, 1, D).  GQA einsum form (no kv repeat); the cache shards over
    kv-heads when divisible, else over its sequence dim (flash-decode-style
    parallelism).  Positions are per-example (continuous batching admits
    requests at different depths).
    """
    B, T, _ = x.shape
    assert T == 1, "decode_attention processes one new token"
    pos = cache["pos"]          # (B,) per-example, or scalar (uniform wave)
    uniform = pos.ndim == 0
    positions = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (B, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, use_rope)
    Kv, G = cfg.num_kv_heads, cfg.q_per_kv
    S = cache["k"].shape[2]
    slot = jnp.mod(pos, S) if window > 0 else jnp.minimum(pos, S - 1)
    new_cache = dict(cache)

    def write(buf, val):
        """Insert one token at `slot` along the cache sequence dim."""
        if uniform:
            # dynamic_update_slice aliases in place (production decode
            # waves advance uniformly; the per-example scatter path below
            # is kept for continuous batching at ragged depths).
            v4 = val[:, :, None] if val.ndim == 3 else val[:, :, None, ...]
            start = (0, 0, slot) + (0,) * (buf.ndim - 3)
            return jax.lax.dynamic_update_slice(buf, v4.astype(buf.dtype),
                                                start)
        bidx = jnp.arange(B)[:, None]
        kidx = jnp.arange(Kv)[None, :]
        return buf.at[bidx, kidx, slot[:, None]].set(val.astype(buf.dtype))

    if cfg.kv_quant:
        k8, ks = quantize_kv(k_new[:, 0])             # (B,Kv,hd),(B,Kv)
        v8, vs = quantize_kv(v_new[:, 0])
        k = write(cache["k"], k8)
        v = write(cache["v"], v8)
        k_scale = write(cache["k_scale"], ks)
        v_scale = write(cache["v_scale"], vs)
        new_cache.update({"k": k, "v": v, "k_scale": k_scale,
                          "v_scale": v_scale})
    else:
        k = write(cache["k"], k_new[:, 0])
        v = write(cache["v"], v_new[:, 0])
        new_cache.update({"k": k, "v": v})
    k = shard(k, CACHE_AXES)
    v = shard(v, CACHE_AXES)

    qg = q.reshape(B, 1, Kv, G, cfg.head_dim_)
    scores = jnp.einsum("btkgh,bksh->bkgts", qg, k,
                        preferred_element_type=jnp.float32)
    if cfg.kv_quant:
        # fold the per-slot key scale into the logits (dequant-free dot)
        scores = scores * k_scale.astype(jnp.float32)[:, :, None, None, :]
    scores = _softcap(scores, cfg.attn_logit_softcap)
    slot_ids = jnp.arange(S, dtype=jnp.int32)
    pb = jnp.reshape(pos, (-1, 1))                    # (B,1) or (1,1)
    if window > 0:
        # slot i holds global position p_i = pos - ((pos - i) mod S); valid
        # slots cover (pos - S, pos].
        p_i = pb - jnp.mod(pb - slot_ids[None, :], S)
        valid = p_i >= 0
    else:
        valid = slot_ids[None, :] <= pb
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    if cfg.kv_quant:
        # fold the value scale into the softmax weights, dot in int8 payload
        w = (w * v_scale.astype(jnp.float32)[:, :, None, None, :]).astype(
            jnp.bfloat16)
        out = jnp.einsum("bkgts,bksh->btkgh", w, v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        w = w.astype(q.dtype)
        out = jnp.einsum("bkgts,bksh->btkgh", w, v)
    out = out.reshape(B, 1, cfg.num_heads, cfg.head_dim_)
    out = jnp.einsum("bthk,hkd->btd", out, p["w_o"].astype(x.dtype))
    new_cache["pos"] = pos + 1
    return shard(out, ("act_batch", "act_seq", "act_embed")), new_cache


# ----------------------------- cross attention ------------------------------- #

def cross_attn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    return attn_specs(cfg)


def cross_attention(p, x: jax.Array, cfg: ArchConfig,
                    enc_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder->encoder attention (whisper); enc k/v precomputed."""
    dt = x.dtype
    B = x.shape[0]
    positions = jnp.zeros((B, x.shape[1]), jnp.int32)
    q, _, _ = _project_qkv(p, x, cfg, positions, use_rope=False)
    k, v = enc_kv
    rep = cfg.q_per_kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    mask = jnp.ones((B, 1, x.shape[1], k.shape[1]), dtype=bool)
    out = _mha(q, k.astype(dt), v.astype(dt), mask, cfg)
    out = jnp.einsum("bthk,hkd->btd", out, p["w_o"].astype(dt))
    return shard(out, ("act_batch", "act_seq", "act_embed"))


def encode_cross_kv(p, enc_out: jax.Array, cfg: ArchConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    """Precompute cross-attention k/v from encoder output."""
    dt = enc_out.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["w_k"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["w_v"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["b_k"].astype(dt)
        v = v + p["b_v"].astype(dt)
    return k, v


# --------------------------------------------------------------------------- #
# positional embeddings (whisper)
# --------------------------------------------------------------------------- #

def sinusoidal_embeddings(length: int, d: int, dtype=jnp.float32) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / (half - 1))
    angles = jnp.arange(length, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)],
                           axis=-1).astype(dtype)
