"""Assigned architecture registry: importing this package registers all 10
architectures (plus tiny smoke-test twins) with `repro.configs.base`."""

from repro.configs import (command_r_35b, deepseek_7b, deepseek_moe_16b,
                           glm4_9b, granite_moe_1b_a400m, internvl2_26b,
                           qwen15_32b, recurrentgemma_9b, whisper_medium,
                           xlstm_125m)
from repro.configs.base import ArchConfig, get_config, list_archs

__all__ = ["ArchConfig", "get_config", "list_archs"]
