"""Whisper-medium backbone (conv frontend stubbed to frame embeddings).

[arXiv:2212.04356; unverified] — enc-dec, 24+24L d_model=1024 16H d_ff=4096
vocab=51865.  LayerNorm + GeLU (non-gated) + biases, sinusoidal positions
(adaptation note: the decoder's learned positions are replaced by sinusoidal
so the assigned 32k-decode shape needs no 32k-entry learned table), tied
decoder embedding/output head.  ``input_specs()`` supplies post-conv frame
embeddings (B, 1500, d_model).
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    use_rope=False,
    norm_type="layernorm",
    act="gelu",
    gated_mlp=False,
    mlp_bias=True,
    qkv_bias=True,
    tie_embeddings=True,
    attn_chunk=1024,
    source="arXiv:2212.04356; hf:openai/whisper-medium",
)

TINY = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    is_encoder_decoder=True,
    encoder_layers=2,
    encoder_seq=16,
    use_rope=False,
    norm_type="layernorm",
    act="gelu",
    gated_mlp=False,
    mlp_bias=True,
    qkv_bias=True,
    tie_embeddings=True,
    source="tiny twin",
)

register(CONFIG, TINY)
