"""DeepSeekMoE-16B (fine-grained experts: 2 shared + 64 routed top-6).

[arXiv:2401.06066; hf] — 28L d_model=2048 16H (MHA kv=16) d_ff=1408 (per
routed expert) vocab=102400.  First layer is dense (intermediate 10944, as in
the release); remaining 27 layers are MoE with 2 shared experts.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_k_dense=1,
    dense_d_ff=10944,
    rope_theta=10_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
)

TINY = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=48,
    vocab_size=512,
    n_experts=8,
    n_shared_experts=2,
    experts_per_token=2,
    moe_d_ff=48,
    first_k_dense=1,
    dense_d_ff=128,
    source="tiny twin",
)

register(CONFIG, TINY)
