"""Granite-3.0-1B-A400M (MoE, 32 experts top-8).

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — 24L d_model=1024 16H
(GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 32e top-8.
Tied embeddings (granite micro models), RoPE, RMSNorm, SwiGLU experts.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    tie_embeddings=True,
    rope_theta=10_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

TINY = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=512,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    tie_embeddings=True,
    source="tiny twin",
)

register(CONFIG, TINY)
