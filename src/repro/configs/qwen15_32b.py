"""Qwen1.5-32B.

[hf:Qwen/Qwen1.5-32B; hf] — 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064.  QKV bias (the Qwen1.5 signature), RMSNorm, SwiGLU, untied,
RoPE theta 1M.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    train_accum=4,
    # 40 heads are not divisible by the 16-way model axis.  §Perf cell 2:
    # padding activations to 48 heads (+20% attention FLOPs) restores clean
    # 16-way head sharding and cut collective bytes 4.2x vs the
    # context-parallel fallback; weight tensors keep their true 40-head
    # shape (unsharded on the head dim).
    pad_heads_to=48,
    rule_overrides=(("heads", ()), ("kv_heads", ())),
    source="hf:Qwen/Qwen1.5-32B",
)

TINY = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    source="tiny twin",
)

register(CONFIG, TINY)
