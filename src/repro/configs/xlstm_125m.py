"""xLSTM-125M (sLSTM + mLSTM blocks).

[arXiv:2405.04517; unverified] — 12L d_model=768 4H d_ff=0 vocab=50304.
d_ff = 0: xLSTM blocks carry their own up/down projections (mLSTM proj
factor 2); no separate FFN.  Every 4th block is an sLSTM (a 3:1 mix in the
spirit of the paper's xLSTM[7:1] notation), the rest are mLSTM.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,
    proj_factor=2.0,
    conv_width=4,
    # 125M params / 4 heads: nothing is 16-way tensor-shardable, so the
    # production layout is pure data parallelism over every mesh axis
    # (weights replicated across `model`; grads all-reduced across it).
    rule_overrides=(
        ("act_batch", (("pod", "data", "model"), ("data", "model"),
                       ("pod", "data"), ("data",))),
        ("act_seq", ()), ("act_rnn", ()), ("act_heads", ()),
        ("rnn", ()), ("heads", ()),
    ),
    source="arXiv:2405.04517",
)

TINY = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    slstm_every=4,
    proj_factor=2.0,
    conv_width=4,
    source="tiny twin",
)

register(CONFIG, TINY)
