"""Architecture configs: one frozen dataclass per assigned architecture.

Every architecture is expressed as a *layer plan* — a sequence of segments,
each segment being a (possibly repeated) homogeneous superblock.  Homogeneous
repeats are executed with ``jax.lax.scan`` over stacked parameters (compile
time and HBM friendly); heterogeneous patterns (hybrids) scan over a
superblock of several block types.

Block types (``mixer`` / ``mlp`` pairs):
  mixer: attn | local_attn | mlstm | slstm | rglru | cross_attn (enc-dec)
  mlp:   dense | moe | none
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One transformer block inside a superblock."""

    mixer: str = "attn"          # attn|local_attn|mlstm|slstm|rglru
    mlp: str = "dense"           # dense|moe|none
    cross_attn: bool = False     # enc-dec decoder blocks


@dataclasses.dataclass(frozen=True)
class Segment:
    """``repeats`` × superblock of ``blocks`` (scanned if repeats > 1)."""

    blocks: Tuple[BlockSpec, ...]
    repeats: int = 1

    @property
    def n_layers(self) -> int:
        return len(self.blocks) * self.repeats


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # attention options
    use_rope: bool = True           # False -> sinusoidal absolute positions
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0
    qkv_bias: bool = False
    sliding_window: int = 0       # 0 = global attention
    parallel_block: bool = False  # command-r style parallel attn+mlp
    attn_logit_softcap: float = 0.0

    # norm / mlp
    norm_type: str = "rmsnorm"    # rmsnorm|layernorm
    act: str = "silu"
    gated_mlp: bool = True
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ssm / hybrid
    slstm_every: int = 0          # xLSTM: every k-th block is sLSTM
    proj_factor: float = 2.0      # mLSTM up-projection
    conv_width: int = 4
    d_rnn: int = 0                # RG-LRU width (0 -> d_model)
    rglru_pattern: int = 3        # 2 recurrent + 1 local attn per 3 layers

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500       # precomputed frame embeddings (stub)

    # vlm
    vision_prefix_len: int = 0    # precomputed patch embeddings (stub)

    # memory shape knobs (0 = off).  attn_chunk: blockwise-softmax attention
    # over query chunks (XLA-level flash-attention analogue — bounds the
    # (B,H,Tq,Skv) logits tile).  ce_chunk: fused LM-head + cross-entropy
    # over sequence chunks (never materializes full (B,T,V) logits).
    attn_chunk: int = 0
    ce_chunk: int = 0
    train_accum: int = 1   # gradient-accumulation microbatches at train_4k
    # perf knobs (§Perf hillclimbs):
    gather_dtype: str = ""      # "bfloat16" -> cast stacked layer params
                                # before the layer scan so FSDP all-gathers
                                # move half the bytes (masters stay f32)
    kv_quant: bool = False      # int8 KV cache with per-slot scales
    pad_heads_to: int = 0       # pad attention heads (activations only) so
                                # the head dim divides the model axis —
                                # trades a little attention compute for
                                # full tensor-parallel attention (qwen: 40->48)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # execution: False -> lax.scan over stacked layers (default); True ->
    # inlined python loop (used by the compositional roofline probes, where
    # XLA's cost analysis must see every layer's ops).
    unroll_layers: bool = False

    # per-arch sharding-rule overrides merged over DEFAULT_RULES, e.g.
    # xlstm-125m (4 heads, d=768) runs pure-DP: no dim is 16-way
    # model-shardable, so batch shards over (data, model) instead.
    rule_overrides: Tuple[Tuple[str, Tuple], ...] = ()

    # provenance
    source: str = ""

    # ---- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def layer_plan(self) -> List[Segment]:
        """Decoder-side segments (encoder handled separately)."""
        if self.family == "ssm":
            return self._xlstm_plan()
        if self.family == "hybrid":
            return self._rglru_plan()
        if self.n_experts > 0:
            return self._moe_plan()
        blocks = (BlockSpec("attn", "dense", cross_attn=self.is_encoder_decoder),)
        return [Segment(blocks, repeats=self.num_layers)]

    def encoder_plan(self) -> List[Segment]:
        assert self.is_encoder_decoder
        return [Segment((BlockSpec("attn", "dense"),),
                        repeats=self.encoder_layers)]

    def _moe_plan(self) -> List[Segment]:
        segs: List[Segment] = []
        if self.first_k_dense:
            segs.append(Segment((BlockSpec("attn", "dense"),),
                                repeats=self.first_k_dense))
        segs.append(Segment((BlockSpec("attn", "moe"),),
                            repeats=self.num_layers - self.first_k_dense))
        return segs

    def _xlstm_plan(self) -> List[Segment]:
        """Pattern: (slstm_every-1) mLSTM blocks then 1 sLSTM, repeated."""
        k = self.slstm_every or self.num_layers + 1
        if self.num_layers % k == 0:
            blocks = tuple(BlockSpec("mlstm", "none") for _ in range(k - 1)) \
                     + (BlockSpec("slstm", "none"),)
            return [Segment(blocks, repeats=self.num_layers // k)]
        return [Segment((BlockSpec("mlstm", "none"),), repeats=self.num_layers)]

    def _rglru_plan(self) -> List[Segment]:
        """Griffin residual pattern: 2 recurrent blocks, 1 local-attn block."""
        period = self.rglru_pattern
        full, extra = divmod(self.num_layers, period)
        blocks = tuple(BlockSpec("rglru", "dense") for _ in range(period - 1)) \
                 + (BlockSpec("local_attn", "dense"),)
        segs = [Segment(blocks, repeats=full)]
        if extra:
            segs.append(Segment(tuple(BlockSpec("rglru", "dense")
                                      for _ in range(extra)), repeats=1))
        return segs

    # ---- bookkeeping -----------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.head_dim_
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for seg in (self.layer_plan() +
                    (self.encoder_plan() if self.is_encoder_decoder else [])):
            for blk in seg.blocks * seg.repeats:
                if blk.mixer in ("attn", "local_attn"):
                    total += d * hd * (n_q + 2 * n_kv) + n_q * hd * d
                    if blk.cross_attn:
                        total += d * hd * (n_q + 2 * n_kv) + n_q * hd * d
                elif blk.mixer == "mlstm":
                    up = int(d * self.proj_factor)
                    total += 2 * d * up + 3 * up * up // max(n_q, 1) + up * d
                elif blk.mixer == "slstm":
                    total += 4 * d * d + 4 * d * (d // max(n_q, 1)) + d * d
                elif blk.mixer == "rglru":
                    rnn = self.d_rnn or d
                    total += 2 * d * rnn + 2 * rnn * rnn // 8 + rnn * d
                if blk.mlp == "dense":
                    ff = self.dense_d_ff or self.d_ff
                    total += d * ff * (3 if self.gated_mlp else 2)
                elif blk.mlp == "moe":
                    ff = self.moe_d_ff or self.d_ff
                    total += self.n_experts * d * ff * 3 + d * self.n_experts
                    total += self.n_shared_experts * d * ff * 3
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * d * ff * 3
        moe_layers = self.num_layers - self.first_k_dense
        return self.param_count() - moe_layers * inactive


_REGISTRY: Dict[str, ArchConfig] = {}
_TINY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig, tiny: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _TINY[cfg.name] = tiny
    return cfg


def get_config(name: str, *, tiny: bool = False) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers per-arch registration)
    table = _TINY if tiny else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return table[name]


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
