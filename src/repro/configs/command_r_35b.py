"""Command-R 35B (c4ai-command-r-v01).

[hf:CohereForAI/c4ai-command-r-v01; unverified] — 40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000.  Parallel attention+FFN blocks
(GPT-J/Cohere style, one shared pre-norm), LayerNorm, no biases, tied
embeddings, RoPE theta 8M.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    parallel_block=True,
    norm_type="layernorm",
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    train_accum=2,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

TINY = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    parallel_block=True,
    norm_type="layernorm",
    tie_embeddings=True,
    source="tiny twin",
)

register(CONFIG, TINY)
