"""RecurrentGemma-9B (Griffin: RG-LRU + local attention, 2:1).

[arXiv:2402.19427; unverified] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  Pattern: (rglru, rglru, local_attn) × 12 + 2 trailing rglru
blocks; sliding window 2048; GeGLU MLP; RMSNorm; tied embeddings with
sqrt(d_model) embedding scale; head_dim 256.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    sliding_window=2048,
    d_rnn=4096,
    rglru_pattern=3,
    conv_width=4,
    act="gelu_tanh",
    tie_embeddings=True,
    rope_theta=10_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    train_accum=2,
    source="arXiv:2402.19427; hf:google/recurrentgemma-9b",
)

TINY = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    sliding_window=8,
    d_rnn=64,
    rglru_pattern=3,
    conv_width=4,
    act="gelu_tanh",
    tie_embeddings=True,
    source="tiny twin",
)

register(CONFIG, TINY)
