"""GLM-4-9B.

[hf:THUDM/glm-4-9b; hf] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552.  Partial rotary (50%), QKV bias, RMSNorm, SwiGLU, untied.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rotary_pct=0.5,
    qkv_bias=True,
    rope_theta=10_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    source="hf:THUDM/glm-4-9b",
)

TINY = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    rotary_pct=0.5,
    qkv_bias=True,
    source="tiny twin",
)

register(CONFIG, TINY)
