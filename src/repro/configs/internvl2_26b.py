"""InternVL2-26B backbone (InternViT frontend stubbed to patch embeddings).

[arXiv:2404.16821; hf] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The LLM backbone is InternLM2-20B-style (llama-family, RoPE,
GQA, SwiGLU, RMSNorm); `input_specs()` supplies precomputed ViT patch
embeddings (B, 1024, d_model) prepended to the token sequence.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    vision_prefix_len=1024,
    attn_chunk=1024,
    ce_chunk=1024,
    train_accum=2,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B",
)

TINY = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    vision_prefix_len=8,
    source="tiny twin",
)

register(CONFIG, TINY)
