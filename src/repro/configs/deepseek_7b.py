"""DeepSeek-LLM 7B.

[arXiv:2401.02954; hf] — 30L d_model=4096 32H (MHA kv=32) d_ff=11008
vocab=102400.  Llama-architecture: RoPE, RMSNorm, SwiGLU, no biases, untied.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10_000.0,
    attn_chunk=1024,
    ce_chunk=1024,
    source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-7b-base",
)

TINY = ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    source="tiny twin",
)

register(CONFIG, TINY)
