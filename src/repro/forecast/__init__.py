"""Workload-rate forecasting (ROADMAP open item 2).

The package joins the repo's two halves: the scenario generators
(`repro.scenarios`) are the data factory, the JAX training substrate
(`repro.models` + `repro.train`) fits a small learned forecaster, and
`repro.core.autoscaler.PredictiveAutoscaler` consumes either forecaster
online to launch capacity *ahead* of bursts (see ARCHITECTURE.md
"Predictive autoscaling").

Layout:

* `features`  — numpy-only windowed (history → next-window rate) examples
  from `TraceStore.arrival_time` columns; deterministic per
  (family, seed, window).
* `baseline`  — numpy-only online EWMA forecaster + closed-form AR(1)
  baseline; these run inside hermetic sweep cells with no JAX dependency.
* `model`     — the learned forecaster: a tiny mLSTM trunk from
  `repro.models.xlstm` trained with `repro.train.optimizer`, restored via
  `repro.train.checkpoint`.  Imported lazily so `repro.forecast` works in
  JAX-free environments.
"""
from repro.forecast.baseline import Ar1Baseline, EwmaForecaster
from repro.forecast.features import (WindowConfig, bin_rates, family_examples,
                                     make_dataset, windowed_examples)

__all__ = [
    "Ar1Baseline", "EwmaForecaster", "WindowConfig", "bin_rates",
    "family_examples", "make_dataset", "windowed_examples",
]
