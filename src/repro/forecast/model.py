"""Learned rate forecaster: tiny mLSTM trunk on the jax_pallas substrate.

Reuses the repo's existing training machinery end to end — parameters
come from `repro.models.params.init_params` over `mlstm_specs`, the
optimizer is the in-house AdamW (`repro.train.optimizer`), and trained
params persist through `repro.train.checkpoint.CheckpointManager` — so
the forecaster is a (very small) citizen of the same world as the LM
configs rather than a parallel stack.

The model predicts the next-window mean arrival rate from
``history_bins`` past rates, in ``log1p`` space (rates are nonnegative
and heavy-tailed across the scenario families; squared error in log
space stops flash-crowd peaks from drowning the quiet regimes).

This module is the only JAX-importing part of `repro.forecast`; import
it lazily (`from repro.forecast import model`) so the numpy-only pieces
keep working where JAX is absent.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec, init_params
from repro.models.xlstm import apply_mlstm, mlstm_specs
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state

from repro.forecast.baseline import _EPS
from repro.forecast.features import WindowConfig


def forecast_arch(d_model: int = 32, num_heads: int = 2) -> ArchConfig:
    """A minimal ArchConfig carrying just what `mlstm_specs` reads
    (d_model / proj_factor / num_heads / conv_width); the LM-only fields
    are inert placeholders."""
    return ArchConfig(name="rate-mlstm", family="ssm", num_layers=1,
                      d_model=d_model, num_heads=num_heads,
                      num_kv_heads=num_heads, d_ff=2 * d_model, vocab_size=0)


def forecast_specs(cfg: ArchConfig) -> Dict:
    return {
        "w_in": ParamSpec((1, cfg.d_model), ("embed", "rnn")),
        "block": mlstm_specs(cfg),
        "w_out": ParamSpec((cfg.d_model, 1), ("rnn", "embed"), scale=0.1),
        "b_out": ParamSpec((1,), (None,), init="zeros"),
    }


def apply_forecast(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, T) log1p-rates -> (B,) predicted log1p next-window rate."""
    h = x[..., None] @ params["w_in"]                   # (B, T, D)
    h = h + apply_mlstm(params["block"], h, cfg)        # residual trunk
    y = h[:, -1, :] @ params["w_out"] + params["b_out"]
    return y[:, 0]


@dataclasses.dataclass
class TrainResult:
    params: Dict
    arch: ArchConfig
    window: WindowConfig
    losses: np.ndarray            # per-step training loss
    val_mse: Optional[float]      # log-space MSE on the val split


def _batches(rng: np.random.Generator, n: int, batch: int, steps: int):
    for _ in range(steps):
        yield rng.integers(0, n, size=batch)


def train_forecaster(X: np.ndarray, y: np.ndarray, *,
                     window: WindowConfig,
                     X_val: Optional[np.ndarray] = None,
                     y_val: Optional[np.ndarray] = None,
                     seed: int = 0, steps: int = 300, batch: int = 64,
                     d_model: int = 32, num_heads: int = 2,
                     learning_rate: float = 3e-3) -> TrainResult:
    """Fit the mLSTM forecaster on (X, y) rate examples.

    Deterministic for fixed inputs + hyperparameters: param init is keyed
    on ``seed``, batch order on the same seed's numpy stream, and every
    update is the jitted AdamW step."""
    if X.shape[0] == 0:
        raise ValueError("empty training set")
    arch = forecast_arch(d_model=d_model, num_heads=num_heads)
    params = init_params(jax.random.key(seed), forecast_specs(arch))
    opt_cfg = OptimizerConfig(learning_rate=learning_rate,
                              warmup_steps=max(1, steps // 10),
                              total_steps=steps, weight_decay=0.0)
    opt_state = init_opt_state(params)

    def loss_fn(p, xb, yb):
        pred = apply_forecast(p, xb, arch)
        return jnp.mean((pred - yb) ** 2)

    @jax.jit
    def step(p, s, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        p, s, _ = adamw_update(opt_cfg, p, grads, s)
        return p, s, loss

    Xl = np.log1p(np.asarray(X, np.float32))
    yl = np.log1p(np.asarray(y, np.float32))
    rng = np.random.default_rng(seed)
    losses = []
    for idx in _batches(rng, Xl.shape[0], min(batch, Xl.shape[0]), steps):
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(Xl[idx]),
                                       jnp.asarray(yl[idx]))
        losses.append(float(loss))

    val_mse = None
    if X_val is not None and X_val.shape[0]:
        pred = apply_forecast(params, jnp.asarray(
            np.log1p(np.asarray(X_val, np.float32))), arch)
        val_mse = float(jnp.mean(
            (pred - jnp.asarray(np.log1p(np.asarray(y_val, np.float32))))
            ** 2))
    return TrainResult(params=params, arch=arch, window=window,
                       losses=np.asarray(losses), val_mse=val_mse)


class LearnedForecaster:
    """Online wrapper giving trained params the baseline forecaster
    contract (`observe_bin` / `predict`, see repro.forecast.baseline).

    Inference is a single jitted apply over the last ``history_bins``
    rates — deterministic for fixed params and history.  Confidence uses
    the same EW one-step-error convention as `EwmaForecaster`, seeded at
    full trust once enough history has accumulated."""

    name = "mlstm"

    def __init__(self, params, arch: ArchConfig, window: WindowConfig,
                 err_alpha: float = 0.25):
        self.params = params
        self.arch = arch
        self.window = window
        self.err_alpha = err_alpha
        self._hist = collections.deque(maxlen=window.history_bins)
        self._mae = 0.0
        self._last_pred: Optional[float] = None
        self._apply = jax.jit(
            lambda p, x: apply_forecast(p, x, arch))

    def observe_bin(self, rate: float) -> None:
        rate = float(rate)
        if self._last_pred is not None:
            self._mae += self.err_alpha * (abs(rate - self._last_pred)
                                           - self._mae)
        self._hist.append(rate)

    def predict(self) -> Tuple[float, float]:
        if len(self._hist) < self.window.history_bins:
            return 0.0, 0.0
        x = jnp.asarray(np.log1p(np.asarray(self._hist, np.float32)))[None]
        rate = float(np.expm1(np.asarray(self._apply(self.params, x))[0]))
        rate = max(0.0, rate)
        self._last_pred = rate
        conf = 1.0 / (1.0 + self._mae / (rate + _EPS))
        return rate, conf


# -- checkpoint round-trip ----------------------------------------------------

def save_forecaster(directory: str, result: TrainResult, step: int) -> str:
    """Persist trained params + geometry with the shared CheckpointManager
    (leaves.npz + meta.json, atomic keep-N — same format as the trainers)."""
    from repro.train.checkpoint import CheckpointManager
    extra = {"d_model": result.arch.d_model,
             "num_heads": result.arch.num_heads,
             "bin_s": result.window.bin_s,
             "history_bins": result.window.history_bins,
             "horizon_bins": result.window.horizon_bins}
    return CheckpointManager(directory).save(step, result.params, extra=extra)


def load_forecaster(directory: str,
                    step: Optional[int] = None) -> LearnedForecaster:
    from repro.train.checkpoint import CheckpointManager
    mgr = CheckpointManager(directory)
    found = mgr.latest_step() if step is None else step
    if found is None:
        raise FileNotFoundError(f"no forecaster checkpoint in {directory}")
    d = mgr.directory
    import json
    import os
    with open(os.path.join(d, f"step_{found:08d}", "meta.json")) as f:
        extra = json.load(f)["extra"]
    arch = forecast_arch(d_model=int(extra["d_model"]),
                         num_heads=int(extra["num_heads"]))
    like = jax.tree.map(lambda s: np.zeros(s.shape, np.float32),
                        forecast_specs(arch),
                        is_leaf=lambda s: isinstance(s, ParamSpec))
    params, _, _ = mgr.restore(like, step=found)
    window = WindowConfig(bin_s=float(extra["bin_s"]),
                          history_bins=int(extra["history_bins"]),
                          horizon_bins=int(extra["horizon_bins"]))
    return LearnedForecaster(params, arch, window)
