"""Windowed arrival-rate features from `TraceStore.arrival_time` columns.

The six seeded generator families (`repro.scenarios.generators`) are the
data factory: every (family, seed, window) triple maps to one fixed array
of labeled examples, so train/val membership is a pure function of the
same triple — no RNG is consumed here at all.

An example is ``history_bins`` consecutive per-bin arrival rates followed
by the label: the mean rate over the next ``horizon_bins`` bins.  Rates
(jobs/s) are what the `PredictiveAutoscaler` converts to node demand, so
the forecaster predicts in the same unit it is consumed in.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class WindowConfig:
    """Feature-window geometry shared by extraction, training and the
    online autoscaler binning."""

    bin_s: float = 30.0       # arrival-count bin width (seconds)
    history_bins: int = 16    # model input length
    horizon_bins: int = 2     # label: mean rate over the next this-many bins

    def __post_init__(self):
        if self.bin_s <= 0 or self.history_bins < 1 or self.horizon_bins < 1:
            raise ValueError(f"degenerate window config: {self}")


def bin_rates(arrival_time: np.ndarray, bin_s: float,
              n_bins: Optional[int] = None) -> np.ndarray:
    """Per-bin arrival rate (jobs/s) of a sorted arrival-time column.

    The trace's last arrival closes the series: bins past it would read as
    spurious zero-rate tail (the scenario *ended*, demand didn't vanish)."""
    t = np.asarray(arrival_time, np.float64)
    if t.size == 0:
        return np.zeros(0 if n_bins is None else n_bins, np.float64)
    if n_bins is None:
        n_bins = int(np.floor(float(t[-1]) / bin_s)) + 1
    idx = np.minimum((t / bin_s).astype(np.int64), n_bins - 1)
    counts = np.bincount(idx, minlength=n_bins)[:n_bins]
    return counts.astype(np.float64) / bin_s


def windowed_examples(rates: np.ndarray, cfg: WindowConfig
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Slide (history → next-horizon-mean) over a rate series.

    Returns ``X`` of shape (n, history_bins) and ``y`` of shape (n,);
    empty (0-row) arrays when the series is shorter than one example."""
    H, K = cfg.history_bins, cfg.horizon_bins
    rates = np.asarray(rates, np.float64)
    n = rates.size - H - K + 1
    if n <= 0:
        return (np.zeros((0, H), np.float64), np.zeros(0, np.float64))
    windows = np.lib.stride_tricks.sliding_window_view(rates, H + K)[:n]
    X = windows[:, :H].copy()
    y = windows[:, H:].mean(axis=1)
    return X, y


def family_examples(family: str, seed: int, cfg: WindowConfig,
                    n_jobs: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Examples for one (family, seed): build the registry scenario, bin
    its arrival column, window it.  Deterministic end to end."""
    from repro.scenarios import build_scenario
    trace = build_scenario(family, seed=seed, n_jobs=n_jobs)
    return windowed_examples(bin_rates(trace.arrival_time, cfg.bin_s), cfg)


def is_val_seed(seed: int) -> bool:
    """Val membership: a pure function of the seed (every 4th seed), so
    the split needs no RNG and never drifts with iteration order."""
    return seed % 4 == 3


def make_dataset(families: Sequence[str], seeds: Sequence[int],
                 cfg: WindowConfig, n_jobs: Optional[int] = None
                 ) -> Dict[str, np.ndarray]:
    """Stacked train/val examples over families × seeds.

    Whole (family, seed) traces go to exactly one split (`is_val_seed`) —
    splitting within a trace would leak overlapping windows across the
    boundary."""
    tr_x, tr_y, va_x, va_y = [], [], [], []
    for family in families:
        for seed in seeds:
            X, y = family_examples(family, seed, cfg, n_jobs=n_jobs)
            if X.shape[0] == 0:
                continue
            (va_x if is_val_seed(seed) else tr_x).append(X)
            (va_y if is_val_seed(seed) else tr_y).append(y)
    H = cfg.history_bins
    empty = lambda: np.zeros((0, H), np.float64)     # noqa: E731
    return {
        "X_train": np.concatenate(tr_x) if tr_x else empty(),
        "y_train": np.concatenate(tr_y) if tr_y else np.zeros(0),
        "X_val": np.concatenate(va_x) if va_x else empty(),
        "y_val": np.concatenate(va_y) if va_y else np.zeros(0),
    }
