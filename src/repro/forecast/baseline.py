"""Numpy-only rate forecasters.

`EwmaForecaster` is the *online* forecaster the `PredictiveAutoscaler`
uses by default inside hermetic sweep cells: pure Python/float state, no
JAX, rebuildable from primitive knobs on the far side of a process pool.
`Ar1Baseline` is the closed-form offline baseline the evaluation harness
(scripts/forecast.py) scores the learned model against.

Both follow one forecaster contract (shared with
`repro.forecast.model.LearnedForecaster`):

* ``observe_bin(rate)`` — one closed arrival bin (jobs/s), in order;
* ``predict() -> (rate, confidence)`` — forecast for the next window,
  with confidence in [0, 1]; confidence 0.0 means "no usable forecast"
  and callers (the autoscaler's fallback contract) must degrade to pure
  reactive Alg. 5 behavior.

Confidence is one convention everywhere: an EW mean absolute error of
past one-step forecasts, normalized by the current level —
``conf = 1 / (1 + mae / (level + eps))`` — so an erratic series that the
forecaster keeps mispredicting talks itself out of prelaunching.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

_EPS = 1e-6


class EwmaForecaster:
    """Online EWMA level with EW-error confidence."""

    name = "ewma"

    def __init__(self, alpha: float = 0.35, err_alpha: float = 0.25,
                 warmup_bins: int = 4):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.err_alpha = err_alpha
        self.warmup_bins = warmup_bins
        self._level: Optional[float] = None
        self._mae = 0.0
        self._seen = 0

    def observe_bin(self, rate: float) -> None:
        rate = float(rate)
        if self._level is None:
            self._level = rate
        else:
            err = abs(rate - self._level)    # previous prediction == level
            self._mae += self.err_alpha * (err - self._mae)
            self._level += self.alpha * (rate - self._level)
        self._seen += 1

    def predict(self) -> Tuple[float, float]:
        if self._level is None or self._seen < self.warmup_bins:
            return 0.0, 0.0
        conf = 1.0 / (1.0 + self._mae / (self._level + _EPS))
        return self._level, conf


@dataclasses.dataclass(frozen=True)
class Ar1Baseline:
    """``y = mu + phi · (x_last - mu)`` fitted by least squares on the
    last history bin — the classic per-scenario AR(1) yardstick."""

    mu: float
    phi: float

    @classmethod
    def fit(cls, X: np.ndarray, y: np.ndarray) -> "Ar1Baseline":
        x = np.asarray(X, np.float64)[:, -1]
        y = np.asarray(y, np.float64)
        mu = float(x.mean()) if x.size else 0.0
        xc, yc = x - mu, y - mu
        denom = float(np.dot(xc, xc))
        phi = float(np.dot(xc, yc) / denom) if denom > 0 else 0.0
        return cls(mu=mu, phi=max(-1.0, min(1.0, phi)))

    def predict_batch(self, X: np.ndarray) -> np.ndarray:
        x = np.asarray(X, np.float64)[:, -1]
        return self.mu + self.phi * (x - self.mu)
