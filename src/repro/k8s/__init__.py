from repro.k8s.objects import Deployment, Job, from_manifest, to_pod_spec

__all__ = ["Deployment", "Job", "from_manifest", "to_pod_spec"]
