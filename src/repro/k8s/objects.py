"""Minimal in-process Kubernetes object model (paper §5.1).

Long-running services are *Deployments* (single replica in the paper's
initial scope) whose pod template may carry the ``rescheduling: moveable``
label; batch jobs are *Jobs* labelled ``type: batch``.  CPU/memory requests
must equal limits (guaranteed QoS class).  `from_manifest` accepts the
dict-form of the paper's Fig. 3/4 YAML files and yields `PodSpec`s for the
orchestrator.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.pods import PodKind, PodSpec
from repro.core.resources import Resources


def parse_cpu(s: str) -> int:
    """'100m' -> 100; '1' -> 1000 (millicores)."""
    s = str(s).strip()
    if s.endswith("m"):
        return int(s[:-1])
    return int(float(s) * 1000)


def parse_mem(s: str) -> float:
    """'1.4Gi' -> MB; '512Mi' -> MB."""
    s = str(s).strip()
    m = re.fullmatch(r"([\d.]+)(Gi|Mi|G|M)?", s)
    if not m:
        raise ValueError(f"bad memory quantity {s!r}")
    val = float(m.group(1))
    unit = m.group(2) or "Mi"
    return val * (1024.0 if unit in ("Gi", "G") else 1.0)


@dataclasses.dataclass(frozen=True)
class Deployment:
    name: str
    cpu: str
    memory: str
    moveable: bool = False
    scheduler_name: str = "customScheduler"

    def pod_spec(self) -> PodSpec:
        return PodSpec(self.name, PodKind.SERVICE,
                       Resources(parse_cpu(self.cpu), parse_mem(self.memory)),
                       moveable=self.moveable,
                       scheduler_name=self.scheduler_name)


@dataclasses.dataclass(frozen=True)
class Job:
    name: str
    cpu: str
    memory: str
    duration_s: float
    checkpointable: bool = False
    scheduler_name: str = "customScheduler"

    def pod_spec(self) -> PodSpec:
        return PodSpec(self.name, PodKind.BATCH,
                       Resources(parse_cpu(self.cpu), parse_mem(self.memory)),
                       duration_s=self.duration_s,
                       checkpointable=self.checkpointable,
                       scheduler_name=self.scheduler_name)


def to_pod_spec(obj) -> PodSpec:
    return obj.pod_spec()


def from_manifest(manifest: Dict) -> PodSpec:
    """Dict form of the paper's YAML (Fig. 3 deployment / Fig. 4 job)."""
    kind = manifest.get("kind", "")
    tmpl = manifest["spec"]["template"]
    meta = tmpl.get("metadata", {})
    labels = meta.get("labels", {})
    spec = tmpl["spec"] if "spec" in tmpl else tmpl
    container = spec["containers"][0]
    req = container["resources"]["requests"]
    lim = container["resources"].get("limits", req)
    if req != lim:
        raise ValueError("requests must equal limits (guaranteed QoS, §5.1)")
    cpu, mem = parse_cpu(req["cpu"]), parse_mem(req["memory"])
    name = manifest.get("metadata", {}).get("generateName",
                                            container.get("name", "pod"))
    name = name.rstrip("-")
    if kind == "Deployment":
        moveable = labels.get("rescheduling") == "moveable"
        return PodSpec(name, PodKind.SERVICE, Resources(cpu, mem),
                       moveable=moveable,
                       scheduler_name=spec.get("schedulerName",
                                               "customScheduler"))
    if kind == "Job":
        if labels.get("type") != "batch":
            raise ValueError("paper §5.1: jobs must be labelled type=batch")
        return PodSpec(name, PodKind.BATCH, Resources(cpu, mem),
                       duration_s=float(manifest.get("x-duration-s", 300.0)),
                       scheduler_name=spec.get("schedulerName",
                                               "customScheduler"))
    raise ValueError(f"unsupported kind {kind!r}")
