"""Masked-extremum select kernels for the many-world lane engine.

The inner decision of every wave placement is *select the first extremum
of a masked score buffer* — ``argmin``/``argmax`` over ``(lane, node)``
scores where infeasible nodes are masked out and ties break to the lowest
rank (serial: first extremum of a ±inf-filled NumPy buffer).  This module
provides that select as a batched ``(L, N) -> (L,)`` primitive in two
interchangeable backends:

* ``jnp`` (default) — ``jnp.argmin`` over a ``+inf``-filled buffer.  XLA
  guarantees first-occurrence tie-breaking, matching NumPy's ``argmin``.
* ``pallas`` — a Pallas kernel, one grid row per lane: two-stage reduce
  (min value, then min index among value-equal entries via a broadcasted
  iota) inside the kernel block.  On CPU the kernel runs in
  ``interpret=True`` mode, so tier-1 stays green without an accelerator;
  on TPU the same kernel compiles natively.

Both backends *minimize*.  Max-mode schedulers negate their scores before
the call — ``argmax(s) == argmin(-s)`` with ties preserved (negation is
exact and order-reversing on non-NaN floats, ``±inf`` fills swap roles).

Backend selection: the ``REPRO_MANYWORLD_SELECT`` environment variable
(``jnp`` | ``pallas``), read per call so tests can flip it; an explicit
``backend=`` argument overrides.

Rows whose mask is all-False return an arbitrary index (0 in practice):
callers must gate on ``mask.any(axis=1)`` — the same contract as the
serial path, where ``buf[argmin] == fill`` flags infeasibility.
"""
from __future__ import annotations

import functools
import os

ENV_FLAG = "REPRO_MANYWORLD_SELECT"
BACKENDS = ("jnp", "pallas")


def active_backend(backend: str | None = None) -> str:
    """Resolve the select backend: explicit arg > env flag > ``jnp``."""
    name = backend or os.environ.get(ENV_FLAG, "jnp")
    if name not in BACKENDS:
        raise ValueError(
            f"unknown {ENV_FLAG}={name!r}; expected one of {BACKENDS}")
    return name


def masked_argmin(scores, mask, backend: str | None = None):
    """First index of the masked minimum, per lane.

    ``scores`` is ``(L, N)`` float64, ``mask`` ``(L, N)`` bool; returns
    ``(L,)`` int32.  Only rows with ``mask.any()`` are meaningful.
    """
    if active_backend(backend) == "pallas":
        return _pallas_argmin(scores, mask)
    return _jnp_argmin(scores, mask)


def _jnp_argmin(scores, mask):
    import jax.numpy as jnp
    buf = jnp.where(mask, scores, jnp.inf)
    return jnp.argmin(buf, axis=1).astype(jnp.int32)


def _pallas_argmin_kernel(scores_ref, mask_ref, out_ref, *, n_nodes: int):
    # One lane per grid row: block shapes are (1, N) in / (1, 1) out.
    import jax
    import jax.numpy as jnp
    s = scores_ref[...]
    m = mask_ref[...] != 0
    buf = jnp.where(m, s, jnp.inf)
    v = jnp.min(buf, axis=1, keepdims=True)           # (1, 1)
    # First occurrence: min iota among value-equal entries.  2-D iota via
    # broadcasted_iota (TPU-safe; 1-D iota is not).
    idx = jax.lax.broadcasted_iota(jnp.int32, buf.shape, 1)
    hit = jnp.where(buf == v, idx, n_nodes)
    out_ref[...] = jnp.min(hit, axis=1, keepdims=True)


@functools.lru_cache(maxsize=None)
def _pallas_call(n_lanes: int, n_nodes: int, interpret: bool):
    import functools as ft

    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    return pl.pallas_call(
        ft.partial(_pallas_argmin_kernel, n_nodes=n_nodes),
        grid=(n_lanes,),
        in_specs=[
            pl.BlockSpec((1, n_nodes), lambda i: (i, 0)),
            pl.BlockSpec((1, n_nodes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_lanes, 1), jnp.int32),
        interpret=interpret,
    )


def _pallas_argmin(scores, mask):
    import jax
    import jax.numpy as jnp
    n_lanes, n_nodes = scores.shape
    interpret = jax.default_backend() == "cpu"
    call = _pallas_call(n_lanes, n_nodes, interpret)
    out = call(scores, mask.astype(jnp.int8))
    return out[:, 0]
