"""Many-world lane engine: thousands of simulations as one JAX program.

One *lane* is one full static-cluster experiment — trace, scheduler,
fleet size — and a batch of lanes runs as a single jit-compiled program
over stacked ``(lane, node)`` / ``(lane, pod)`` arrays.  The program is
the cycle hot path of the serial engine lowered to fixed shapes:

* an outer ``lax.while_loop`` advances the 10 s scheduling cycle for all
  lanes in lockstep, bailing out as soon as every lane is finished
  (completed, stuck, or quiescent) or the 48 h horizon is reached;
* a completion inner loop commits due batch completions **one pod per
  lane per step** in ``(done_time, bind_seq)`` order — the serial event
  order — so the per-node ``used_*`` running floats stay bit-identical
  (summation order matters; a segment-sum would not);
* a bind inner loop walks the pending snapshot in FIFO (row) order, one
  pod per lane per step: feasibility mask, scheduler score, first-extremum
  select (``repro.manyworld.select``; Pallas kernel or jnp backend), then
  the serial accounting ops ``used += req`` / ``free = alloc - used``.

**Relaxed-semantics envelope.**  Lanes model the void/void static-cluster
regime only: no autoscaler, no rescheduler, no chaos, homogeneous READY
fleet billed from t=0, speed factor 1.  Everything else — event ordering,
tie-breaks, stuck detection, blocked-pod scale-out counting — follows the
serial engine exactly; ``repro.manyworld.evaluator`` reconstructs full
``ExperimentResult`` rows host-side from the lane outputs.  See
ARCHITECTURE.md "Many-world lanes" for the contract and the enumerated
divergences.

**Float discipline.**  All arithmetic the serial engine does in float64
is done in float64 (``jax.experimental.enable_x64``).  Integer request
columns become float64 — exact below 2^53, so comparisons and the k8s
fraction divides are bit-identical.  XLA's CPU backend contracts
``a*b + c`` into a fused multiply-add, which would change score bits
vs NumPy; every product feeding an add goes through :func:`_fence`
(a data-dependent ``where`` LLVM cannot contract across).  Masked
scatter updates add ``±0.0`` on inactive lanes, which is a bitwise
no-op because the engine's ``used`` values are never ``-0.0``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from repro.manyworld import select as _select

CYCLE_PERIOD_S = 10.0
HORIZON_S = 48 * 3600.0          # SimConfig.max_sim_time_s default
MAX_CYCLES = int(HORIZON_S / CYCLE_PERIOD_S)   # cycle at t == horizon runs

SCHEDULERS = ("best-fit", "worst-fit", "first-fit", "k8s-default", "weighted")

# bind_seq fill for "no completion candidate" (any value > every real seq).
_SEQ_INF = np.int32(2**31 - 1)


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) — the padding quantum that keeps
    the jit cache small (one compile per (scheduler, N, P) bucket)."""
    p = 1
    while p < max(n, 1):
        p *= 2
    return p


@dataclasses.dataclass
class LaneBatch:
    """Stacked fixed-shape inputs for one compiled many-world program.

    Pod axis is padded to ``p_pad`` (``valid`` masks real rows), node axis
    to ``n_pad`` (``n_nodes`` masks real nodes); every lane in a batch
    shares one scheduler.  Build via :func:`stack_lanes`.
    """

    scheduler: str
    arrival_t: np.ndarray     # (L, P) f64, +inf padded
    cpu_m: np.ndarray         # (L, P) f64
    mem_mb: np.ndarray        # (L, P) f64
    duration_s: np.ndarray    # (L, P) f64
    is_batch: np.ndarray      # (L, P) bool
    valid: np.ndarray         # (L, P) bool
    n_nodes: np.ndarray       # (L,)  i32
    alloc_cpu: np.ndarray     # (L,)  f64
    alloc_mem: np.ndarray     # (L,)  f64
    weights: np.ndarray       # (L, 3) f64 (weighted scheduler; else pack)

    @property
    def n_lanes(self) -> int:
        return self.arrival_t.shape[0]

    @property
    def p_pad(self) -> int:
        return self.arrival_t.shape[1]

    @property
    def n_pad(self) -> int:
        return next_pow2(int(self.n_nodes.max()) if self.n_nodes.size else 1)


def stack_lanes(lanes, scheduler: str, p_pad: Optional[int] = None
                ) -> LaneBatch:
    """Stack per-lane dicts (``TraceStore.to_lane_arrays`` output plus
    cluster scalars ``n_nodes`` / ``alloc_cpu`` / ``alloc_mem`` and an
    optional ``weights`` 3-tuple) into one padded :class:`LaneBatch`."""
    if scheduler not in SCHEDULERS:
        raise ValueError(f"unsupported lane scheduler {scheduler!r}")
    n_max = max((int(d["arrival_t"].size) for d in lanes), default=0)
    P = p_pad if p_pad is not None else next_pow2(n_max)
    if n_max > P:
        raise ValueError(f"p_pad={P} < largest lane ({n_max} pods)")
    L = len(lanes)
    arr = np.full((L, P), np.inf)
    cpu = np.zeros((L, P))
    mem = np.zeros((L, P))
    dur = np.zeros((L, P))
    isb = np.zeros((L, P), bool)
    val = np.zeros((L, P), bool)
    n_nodes = np.zeros(L, np.int32)
    a_cpu = np.zeros(L)
    a_mem = np.zeros(L)
    wts = np.zeros((L, 3))
    for i, d in enumerate(lanes):
        n = int(d["arrival_t"].size)
        arr[i, :n] = d["arrival_t"]
        cpu[i, :n] = d["cpu_m"]
        mem[i, :n] = d["mem_mb"]
        dur[i, :n] = d["duration_s"]
        isb[i, :n] = d["is_batch"]
        val[i, :n] = True
        n_nodes[i] = d["n_nodes"]
        a_cpu[i] = d["alloc_cpu"]
        a_mem[i] = d["alloc_mem"]
        w = d.get("weights")
        wts[i] = (1.0, 0.0, 0.0) if w is None else tuple(w)
    return LaneBatch(scheduler, arr, cpu, mem, dur, isb, val,
                     n_nodes, a_cpu, a_mem, wts)


def _fence(t):
    """Contraction fence: route a product through a data-dependent select
    so LLVM cannot fuse it into a following add (``a*b + c -> fma`` would
    change score bits vs the serial NumPy path).  ``isfinite`` is always
    True for real scores, so the value is unchanged."""
    import jax.numpy as jnp
    return jnp.where(jnp.isfinite(t), t, jnp.inf)


def _wave_scores(sched: str, free_cpu, free_mem, alloc_cpu, alloc_mem,
                 pc, pm, weights):
    """Per-node scores for one pod per lane, **negated for max-mode** so a
    single masked-argmin select serves every policy.  Formulas are the
    serial ``Scheduler.wave_scores`` ops verbatim (same order, float64);
    ``pc``/``pm`` are the pod's request broadcast to ``(L, 1)``.
    """
    import jax.numpy as jnp
    if sched == "best-fit":
        return free_mem                       # min free_mem
    if sched == "worst-fit":
        return -free_mem                      # max free_mem
    if sched == "first-fit":
        return jnp.zeros_like(free_mem)       # first feasible rank
    # k8s-default / weighted share the request-fraction core (serial:
    # int64 subtract then true-divide -> f64; these columns are already
    # f64-exact ints, so subtract/divide bits match).
    cpu_frac = (free_cpu - pc) / jnp.maximum(alloc_cpu, 1.0)
    mem_frac = (free_mem - pm) / jnp.maximum(alloc_mem, 1e-9)
    # Both blend terms are fenced: XLA rewrites the trailing /2.0 into
    # *0.5 and would contract either term's product into an FMA with the
    # (lr + bal) add otherwise, shifting the last ulp vs NumPy.
    least_requested = _fence(10.0 * (cpu_frac + mem_frac) / 2.0)
    balanced = _fence(10.0 * (1.0 - jnp.abs(cpu_frac - mem_frac)))
    if sched == "k8s-default":
        return -((least_requested + balanced) / 2.0)
    # weighted: w_pack*pack + w_lr*lr + w_bal*bal, left-to-right adds.
    # pack is fenced like the other composite terms — unfenced, XLA
    # rewrites the nested w*(10*(1-x)) chain non-IEEE.
    pack = _fence(10.0 * (1.0 - mem_frac))
    s = (_fence(weights[:, 0:1] * pack)
         + _fence(weights[:, 1:2] * least_requested)
         ) + _fence(weights[:, 2:3] * balanced)
    return -s


def _program_factory(sched: str, backend: str, n_pad: int):
    """Build the jitted many-world program for one (scheduler, select
    backend, padded node count); XLA retraces per (L, P) bucket."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def select(scores, mask):
        return _select.masked_argmin(scores, mask, backend)

    def run(arr_t, cpu, mem, dur, isb, valid, n_nodes,
            alloc_cpu, alloc_mem, weights):
        L, P = arr_t.shape
        li = jnp.arange(L)
        node_active = (jnp.arange(n_pad, dtype=jnp.int32)[None, :]
                       < n_nodes[:, None])                    # (L, N)
        ac = alloc_cpu[:, None]
        am = alloc_mem[:, None]

        def completions(t, st):
            """Commit due batch completions one pod per lane per step, in
            (done_time, bind_seq) order — the serial POD_DONE event order
            (heap pops ascending time; push order == bind order within a
            timestamp)."""
            def due_of(c):
                done_c, done_t, bound, active = c[3], c[4], c[5], c[9]
                return (valid & isb & bound & ~done_c
                        & (done_t <= t) & active[:, None])

            def cond(c):
                return due_of(c).any()

            def body(c):
                (used_cpu, used_mem, pcount, done_c, done_t, bound,
                 bind_node, bind_seq, bind_cycle, active, completed,
                 done_time, done_is_cycle) = c
                due = due_of(c)
                has = due.any(axis=1)
                # Two-stage extremum: earliest done_time, then lowest
                # bind_seq among its ties (seq is unique per lane).
                t1 = jnp.where(due, done_t, jnp.inf)
                tmin = t1.min(axis=1, keepdims=True)
                s1 = jnp.where(due & (t1 == tmin), bind_seq, _SEQ_INF)
                p = jnp.argmin(s1, axis=1)
                node = jnp.where(has, bind_node[li, p], 0)
                dc = jnp.where(has, cpu[li, p], 0.0)
                dm = jnp.where(has, mem[li, p], 0.0)
                # serial: node._used_* -= req, one pod at a time.
                used_cpu = used_cpu.at[li, node].add(-dc)
                used_mem = used_mem.at[li, node].add(-dm)
                pcount = pcount.at[li, node].add(-has.astype(jnp.int32))
                done_c = done_c.at[li, p].set(done_c[li, p] | has)
                # _done() check after this POD_DONE event: all arrived at
                # the *event's* time, every batch row committed, every
                # service bound.
                td = jnp.where(has, done_t[li, p], jnp.inf)
                arrived_td = (~valid | (arr_t <= td[:, None])).all(axis=1)
                batch_done = (~valid | ~isb | done_c).all(axis=1)
                svc_bound = (~valid | isb | bound).all(axis=1)
                now_done = has & active & arrived_td & batch_done & svc_bound
                completed = completed | now_done
                done_time = jnp.where(now_done, td, done_time)
                active = active & ~now_done
                return (used_cpu, used_mem, pcount, done_c, done_t, bound,
                        bind_node, bind_seq, bind_cycle, active, completed,
                        done_time, done_is_cycle)

            return lax.while_loop(cond, body, st)

        def wave(t, k, st):
            """One scheduling cycle's wave: walk the pending snapshot in
            row (FIFO) order, one pod per lane per step.  Blocked pods are
            counted (the serial void/void fallback bumps one scale-out
            request per blocked pod) and skipped — decision-identical to
            the serial blocked_keys latch, which only memoizes the same
            outcome (working frees never grow inside a cycle)."""
            (used_cpu, used_mem, pcount, done_c, done_t, bound,
             bind_node, bind_seq, bind_cycle, active, completed,
             done_time, done_is_cycle, seq_ctr, scale_outs) = st
            arrived = valid & (arr_t <= t)

            def cand_of(c):
                bound, attempted = c[2], c[8]
                return arrived & ~bound & ~attempted & active[:, None]

            def cond(c):
                return cand_of(c).any()

            def body(c):
                (used_cpu, used_mem, bound, bind_node, bind_seq,
                 bind_cycle, done_t, pcount, attempted, placed, blocked,
                 seq_ctr) = c
                cand = cand_of(c)
                has = cand.any(axis=1)
                p = jnp.argmax(cand, axis=1)       # first pending row
                pc = cpu[li, p][:, None]
                pm = mem[li, p][:, None]
                # serial WavePlacer: free = alloc - used (elementwise);
                # fits = (free_cpu >= cpu) & (free_mem + 1e-9 >= mem).
                free_cpu = ac - used_cpu
                free_mem = am - used_mem
                mask = ((free_cpu >= pc) & ((free_mem + 1e-9) >= pm)
                        & node_active)
                scores = _wave_scores(sched, free_cpu, free_mem, ac, am,
                                      pc, pm, weights)
                r = select(scores, mask)
                feas = mask.any(axis=1)
                do = has & feas
                blk = has & ~feas
                r_g = jnp.where(do, r, 0).astype(jnp.int32)
                add_c = jnp.where(do, pc[:, 0], 0.0)
                add_m = jnp.where(do, pm[:, 0], 0.0)
                used_cpu = used_cpu.at[li, r_g].add(add_c)
                used_mem = used_mem.at[li, r_g].add(add_m)
                pcount = pcount.at[li, r_g].add(do.astype(jnp.int32))
                bound = bound.at[li, p].set(bound[li, p] | do)
                bind_node = bind_node.at[li, p].set(
                    jnp.where(do, r_g, bind_node[li, p]))
                bind_seq = bind_seq.at[li, p].set(
                    jnp.where(do, seq_ctr, bind_seq[li, p]))
                bind_cycle = bind_cycle.at[li, p].set(
                    jnp.where(do, k, bind_cycle[li, p]))
                # Completion timestamp: now + duration (speed factor 1);
                # services never complete (+inf).
                td = jnp.where(do & isb[li, p], t + dur[li, p], jnp.inf)
                done_t = done_t.at[li, p].set(
                    jnp.where(do, td, done_t[li, p]))
                seq_ctr = seq_ctr + do.astype(jnp.int32)
                placed = placed + do.astype(jnp.int32)
                blocked = blocked + blk.astype(jnp.int32)
                attempted = attempted.at[li, p].set(attempted[li, p] | has)
                return (used_cpu, used_mem, bound, bind_node, bind_seq,
                        bind_cycle, done_t, pcount, attempted, placed,
                        blocked, seq_ctr)

            zeros_i = jnp.zeros(L, jnp.int32)
            (used_cpu, used_mem, bound, bind_node, bind_seq, bind_cycle,
             done_t, pcount, _att, placed, blocked, seq_ctr
             ) = lax.while_loop(
                cond, body,
                (used_cpu, used_mem, bound, bind_node, bind_seq,
                 bind_cycle, done_t, pcount, jnp.zeros_like(bound),
                 zeros_i, zeros_i, seq_ctr))
            scale_outs = scale_outs + blocked

            # -- post-cycle bookkeeping (serial order: wave stats, the
            # _done() check after the CYCLE event, then stuck detection).
            all_arrived = (~valid | (arr_t <= t)).all(axis=1)
            pending_after = (arrived & ~bound).any(axis=1)
            running_batch = (valid & isb & bound & ~done_c).any(axis=1)
            batch_done = (~valid | ~isb | done_c).all(axis=1)
            svc_bound = (~valid | isb | bound).all(axis=1)
            has_pods = valid.any(axis=1)
            done_b = (active & has_pods & all_arrived & batch_done
                      & svc_bound)
            completed = completed | done_b
            done_time = jnp.where(done_b, t, done_time)
            done_is_cycle = done_is_cycle | done_b
            active = active & ~done_b
            # _permanently_stuck: static cluster, everything arrived,
            # nothing placed, something blocked, nothing running.
            stuck_now = (active & all_arrived & (placed == 0)
                         & (blocked > 0) & ~running_batch & pending_after)
            active = active & ~stuck_now
            # Quiescent: all arrived, nothing pending, nothing running,
            # not done (zero-pod lanes) — state can never change again;
            # the lane just samples to the horizon (host-side).
            quies = active & all_arrived & ~pending_after & ~running_batch
            active = active & ~quies
            return (used_cpu, used_mem, pcount, done_c, done_t, bound,
                    bind_node, bind_seq, bind_cycle, active, completed,
                    done_time, done_is_cycle, seq_ctr, scale_outs)

        def cycle_body(st):
            k = st[0]
            t = k.astype(jnp.float64) * CYCLE_PERIOD_S
            # POD_DONE events at times <= t all fire before CYCLE(t).
            mid = completions(t, st[1:14])
            out = wave(t, k, mid + st[14:])
            return (k + 1,) + out

        def cycle_cond(st):
            k, active = st[0], st[10]
            return active.any() & (k <= MAX_CYCLES)

        init = (
            jnp.zeros((), jnp.int32),                      # k
            jnp.zeros((L, n_pad)),                         # used_cpu
            jnp.zeros((L, n_pad)),                         # used_mem
            jnp.zeros((L, n_pad), jnp.int32),              # pcount
            jnp.zeros((L, P), bool),                       # done_c
            jnp.full((L, P), jnp.inf),                     # done_t
            jnp.zeros((L, P), bool),                       # bound
            jnp.full((L, P), -1, jnp.int32),               # bind_node
            jnp.full((L, P), -1, jnp.int32),               # bind_seq
            jnp.full((L, P), -1, jnp.int32),               # bind_cycle
            valid.any(axis=1),                             # active
            jnp.zeros(L, bool),                            # completed
            jnp.full(L, HORIZON_S),                        # done_time
            jnp.zeros(L, bool),                            # done_is_cycle
            jnp.zeros(L, jnp.int32),                       # seq_ctr
            jnp.zeros(L, jnp.int32),                       # scale_outs
        )
        (k, used_cpu, used_mem, pcount, done_c, done_t, bound,
         bind_node, bind_seq, bind_cycle, active, completed, done_time,
         done_is_cycle, seq_ctr, scale_outs) = lax.while_loop(
            cycle_cond, cycle_body, init)
        return {
            "bound": bound, "done_committed": done_c,
            "bind_node": bind_node, "bind_seq": bind_seq,
            "bind_cycle": bind_cycle, "done_t": done_t,
            "completed": completed, "done_time": done_time,
            "done_is_cycle": done_is_cycle, "scale_outs": scale_outs,
            "n_cycles": k, "used_cpu": used_cpu, "used_mem": used_mem,
            "pcount": pcount,
        }

    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _jit_cache(sched: str, backend: str, n_pad: int):
    return _program_factory(sched, backend, n_pad)


def run_lane_batch(batch: LaneBatch, backend: Optional[str] = None) -> dict:
    """Execute one :class:`LaneBatch`; returns numpy lane outputs.

    Per lane: ``completed`` / ``done_time`` / ``done_is_cycle`` /
    ``scale_outs``; per pod: ``bound``, ``bind_node`` (node *rank* —
    serial parity maps ``node_slot`` through ``ClusterArrays.id_rank``),
    ``bind_seq`` (per-lane bind order), ``bind_cycle`` (bind time is
    exactly ``bind_cycle * 10.0``), ``done_t`` and ``done_committed``.
    """
    from jax.experimental import enable_x64
    backend = _select.active_backend(backend)
    with enable_x64():
        import jax.numpy as jnp
        run = _jit_cache(batch.scheduler, backend, batch.n_pad)
        out = run(jnp.asarray(batch.arrival_t), jnp.asarray(batch.cpu_m),
                  jnp.asarray(batch.mem_mb), jnp.asarray(batch.duration_s),
                  jnp.asarray(batch.is_batch), jnp.asarray(batch.valid),
                  jnp.asarray(batch.n_nodes), jnp.asarray(batch.alloc_cpu),
                  jnp.asarray(batch.alloc_mem), jnp.asarray(batch.weights))
        return {key: np.asarray(v) for key, v in out.items()}
