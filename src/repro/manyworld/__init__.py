"""Many-world lanes: batched JAX evaluation of independent simulations.

An explicitly-flagged fast path that runs thousands of void/void
static-cluster experiment *lanes* as one jit-compiled program — see
`repro.manyworld.lanes` for the engine and its relaxed-semantics
contract, `repro.manyworld.select` for the masked-extremum select
kernels (jnp / Pallas), and `repro.manyworld.evaluator` for the
``run_cells(..., workers="lanes")`` backend that reconstructs serial
bit-identical result rows.  Importing this package does **not** import
JAX; the engine modules import it lazily on first use.
"""
from repro.manyworld.lanes import (LaneBatch, next_pow2, run_lane_batch,
                                   stack_lanes)

__all__ = ["LaneBatch", "next_pow2", "run_lane_batch", "stack_lanes",
           "lane_eligible", "run_cells_lanes"]


def __getattr__(name):
    # evaluator pulls in repro.search lazily; avoid import cycles at
    # package-import time.
    if name in ("lane_eligible", "run_cells_lanes"):
        from repro.manyworld import evaluator
        return getattr(evaluator, name)
    raise AttributeError(name)
