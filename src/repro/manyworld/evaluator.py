"""Lane-batched cell evaluator: ``run_cells`` rows from the lane engine.

``run_cells_lanes`` is the drop-in backend behind
``repro.search.runner.run_cells(..., workers="lanes")``: it takes the same
cell list and returns the same row dicts in the same order, but evaluates
every *lane-eligible* cell inside batched JAX programs
(`repro.manyworld.lanes`) instead of one serial simulation per cell.

**Eligibility** is the lane engine's relaxed-semantics envelope — the
void/void static-cluster regime (:func:`lane_eligible`).  Anything
outside it (autoscalers, reschedulers, chaos, the object engine) falls
back to the serial ``run_cell`` transparently, so a mixed cell list still
returns one complete row list.  If JAX is unavailable the whole list
falls back serially with a warning.

**Exactness.**  For eligible cells the rows are bit-identical to
``run_cell`` (except ``wall_s``, which is wall time and is reported as
the lane's share of its batch).  The lane program reproduces the bind
sequence exactly; this module reconstructs the remaining
``ExperimentResult`` metrics host-side by replaying the serial event
semantics over the lane outputs:

* pending intervals are ``bind_time - submit_time`` per bound row in
  row order (the serial end-of-run column walk);
* the 20 s utilisation samples are replayed with a pointer walk over the
  bind/completion events in serial processing order — the event order
  and the sample-tie rules (arrivals win ties; ``POD_DONE(t)`` precedes
  ``CYCLE(t)``; ``SAMPLE(t)`` ordering against both depends on push
  time) decide exactly which events each sample sees and which sample is
  the last one recorded before a completed run breaks;
* cost/node-seconds use the serial CostModel formulas for a static fleet
  billed from t=0 (one ``ceil`` per node, left-to-right accumulation).

Buckets: lanes group by ``(scheduler, pod-pad, node-pad)`` with
power-of-two pads, so the jit cache stays small while mixed workloads
share compilations.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
import time
import warnings
from typing import List, Optional, Sequence

import numpy as np

from repro.manyworld import lanes as _lanes
from repro.manyworld.lanes import (CYCLE_PERIOD_S, HORIZON_S, SCHEDULERS,
                                   next_pow2)

SAMPLE_PERIOD_S = 20.0


def lane_eligible(cell) -> bool:
    """True when ``cell`` is inside the lane engine's relaxed envelope:
    a void/void static cluster (no autoscaler, no rescheduler, no chaos)
    on the array engine with a supported scheduler.  Weight validation is
    left to the serial path so invalid specs raise the serial error."""
    if cell.autoscaler != "void" or cell.rescheduler != "void":
        return False
    if cell.chaos:
        return False
    if cell.engine not in (None, "array"):
        return False
    if cell.scheduler not in SCHEDULERS:
        return False
    if cell.initial_workers < 1:
        return False
    w = cell.scheduler_weights
    if w is not None:
        if cell.scheduler != "weighted" or len(w) != 3:
            return False          # serial raises; keep that behavior
        if not (sum(w) > 0.0) or min(w) < 0.0:
            return False
    return True


def _template_of(cell):
    from repro.cloud.adapter import M2_SMALL, NODE_TEMPLATES
    return (NODE_TEMPLATES[cell.template_name]
            if cell.template_name is not None else M2_SMALL)


_CELL_FIELDS: tuple = ()


def _cell_dict(cell) -> dict:
    """`dataclasses.asdict(cell)` minus the recursive deepcopy walk —
    every `CellSpec` field is a primitive or a flat tuple, for which
    `asdict` returns the value unchanged, so a getattr sweep builds an
    `==`-identical dict at a fraction of the cost (the serial `run_cell`
    row this must match bit-for-bit uses `asdict`)."""
    global _CELL_FIELDS
    if not _CELL_FIELDS:
        _CELL_FIELDS = tuple(f.name for f in dataclasses.fields(cell))
    return {name: getattr(cell, name) for name in _CELL_FIELDS}


def _base_row(cell, trace, infeasible: bool) -> dict:
    from repro.search.runner import _RESULT_FIELDS
    row = {"label": cell.label, "cell": _cell_dict(cell),
           "n_jobs": trace.n, "infeasible": infeasible}
    if infeasible:
        for field in _RESULT_FIELDS:
            row[field] = False if field == "completed" else 0
        row["wall_s"] = 0.0
    return row


def _grid_after(t: float) -> float:
    """Smallest sample-grid time strictly greater than ``t``."""
    return (math.floor(t / SAMPLE_PERIOD_S) + 1.0) * SAMPLE_PERIOD_S


def _on_grid(t: float) -> bool:
    return math.fmod(t, SAMPLE_PERIOD_S) == 0.0


def _lane_metrics(cell, trace, template, o: dict) -> dict:
    """Reconstruct one cell's ExperimentResult fields from lane outputs.

    ``o`` holds this lane's slices: per-pod ``bound`` / ``bind_node`` /
    ``bind_seq`` / ``bind_cycle`` / ``done_t`` / ``done_committed`` and
    per-lane ``completed`` / ``done_time`` / ``done_is_cycle`` /
    ``scale_outs``.  Every formula below is the serial one, applied in
    the serial order.
    """
    n = trace.n
    n_nodes = cell.initial_workers
    alloc_cpu = float(template.allocatable.cpu_m)
    alloc_mem = float(template.allocatable.mem_mb)
    price = float(template.price_per_s)

    bound = o["bound"][:n]
    committed = o["done_committed"][:n]
    bind_t = o["bind_cycle"][:n].astype(np.float64) * CYCLE_PERIOD_S
    done_t = o["done_t"][:n]
    seq = o["bind_seq"][:n]
    node = o["bind_node"][:n]
    cpu = trace.cpu_m.astype(np.float64)
    mem = trace.mem_mb.astype(np.float64)
    completed = bool(o["completed"])
    done_time = float(o["done_time"])

    # -- end of run (simulation.run: last_batch_done wins when truthy) --
    if completed:
        lbd = float(done_t[committed].max()) if committed.any() else 0.0
        end = lbd if lbd else done_time
        te = done_time
    else:
        end = HORIZON_S            # samples run the clock to the horizon
        te = None

    arr0 = float(trace.arrival_time[0]) if n else None
    start = arr0 if (arr0 is not None and arr0 <= HORIZON_S) else 0.0

    # -- pending intervals (store.pending_intervals_all: bound rows only,
    # row order; void/void never rebinds so one interval per pod) --------
    pend = (bind_t[bound] - trace.arrival_time[bound].astype(np.float64)
            ).tolist()

    # -- utilisation sample replay --------------------------------------
    # Events in serial processing order: (time, kind, bind_seq) with
    # POD_DONE (0) before the cycle's binds (1) at equal times; equal-time
    # completions fire in scheduling-push order == ascending bind_seq.
    # Each event carries the first sample time that can see it:
    # * a bind at cycle tc is visible from the next grid point after tc
    #   (SAMPLE(t) runs before CYCLE(t) for t>0) — except cycle 0, whose
    #   binds sample at t=0 (run() pushes CYCLE(0) before SAMPLE(0));
    # * a completion at td is visible from td itself when td is on-grid
    #   and its POD_DONE was pushed (at its bind cycle tc) before
    #   SAMPLE(td) was (at td-20) — i.e. tc < td-20, or the cycle-0
    #   corner tc==0, td==20 — else from the next grid point after td.
    SP = SAMPLE_PERIOD_S
    bi = np.nonzero(bound)[0]
    tb = bind_t[bi]
    sv_b = np.where(tb == 0.0, 0.0, (np.floor(tb / SP) + 1.0) * SP)
    di = np.nonzero(committed)[0]
    td_a = done_t[di]
    tc_a = bind_t[di]
    done_early = ((np.fmod(td_a, SP) == 0.0)
                  & ((tc_a < td_a - SP) | ((tc_a == 0.0) & (td_a == SP))))
    sv_d = np.where(done_early, td_a, (np.floor(td_a / SP) + 1.0) * SP)
    ev_t = np.concatenate([td_a, tb])
    ev_kind = np.concatenate([np.zeros(di.size, np.int8),
                              np.ones(bi.size, np.int8)])
    ev_seq = np.concatenate([seq[di], seq[bi]])
    order = np.lexsort((ev_seq, ev_kind, ev_t))
    ev_sv = np.concatenate([sv_d, sv_b])[order].tolist()
    ev_node = np.concatenate([node[di], node[bi]])[order].tolist()
    ev_dcpu = np.concatenate([-cpu[di], cpu[bi]])[order].tolist()
    ev_dmem = np.concatenate([-mem[di], mem[bi]])[order].tolist()
    ev_dp = np.concatenate([np.full(di.size, -1), np.ones(bi.size)]
                           )[order].astype(np.int64).tolist()
    n_ev = len(ev_sv)

    # Which samples were recorded before the run ended?  Non-completed
    # lanes sample the whole horizon.  A completed lane breaks on its
    # trigger event at te: every grid point strictly before te is in; the
    # grid point *at* te is in iff the trigger ran after SAMPLE(te) —
    # for a CYCLE trigger that is every te>0, for a POD_DONE trigger it
    # is the complement of the completion-visibility push rule above,
    # judged on the trigger pod (the last-committed one).
    if not completed:
        last_s = HORIZON_S
    else:
        if _on_grid(te) and te > 0.0:
            if o["done_is_cycle"]:
                last_s = te
            else:
                ic = np.nonzero(committed)[0]
                trig = ic[np.lexsort((seq[ic], done_t[ic]))[-1]]
                tc = float(bind_t[trig])
                pod_done_first = (tc < te - SAMPLE_PERIOD_S
                                  or (tc == 0.0 and te == SAMPLE_PERIOD_S))
                last_s = te if not pod_done_first else te - SAMPLE_PERIOD_S
        else:
            last_s = (math.ceil(te / SAMPLE_PERIOD_S) - 1.0) * SAMPLE_PERIOD_S
            if _on_grid(te):       # te == 0: CYCLE(0) broke before SAMPLE(0)
                last_s = te - SAMPLE_PERIOD_S

    ram_vals: List[float] = []
    cpu_vals: List[float] = []
    ppn_vals: List[float] = []
    used_cpu = [0.0] * n_nodes
    used_mem = [0.0] * n_nodes
    pods = 0
    acpu = max(alloc_cpu, 1)       # serial: np.maximum(alloc_cpu, 1)
    ptr = 0
    s = 0.0
    while s <= last_s:
        while ptr < n_ev and ev_sv[ptr] <= s:
            nd = ev_node[ptr]
            used_cpu[nd] += ev_dcpu[ptr]
            used_mem[nd] += ev_dmem[ptr]
            pods += ev_dp[ptr]
            ptr += 1
        # Serial sampler: exact fsum of per-node IEEE ratios, / n.
        cur_ram = math.fsum(u / alloc_mem for u in used_mem) / n_nodes
        cur_cpu = math.fsum(u / acpu for u in used_cpu) / n_nodes
        cur_ppn = float(pods) / n_nodes
        # `ev_sv` is non-decreasing in commit order, so the state stays
        # constant until the next event becomes visible (or the run
        # ends): emit the whole constant run of samples in one extend.
        if ptr == n_ev or ev_sv[ptr] > last_s:
            run_end = last_s
        else:
            run_end = ev_sv[ptr] - SAMPLE_PERIOD_S
        m = int((run_end - s) / SAMPLE_PERIOD_S) + 1
        ram_vals.extend([cur_ram] * m)
        cpu_vals.extend([cur_cpu] * m)
        ppn_vals.extend([cur_ppn] * m)
        s += m * SAMPLE_PERIOD_S

    # -- cost (CostModel: N static nodes billed 0 -> end, ceil'd, summed
    # left-to-right in record order) ------------------------------------
    secs = float(np.ceil(np.maximum(0.0, np.float64(end))))
    term = float(np.float64(secs) * np.float64(price))
    cost = 0.0
    for _ in range(n_nodes):
        cost += term
    node_seconds = int(secs * n_nodes)

    return {
        "completed": completed,
        "cost": cost,
        "duration_s": end - start,
        "mean_pending_s": statistics.fmean(pend) if pend else 0.0,
        "median_pending_s": statistics.median(pend) if pend else 0.0,
        "max_pending_s": max(pend) if pend else 0.0,
        "avg_ram_ratio": statistics.fmean(ram_vals) if ram_vals else 0.0,
        "avg_cpu_ratio": statistics.fmean(cpu_vals) if cpu_vals else 0.0,
        "avg_pods_per_node": statistics.fmean(ppn_vals) if ppn_vals else 0.0,
        "max_nodes": n_nodes if ram_vals else 0,
        "node_seconds": node_seconds,
        "evictions": 0,
        "scale_outs": int(o["scale_outs"]),
        "scale_ins": 0,
        "failures_injected": 0,
        "preemption_notices": 0,
        "lost_work_s": 0.0,
    }


def _zero_pod_metrics(cell, template) -> dict:
    """A lane with an empty trace never completes: the empty static
    cluster just samples flat zeros to the horizon (handled without JAX)."""
    o = {"bound": np.zeros(0, bool), "done_committed": np.zeros(0, bool),
         "bind_cycle": np.zeros(0, np.int32), "done_t": np.zeros(0),
         "bind_seq": np.zeros(0, np.int32), "bind_node": np.zeros(0, np.int32),
         "completed": False, "done_time": HORIZON_S, "done_is_cycle": False,
         "scale_outs": 0}
    empty = _EmptyTrace()
    return _lane_metrics(cell, empty, template, o)


class _EmptyTrace:
    n = 0
    arrival_time = np.zeros(0)
    cpu_m = np.zeros(0, np.int64)
    mem_mb = np.zeros(0)


def run_cells_lanes(cells: Sequence, backend: Optional[str] = None,
                    ) -> List[dict]:
    """Evaluate ``cells`` with the lane engine; serial-identical rows in
    submission order.  Ineligible cells run through the serial
    ``run_cell`` unchanged; if JAX is missing everything does."""
    from repro.search.runner import (_RESULT_FIELDS, CellError, _get_trace,
                                     _infeasible, run_cell)
    cells = list(cells)
    try:
        import jax  # noqa: F401
        have_jax = True
    except Exception:             # pragma: no cover - env without jax
        have_jax = False
        warnings.warn("repro.manyworld: JAX unavailable; workers='lanes' "
                      "falling back to the serial cell runner")

    rows: List[Optional[dict]] = [None] * len(cells)
    buckets = {}                  # (sched, p_pad, n_pad) -> [(idx, lane)]
    for idx, cell in enumerate(cells):
        try:
            if not (have_jax and lane_eligible(cell)):
                rows[idx] = run_cell(cell)
                continue
            trace = _get_trace(cell.scenario, cell.seed, cell.n_jobs)
            template = _template_of(cell)
            if _infeasible(cell, trace):
                rows[idx] = _base_row(cell, trace, infeasible=True)
                continue
            if trace.n == 0:
                t0 = time.perf_counter()
                row = _base_row(cell, trace, infeasible=False)
                row.update(_zero_pod_metrics(cell, template))
                row["wall_s"] = time.perf_counter() - t0
                rows[idx] = row
                continue
            lane = trace.to_lane_arrays()
            lane["n_nodes"] = cell.initial_workers
            lane["alloc_cpu"] = float(template.allocatable.cpu_m)
            lane["alloc_mem"] = float(template.allocatable.mem_mb)
            lane["weights"] = cell.scheduler_weights
            key = (cell.scheduler, next_pow2(trace.n),
                   next_pow2(cell.initial_workers))
            buckets.setdefault(key, []).append((idx, cell, trace, template,
                                                lane))
        except CellError:
            raise
        except Exception as exc:
            raise CellError(f"cell {cell.label} failed: {exc!r}") from exc

    for (sched, p_pad, _n_pad), entries in buckets.items():
        t0 = time.perf_counter()
        batch = _lanes.stack_lanes([e[4] for e in entries], sched,
                                   p_pad=p_pad)
        out = _lanes.run_lane_batch(batch, backend=backend)
        share = (time.perf_counter() - t0) / len(entries)
        for li, (idx, cell, trace, template, _lane) in enumerate(entries):
            o = {key: val[li] for key, val in out.items()
                 if key not in ("n_cycles",)}
            try:
                row = _base_row(cell, trace, infeasible=False)
                row.update(_lane_metrics(cell, trace, template, o))
                row["wall_s"] = share
                rows[idx] = row
            except Exception as exc:
                raise CellError(
                    f"cell {cell.label} failed: {exc!r}") from exc

    assert all(r is not None for r in rows)
    return rows
