"""Reschedulers (paper §6.2, Algorithms 3 & 4).

Both active variants share the same plan-construction logic: pick a victim
node, plan relocations for its moveable pods onto *other* nodes using shadow
capacity accounting, and commit only if the freed memory lets the
unschedulable pod fit.  They differ in what happens after eviction:

* **Non-binding** — evictees and the pending pod go back to the queue; the
  scheduler places everyone next cycle ("it seems to be a better option to
  allow the scheduler to place all pending pods", §7.2).
* **Binding** — the rescheduler itself creates the bindings it planned.

Pseudocode/text discrepancy note: the paper's prose says candidate nodes are
sorted *ascending* by available memory while Algorithms 3/4 say *descending*.
We follow the pseudocode (descending): the node with the most free memory
needs the fewest evictions to make room, which matches the algorithm's
evict-as-little-as-possible structure.  (`sort_ascending=True` switches to the
prose order for the ablation in benchmarks.)

``_ShadowCapacity`` is array-backed when the cluster carries a SoA mirror:
best-fit placement of each mover is a masked argmin over the free-memory
vector instead of a dict scan.  The same shadow is used by Alg. 6 scale-in
placeability checks (see ``autoscaler._all_placeable``).
"""
from __future__ import annotations

import abc
import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import engine as _engine
from repro.core.cluster import Cluster, Node
from repro.core.pods import Pod
from repro.core.resources import Resources
from repro.obs.recorder import R_RESCHED, R_UNSPEC, RS_RESCHEDULED


class RescheduleOutcome(enum.Enum):
    """Tri-state result consumed by the orchestrator (Alg. 1).

    The `max_pod_age` gate exists "with the aim of reducing the number of
    unnecessary rescheduling **and autoscaling** decisions" (§6.2) — i.e. a
    young pending pod yields WAIT, which suppresses scale-out for this cycle
    and gives running batch jobs the chance to complete and free room.
    """

    WAIT = "wait"            # age gate not reached — do NOT scale out yet
    RESCHEDULED = "done"     # evictions performed (room being made)
    FAILED = "failed"        # nothing can be consolidated — scale out


@dataclasses.dataclass
class ReschedulePlan:
    """Planned evictions: victim node + (pod -> target node id) map."""

    victim: Node
    relocations: Dict[int, Tuple[Pod, str]]   # uid -> (pod, target node id)


class _ShadowBase:
    """Version-keyed base snapshot shared by a cycle's shadow passes.

    Rebuilding ``_ShadowCapacity`` costs O(n_slots) per candidate node per
    blocked pod, and a deeply-backlogged cycle replays the identical failing
    plan for hundreds of blocked pods against an *unchanged* cluster — the
    ROADMAP bottleneck that forced the sweep onto the void rescheduler.
    This cache keeps one base copy of the free vectors + READY mask, keyed
    on the mirror's monotone ``version`` counter (any bind/unbind/
    membership/state change bumps it), and serves shadows that *undo their
    own writes* (verbatim old-value restore, so the base stays bit-exact)
    instead of re-snapshotting.  ``failed_keys`` additionally latches
    request sizes whose plan construction failed at this version: plan
    construction is a pure function of (cluster state, pod.requests), so an
    identical request can only fail identically until the version moves.
    """

    __slots__ = ("arr", "version", "free_cpu", "free_mem", "ready_mask",
                 "failed_keys")

    def __init__(self):
        self.arr = None
        self.version = -1

    def refresh(self, arr) -> None:
        if arr is self.arr and arr.version == self.version:
            return
        self.arr = arr
        self.version = arr.version
        # Same `alloc - used` float op free_views() applies — bit-identical
        # to an uncached per-pod snapshot at this version.
        self.free_cpu, self.free_mem = arr.free_views()
        self.ready_mask = arr.live("active") & (
            arr.live("state") == _engine.STATE_READY)
        self.failed_keys = set()


class _ShadowCapacity:
    """Hypothetical free-capacity tracker for multi-pod relocation planning.

    Array mode (cluster has a SoA mirror): snapshot of the free vectors with
    the victim masked out; ``place_best_fit`` is a masked argmin + in-place
    subtraction.  Dict mode (seed engine): per-node ``Resources`` map.  Both
    modes pick min (free_mem, node_id) and subtract with the same float ops,
    so plans are identical.

    With a ``base`` (`_ShadowBase`), the shadow borrows the cached vectors
    instead of snapshotting, records every write in an undo log, and
    ``rollback()`` restores the stored old values verbatim — exact, unlike
    add-the-delta-back, which is not an IEEE-754 inverse.  Callers that
    pass ``base`` must call ``rollback()`` when done (try/finally).
    """

    def __init__(self, cluster: Cluster, exclude: Node,
                 base: Optional[_ShadowBase] = None):
        self._arr = cluster.arrays
        self._undo = None
        self._excluded = None
        if self._arr is not None:
            arr = self._arr
            if base is not None:
                base.refresh(arr)
                self.free_cpu, self.free_mem = base.free_cpu, base.free_mem
                self.mask = base.ready_mask
                self._undo = []
                if exclude._slot is not None and exclude._arrays is arr:
                    slot = exclude._slot
                    self._excluded = (slot, bool(self.mask[slot]))
                    self.mask[slot] = False
                return
            self.free_cpu, self.free_mem = arr.free_views()
            self.mask = arr.live("active") & (
                arr.live("state") == _engine.STATE_READY)
            if exclude._slot is not None and exclude._arrays is arr:
                self.mask[exclude._slot] = False
            return
        self.free: Dict[str, Resources] = {
            n.node_id: n.free for n in cluster.ready_nodes()
            if n.node_id != exclude.node_id
        }

    def place_best_fit(self, req: Resources) -> Optional[str]:
        """Best-fit placement against shadow capacities (consistent with
        the best-fit scheduler the system runs)."""
        if self._arr is not None:
            fits = self.mask & (self.free_cpu >= req.cpu_m) & (
                (self.free_mem + 1e-9) >= req.mem_mb)
            if not fits.any():
                return None
            best = self.free_mem[fits].min()
            slot = self._arr.first_by_id(fits & (self.free_mem == best))
            if self._undo is not None:
                self._undo.append((slot, self.free_cpu[slot],
                                   self.free_mem[slot]))
            self.free_cpu[slot] -= req.cpu_m
            self.free_mem[slot] -= req.mem_mb
            return self._arr.node_ids[slot]
        candidates = [(free.mem_mb, nid) for nid, free in self.free.items()
                      if req.fits_in(free)]
        if not candidates:
            return None
        _, nid = min(candidates)
        self.free[nid] = self.free[nid] - req
        return nid

    def rollback(self) -> None:
        """Restore a base-backed shadow's writes (no-op otherwise)."""
        if self._undo is not None:
            for slot, cpu, mem in reversed(self._undo):
                self.free_cpu[slot] = cpu
                self.free_mem[slot] = mem
            self._undo = []
        if self._excluded is not None:
            slot, was = self._excluded
            self.mask[slot] = was
            self._excluded = None


class Rescheduler(abc.ABC):
    """Interface used by the orchestrator when a pod is unschedulable."""

    name = "rescheduler"

    def __init__(self, max_pod_age_s: float = 60.0, sort_ascending: bool = False):
        self.max_pod_age_s = max_pod_age_s
        self.sort_ascending = sort_ascending
        # Observability recorder (repro.obs.ObsRecorder.attach sets it);
        # None = compiled out.
        self.obs = None
        # Array-engine plan-construction cache, version-invalidated (see
        # _ShadowBase): shared across every blocked pod of a cycle as long
        # as nothing mutates the cluster in between.
        self._shadow_base = _ShadowBase()

    @abc.abstractmethod
    def reschedule(self, cluster: Cluster, pod: Pod, now: float) -> RescheduleOutcome:
        """Try to make room for `pod` (see RescheduleOutcome)."""

    # -- shared plan construction (Alg. 3/4 body) -----------------------------
    def _candidate_nodes(self, cluster: Cluster, pod: Pod) -> List[Node]:
        """Stage 1 filter: READY nodes that already have enough *CPU* for the
        pod (evictions only need to free memory, the non-compressible axis),
        sorted by (free_mem, node_id) — descending unless sort_ascending."""
        arr = cluster.arrays
        if arr is not None:
            free_cpu, free_mem = arr.free_views()
            mask = arr.live("active") & (
                arr.live("state") == _engine.STATE_READY) & (
                free_cpu >= pod.requests.cpu_m)
            idx = np.nonzero(mask)[0]
            rank = arr.live("id_rank")[idx]
            if self.sort_ascending:
                order = np.lexsort((rank, free_mem[idx]))
            else:
                order = np.lexsort((-rank, -free_mem[idx]))
            return [cluster.node_by_slot(int(i)) for i in idx[order]]
        nodes = [n for n in cluster.ready_nodes()
                 if pod.requests.cpu_fits_in(n.free)]
        nodes.sort(key=lambda n: (n.free.mem_mb, n.node_id),
                   reverse=not self.sort_ascending)
        return nodes

    def _build_plan(self, cluster: Cluster, pod: Pod) -> Optional[ReschedulePlan]:
        # Plan construction is deterministic in (cluster state, pod.requests):
        # on the array engine, latch request sizes that failed at the current
        # mirror version so the deeply-backlogged case — many blocked pods of
        # the same shape against an unchanged cluster — pays for one scan
        # instead of one per pod.  The object path stays verbatim seed
        # behavior (it is the parity reference; both paths build identical
        # plans regardless).
        arr = cluster.arrays
        base = None
        if arr is not None:
            base = self._shadow_base
            base.refresh(arr)
            key = (pod.requests.cpu_m, pod.requests.mem_mb)
            if key in base.failed_keys:
                return None
        obs = self.obs
        prof = obs.prof if obs is not None else None
        if prof is None:
            plan = self._build_plan_uncached(cluster, pod, base)
        else:
            t0 = prof.start()
            plan = self._build_plan_uncached(cluster, pod, base)
            prof.stop("shadow_plan", t0)
        if plan is None and base is not None:
            base.failed_keys.add(key)
        return plan

    def _build_plan_uncached(self, cluster: Cluster, pod: Pod,
                             base: Optional[_ShadowBase]) -> Optional[ReschedulePlan]:
        for node in self._candidate_nodes(cluster, pod):
            moveables = node.moveable_pods()
            if not moveables:
                continue
            # Evict the largest movers first: fewest evictions to close the gap.
            moveables.sort(key=lambda p: (p.requests.mem_mb, p.uid), reverse=True)
            shadow = _ShadowCapacity(cluster, exclude=node, base=base)
            try:
                relocations: Dict[int, Tuple[Pod, str]] = {}
                freed = 0.0
                needed = pod.requests.mem_mb - node.free.mem_mb
                for mover in moveables:
                    if freed >= needed - 1e-9:
                        break
                    target = shadow.place_best_fit(mover.requests)
                    if target is None:
                        continue
                    relocations[mover.uid] = (mover, target)
                    freed += mover.requests.mem_mb
                if freed >= needed - 1e-9 and relocations:
                    return ReschedulePlan(victim=node, relocations=relocations)
            finally:
                shadow.rollback()
        return None

    def _gated(self, pod: Pod, now: float) -> bool:
        """Alg. 3/4 precondition: pod must have been pending max_pod_age."""
        return pod.age(now) >= self.max_pod_age_s


class VoidRescheduler(Rescheduler):
    """Paper: ignores every rescheduling request — no gate, so the
    orchestrator proceeds straight to scale-out ("blindly provisions")."""

    name = "void"

    def reschedule(self, cluster: Cluster, pod: Pod, now: float) -> RescheduleOutcome:
        return RescheduleOutcome.FAILED


class NonBindingRescheduler(Rescheduler):
    """Paper Alg. 3: evict planned movers; everyone returns to the queue."""

    name = "non-binding"

    def reschedule(self, cluster: Cluster, pod: Pod, now: float) -> RescheduleOutcome:
        if not self._gated(pod, now):
            return RescheduleOutcome.WAIT
        plan = self._build_plan(cluster, pod)
        if plan is None:
            return RescheduleOutcome.FAILED
        obs = self.obs
        if obs is not None:
            obs.resched(now, pod.uid, RS_RESCHEDULED,
                        victim=plan.victim.node_id,
                        n_moved=len(plan.relocations))
            obs.reason = R_RESCHED   # eviction attribution context
        try:
            for mover, _target in plan.relocations.values():
                cluster.unbind(mover, now)   # -> PENDING, recreated next cycle
        finally:
            if obs is not None:
                obs.reason = R_UNSPEC
        return RescheduleOutcome.RESCHEDULED


class BindingRescheduler(Rescheduler):
    """Paper Alg. 4: evict planned movers and bind them (and the pending pod)
    to their planned nodes immediately."""

    name = "binding"

    def reschedule(self, cluster: Cluster, pod: Pod, now: float) -> RescheduleOutcome:
        if not self._gated(pod, now):
            return RescheduleOutcome.WAIT
        plan = self._build_plan(cluster, pod)
        if plan is None:
            return RescheduleOutcome.FAILED
        obs = self.obs
        if obs is not None:
            obs.resched(now, pod.uid, RS_RESCHEDULED,
                        victim=plan.victim.node_id,
                        n_moved=len(plan.relocations))
            obs.reason = R_RESCHED   # eviction attribution context
        try:
            for mover, target in plan.relocations.values():
                cluster.unbind(mover, now)
                cluster.bind(mover, cluster.get(target), now)
        finally:
            if obs is not None:
                obs.reason = R_UNSPEC
        # Place the unschedulable pod on the freed victim node.
        if plan.victim.fits(pod.requests):
            cluster.bind(pod, plan.victim, now)
        return RescheduleOutcome.RESCHEDULED


RESCHEDULERS = {
    cls.name: cls
    for cls in (VoidRescheduler, NonBindingRescheduler, BindingRescheduler)
}
