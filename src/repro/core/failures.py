"""Failure injection + straggler model (fleet extension; DESIGN.md §2).

The paper assumes reliable VMs; a 1000+-node fleet cannot.  This module adds:

* `FailureInjector` — per-node exponential time-to-failure.  On failure the
  node vanishes, its pods are recreated as PENDING (checkpointable training
  jobs resume from their last checkpoint boundary — see `Pod.evict`), and the
  orchestrator's normal schedule→reschedule→scale-out loop absorbs the loss.
  This is exactly the paper's machinery reused as a *recovery* mechanism.
* `StragglerInjector` — marks a fraction of nodes slow (speed_factor < 1);
  the orchestrator's straggler policy evicts checkpointable batch pods from
  slow nodes so they finish elsewhere.  Wire it into the launch path via
  ``ExperimentSpec.straggler_injector``.

Spot reclaims (notice-before-kill), correlated zone outages and pod
crash-loops live in `repro.core.disruption`; they speak this module's
``prime``/``arm_node`` injector protocol and compose with `FailureInjector`
through `disruption.DisruptionInjector`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.cluster import Node

NODE_FAIL = 5   # must match simulation.NODE_FAIL


@dataclasses.dataclass
class FailureInjector:
    mtbf_s: float = 4 * 3600.0
    seed: int = 0
    arm_static_nodes: bool = True

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def prime(self, sim) -> None:
        for node in sim.cluster.nodes.values():
            if self.arm_static_nodes or node.autoscaled:
                self.arm_node(sim, node)

    def arm_node(self, sim, node: Node) -> None:
        ttf = float(self._rng.exponential(self.mtbf_s))
        sim.push(sim.now + ttf, NODE_FAIL, node)


@dataclasses.dataclass
class StragglerInjector:
    """Makes every k-th launched node slow by `slow_factor`."""

    every_k: int = 4
    slow_factor: float = 0.4
    _count: int = 0

    def maybe_slow(self, node: Node) -> Node:
        self._count += 1
        if self.every_k > 0 and self._count % self.every_k == 0:
            node.speed_factor = self.slow_factor
        return node
