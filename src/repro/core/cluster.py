"""Cluster + node state model (paper §4).

Nodes move through a small state machine::

    PROVISIONING --ready--> READY --taint--> TAINTED --untaint--> READY
          \\                                   |
           \\--------------- terminate --------+--> TERMINATED

``TAINTED`` mirrors the paper's *taint as unschedulable* (Alg. 6 step 3):
schedulers avoid tainted nodes unless no untainted node fits.

Capacity accounting is *request-based*, exactly like the default Kubernetes
scheduler (§4.1): the sum of requests of pods bound to a node never exceeds
its allocatable capacity, regardless of actual usage.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, Iterable, List, Optional

from repro.core.pods import Pod
from repro.core.resources import Resources, sum_resources


class NodeState(enum.Enum):
    PROVISIONING = "provisioning"   # VM requested, not yet joined the cluster
    READY = "ready"
    TAINTED = "tainted"             # schedulable only as a last resort
    TERMINATED = "terminated"


_node_seq = itertools.count()


@dataclasses.dataclass
class Node:
    """One worker (paper: m2.small VM; fleet: one TPU v5e host)."""

    allocatable: Resources
    node_type: str = "worker"
    autoscaled: bool = False            # created dynamically (Alg. 6 precondition)
    node_id: str = ""
    state: NodeState = NodeState.PROVISIONING
    provision_time: float = 0.0         # when the provider was asked for it
    ready_time: Optional[float] = None  # when it joined the cluster
    terminate_time: Optional[float] = None
    pods: Dict[int, Pod] = dataclasses.field(default_factory=dict)
    # Fleet extensions.
    speed_factor: float = 1.0           # <1.0 models a straggler node
    failed: bool = False
    oversub: bool = False               # request-sum may exceed allocatable

    def __post_init__(self):
        if not self.node_id:
            self.node_id = f"node-{next(_node_seq)}"

    # -- capacity ------------------------------------------------------------
    @property
    def used(self) -> Resources:
        return sum_resources(p.requests for p in self.pods.values())

    @property
    def free(self) -> Resources:
        return self.allocatable - self.used

    def fits(self, req: Resources) -> bool:
        return req.fits_in(self.free)

    # -- queries used by the paper's algorithms ------------------------------
    @property
    def schedulable(self) -> bool:
        return self.state == NodeState.READY

    @property
    def last_resort(self) -> bool:
        return self.state == NodeState.TAINTED

    def moveable_pods(self) -> List[Pod]:
        return [p for p in self.pods.values() if p.moveable]

    def has_only_moveable(self) -> bool:
        return bool(self.pods) and all(p.moveable for p in self.pods.values())

    def has_moveable_and_batch(self) -> bool:
        pods = list(self.pods.values())
        return (any(p.moveable for p in pods)
                and any(p.is_batch for p in pods)
                and all(p.moveable or p.is_batch for p in pods))

    # -- lifecycle -----------------------------------------------------------
    def mark_ready(self, now: float) -> None:
        assert self.state == NodeState.PROVISIONING
        self.state = NodeState.READY
        self.ready_time = now

    def taint(self) -> None:
        if self.state == NodeState.READY:
            self.state = NodeState.TAINTED

    def untaint(self) -> None:
        if self.state == NodeState.TAINTED:
            self.state = NodeState.READY

    def terminate(self, now: float) -> None:
        assert not self.pods, f"terminating non-empty node {self.node_id}"
        self.state = NodeState.TERMINATED
        self.terminate_time = now

    # -- bindings ------------------------------------------------------------
    def add_pod(self, pod: Pod) -> None:
        assert pod.requests.fits_in(self.free), (
            f"overcommit on {self.node_id}: {pod} does not fit {self.free}")
        self.pods[pod.uid] = pod

    def remove_pod(self, pod: Pod) -> None:
        del self.pods[pod.uid]

    def __repr__(self):
        return (f"Node({self.node_id}, {self.state.value}, "
                f"pods={len(self.pods)}, free={self.free})")


class Cluster:
    """The live cluster: the single source of truth (paper: etcd)."""

    def __init__(self):
        self.nodes: Dict[str, Node] = {}
        self.terminated: List[Node] = []    # kept for cost accounting

    # -- membership ----------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        self.nodes[node.node_id] = node
        return node

    def remove_node(self, node: Node, now: float) -> None:
        node.terminate(now)
        self.terminated.append(node)
        del self.nodes[node.node_id]

    def get(self, node_id: str) -> Node:
        return self.nodes[node_id]

    # -- views ---------------------------------------------------------------
    def ready_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.state == NodeState.READY]

    def tainted_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.state == NodeState.TAINTED]

    def schedulable_nodes(self) -> List[Node]:
        """READY nodes; the scheduler falls back to TAINTED separately."""
        return self.ready_nodes()

    def provisioning_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values()
                if n.state == NodeState.PROVISIONING]

    def all_pods(self) -> List[Pod]:
        return [p for n in self.nodes.values() for p in n.pods.values()]

    def node_of(self, pod: Pod) -> Optional[Node]:
        return self.nodes.get(pod.node_id) if pod.node_id else None

    # -- bindings (paper §4.2 createBinding) ----------------------------------
    def bind(self, pod: Pod, node: Node, now: float) -> None:
        node.add_pod(pod)
        pod.bind(node.node_id, now)

    def unbind(self, pod: Pod, now: float, *, failed: bool = False) -> None:
        node = self.node_of(pod)
        if node is not None:
            node.remove_pod(pod)
        pod.evict(now, failed=failed)

    # -- invariant (property-tested) ------------------------------------------
    def check_invariants(self) -> None:
        for n in self.nodes.values():
            if n.oversub:
                continue   # estimator-driven oversubscription is intentional
            used = n.used
            assert used.cpu_m <= n.allocatable.cpu_m, n
            assert used.mem_mb <= n.allocatable.mem_mb + 1e-6, n
            for p in n.pods.values():
                assert p.node_id == n.node_id, (p, n)
