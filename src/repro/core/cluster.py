"""Cluster + node state model (paper §4).

Nodes move through a small state machine::

    PROVISIONING --ready--> READY --taint--> TAINTED --untaint--> READY
          \\                                   |
           \\--------------- terminate --------+--> TERMINATED

``TAINTED`` mirrors the paper's *taint as unschedulable* (Alg. 6 step 3):
schedulers avoid tainted nodes unless no untainted node fits.

Capacity accounting is *request-based*, exactly like the default Kubernetes
scheduler (§4.1): the sum of requests of pods bound to a node never exceeds
its allocatable capacity, regardless of actual usage.

Accounting is **incremental**: ``Node.used`` is maintained on every
add_pod/remove_pod instead of re-summing resident pods on each access, and a
structure-of-arrays mirror (``repro.core.engine.ClusterArrays``) is kept in
lockstep so schedulers can vectorize filter+select.  Both the object path and
the array path read the *same* incrementally-maintained floats, so the two
engines are bit-for-bit identical.

On the array engine, pod state itself is SoA too
(``repro.core.engine.PodStore``, attached as ``Cluster.pod_store``): the
bind/unbind/complete commit points write the pod columns alongside any
materialized ``Pod`` shells, ``bind_wave_store``/``complete_wave_store``
commit whole waves as column writes with the identical accounting ops in
the identical order, and ``Node.pods`` is a :class:`ResidentPods` mapping
whose shell-less residents materialize lazily on first object access.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.core import engine as _engine
from repro.core.pods import Pod
from repro.core.resources import Resources, sum_resources


class NodeState(enum.Enum):
    PROVISIONING = "provisioning"   # VM requested, not yet joined the cluster
    READY = "ready"
    TAINTED = "tainted"             # schedulable only as a last resort
    TERMINATED = "terminated"

    @property
    def value_code(self) -> int:
        """Int code used by the SoA mirror's state vector."""
        return _STATE_CODES[self]


_STATE_CODES = {
    NodeState.PROVISIONING: _engine.STATE_PROVISIONING,
    NodeState.READY: _engine.STATE_READY,
    NodeState.TAINTED: _engine.STATE_TAINTED,
    NodeState.TERMINATED: _engine.STATE_TERMINATED,
}

_node_seq = itertools.count()


class ResidentPods(dict):
    """``Node.pods``: a ``{uid: Pod}`` mapping whose values may be *lazy*.

    On the array engine's shell-less fast path (``Cluster.bind_wave_store``)
    a resident pod is recorded as ``uid -> None`` plus its SoA columns in the
    :class:`repro.core.engine.PodStore`; the ``Pod`` shell is materialized
    from the columns the first time any reader actually asks for the object
    (``values()`` / ``items()`` / ``__getitem__`` / ``get``).  Keys, length
    and truthiness never materialize — ``len(node.pods)`` and membership
    checks stay O(1) — so counters, the mirror's ``pod_count`` sync and the
    ``terminate`` guard all see shell-less residents.

    On the seed object engine no lazy entry is ever inserted and every
    operation degrades to the plain dict it subclasses.
    """

    __slots__ = ("_store", "_lazy")

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._store = None
        # Conservative "may contain uid -> None entries" flag: set by the
        # fast bind path (once per touched node per wave, not per pod — the
        # per-pod insert stays the inherited C setitem), cleared when a full
        # materialization proves the mapping dense again.  Deletions don't
        # maintain it, so the flag may stay True after the last lazy entry
        # is gone; that only costs one no-op scan on the next values() call.
        self._lazy = False

    # Lazy insertion happens in Cluster.bind_wave_store: a plain
    # ``pods[uid] = None`` per pod (the inherited C setitem) plus one
    # ``_store``/``_lazy`` write per touched node after the wave.

    # -- lazy materialization --------------------------------------------------
    def _materialize(self, uid: int):
        pod = self._store.pod_by_uid(uid)
        dict.__setitem__(self, uid, pod)
        return pod

    def __getitem__(self, uid: int):
        pod = dict.__getitem__(self, uid)
        if pod is None:
            pod = self._materialize(uid)
        return pod

    def get(self, uid, default=None):
        pod = dict.get(self, uid, default)
        if pod is None and dict.__contains__(self, uid):
            pod = self._materialize(uid)
        return pod

    def values(self):
        if self._lazy:
            self._materialize_all()
        return dict.values(self)

    def items(self):
        if self._lazy:
            self._materialize_all()
        return dict.items(self)

    def _materialize_all(self) -> None:
        for uid, pod in list(dict.items(self)):
            if pod is None:
                self._materialize(uid)
        self._lazy = False

    # -- store-aware iteration (invariant checks avoid materializing) ---------
    def lazy_uids(self):
        return [uid for uid, pod in dict.items(self) if pod is None]

    def materialized_values(self):
        return [pod for pod in dict.values(self) if pod is not None]


@dataclasses.dataclass
class Node:
    """One worker (paper: m2.small VM; fleet: one TPU v5e host)."""

    allocatable: Resources
    node_type: str = "worker"
    autoscaled: bool = False            # created dynamically (Alg. 6 precondition)
    node_id: str = ""
    state: NodeState = NodeState.PROVISIONING
    provision_time: float = 0.0         # when the provider was asked for it
    ready_time: Optional[float] = None  # when it joined the cluster
    terminate_time: Optional[float] = None
    pods: Dict[int, Pod] = dataclasses.field(default_factory=dict)
    # Fleet extensions.
    speed_factor: float = 1.0           # <1.0 models a straggler node
    failed: bool = False
    oversub: bool = False               # request-sum may exceed allocatable
    # Availability-zone label for correlated failures (assigned by
    # disruption.ZoneOutageInjector; "" == unlabelled, never targeted).
    zone: str = ""

    def __post_init__(self):
        if not self.node_id:
            self.node_id = f"node-{next(_node_seq)}"
        # Resident-pod mapping with lazy shell materialization (plain-dict
        # behaviour on the seed engine; see ResidentPods).
        self.pods = ResidentPods(self.pods)
        # Incremental accounting (seeded from any pre-populated pods dict).
        self._used_cpu_m: int = 0
        self._used_mem_mb: float = 0.0
        self._moveable_count: int = 0
        self._batch_count: int = 0
        for p in self.pods.values():
            self._account_add(p)
        # SoA mirror back-references, set by Cluster.add_node.
        self._arrays: Optional[_engine.ClusterArrays] = None
        self._slot: Optional[int] = None

    # -- capacity ------------------------------------------------------------
    @property
    def used(self) -> Resources:
        return Resources(self._used_cpu_m, self._used_mem_mb)

    @property
    def free(self) -> Resources:
        return Resources(self.allocatable.cpu_m - self._used_cpu_m,
                         self.allocatable.mem_mb - self._used_mem_mb)

    def fits(self, req: Resources) -> bool:
        return req.fits_in(self.free)

    # -- queries used by the paper's algorithms ------------------------------
    @property
    def schedulable(self) -> bool:
        return self.state == NodeState.READY

    @property
    def last_resort(self) -> bool:
        return self.state == NodeState.TAINTED

    def moveable_pods(self) -> List[Pod]:
        if self._moveable_count == 0:
            return []   # count-based early exit: never materializes shells
        return [p for p in self.pods.values() if p.moveable]

    def has_only_moveable(self) -> bool:
        return bool(self.pods) and self._moveable_count == len(self.pods)

    def has_moveable_and_batch(self) -> bool:
        return (self._moveable_count > 0 and self._batch_count > 0
                and self._moveable_count + self._batch_count == len(self.pods))

    # -- lifecycle -----------------------------------------------------------
    def _notify_state(self) -> None:
        if self._arrays is not None:
            self._arrays.sync_state(self._slot, self)

    def mark_ready(self, now: float) -> None:
        assert self.state == NodeState.PROVISIONING
        self.state = NodeState.READY
        self.ready_time = now
        self._notify_state()

    def taint(self) -> None:
        if self.state == NodeState.READY:
            self.state = NodeState.TAINTED
            self._notify_state()

    def untaint(self) -> None:
        if self.state == NodeState.TAINTED:
            self.state = NodeState.READY
            self._notify_state()

    def terminate(self, now: float) -> None:
        assert not self.pods, f"terminating non-empty node {self.node_id}"
        self.state = NodeState.TERMINATED
        self.terminate_time = now
        self._notify_state()

    # -- bindings ------------------------------------------------------------
    def _account_add(self, pod: Pod) -> None:
        self._used_cpu_m += pod.requests.cpu_m
        self._used_mem_mb += pod.requests.mem_mb
        if pod.moveable:
            self._moveable_count += 1
        if pod.is_batch:
            self._batch_count += 1

    def _account_remove(self, pod: Pod) -> None:
        self._used_cpu_m -= pod.requests.cpu_m
        self._used_mem_mb -= pod.requests.mem_mb
        if pod.moveable:
            self._moveable_count -= 1
        if pod.is_batch:
            self._batch_count -= 1

    def _notify_usage(self) -> None:
        if self._arrays is not None:
            self._arrays.sync_usage(self._slot, self)

    def add_pod(self, pod: Pod, *, enforce: bool = True) -> None:
        if enforce:
            assert pod.requests.fits_in(self.free), (
                f"overcommit on {self.node_id}: {pod} does not fit {self.free}")
        self.pods[pod.uid] = pod
        self._account_add(pod)
        self._notify_usage()

    def remove_pod(self, pod: Pod) -> None:
        del self.pods[pod.uid]
        self._account_remove(pod)
        self._notify_usage()

    def __repr__(self):
        return (f"Node({self.node_id}, {self.state.value}, "
                f"pods={len(self.pods)}, free={self.free})")


class Cluster:
    """The live cluster: the single source of truth (paper: etcd).

    ``arrays`` is the SoA mirror used by the vectorized schedulers / shadow
    capacity / scale-in; pass ``use_arrays=False`` (or set
    ``REPRO_SCHED_ENGINE=object``) to run the seed object-scan engine.

    The orchestrator registers ``on_bind`` / ``on_unbind`` / ``on_complete``
    callbacks so it can maintain its pending queue and running counters
    without rescanning every pod each cycle.
    """

    def __init__(self, use_arrays: Optional[bool] = None,
                 wave_select: Optional[str] = None):
        self.nodes: Dict[str, Node] = {}
        self.terminated: List[Node] = []    # kept for cost accounting
        if use_arrays is None:
            use_arrays = _engine.arrays_enabled_default()
        self.arrays: Optional[_engine.ClusterArrays] = (
            _engine.ClusterArrays(wave_select=wave_select)
            if use_arrays else None)
        # SoA pod columns (set by the orchestrator on the array engine); the
        # bind/unbind/complete commit points keep it in lockstep with any
        # materialized Pod shells.
        self.pod_store = None
        # slot -> live Node (None once removed): O(1) node lookup for the
        # store fast paths, in lockstep with ClusterArrays slots.
        self._slot_nodes: List[Optional[Node]] = []
        self.on_bind: Optional[Callable[[Pod], None]] = None
        self.on_unbind: Optional[Callable[[Pod], None]] = None
        self.on_complete: Optional[Callable[[Pod], None]] = None
        # Flight recorder (repro.obs.ObsRecorder), attached by
        # build_simulation when ExperimentSpec.obs is set.  Unlike the
        # on_bind/on_unbind observers, the recorder hooks at the *commit*
        # points below, so it sees every bind/evict on both engines without
        # deoptimizing the shell-less fast paths (which key on the observer
        # callbacks staying the orchestrator's own).  None = compiled out:
        # each commit pays one attribute test.
        self.obs = None

    # -- membership ----------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        self.nodes[node.node_id] = node
        if self.arrays is not None:
            node._arrays = self.arrays
            node._slot = self.arrays.add(node)
            self._slot_nodes.append(node)
        return node

    def remove_node(self, node: Node, now: float) -> None:
        node.terminate(now)
        self.terminated.append(node)
        del self.nodes[node.node_id]
        if node._arrays is not None:
            self._slot_nodes[node._slot] = None
            node._arrays.remove(node._slot)
            node._arrays = None

    def get(self, node_id: str) -> Node:
        return self.nodes[node_id]

    # -- views ---------------------------------------------------------------
    def ready_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.state == NodeState.READY]

    def tainted_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.state == NodeState.TAINTED]

    def schedulable_nodes(self) -> List[Node]:
        """READY nodes; the scheduler falls back to TAINTED separately."""
        return self.ready_nodes()

    def provisioning_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values()
                if n.state == NodeState.PROVISIONING]

    def all_pods(self) -> List[Pod]:
        return [p for n in self.nodes.values() for p in n.pods.values()]

    def node_of(self, pod: Pod) -> Optional[Node]:
        return self.nodes.get(pod.node_id) if pod.node_id else None

    def node_by_slot(self, slot: int) -> Node:
        return self.nodes[self.arrays.node_ids[slot]]

    # -- bindings (paper §4.2 createBinding) ----------------------------------
    def bind(self, pod: Pod, node: Node, now: float, *,
             enforce: bool = True) -> None:
        node.add_pod(pod, enforce=enforce)
        pod.bind(node.node_id, now)
        if self.pod_store is not None:
            self.pod_store.sync_bind(pod, node._slot)
        if self.obs is not None:
            # Pod.bind leaves pending_since at the interval it just closed.
            self.obs.bind(now, pod.uid, node.node_id,
                          now - pod.pending_since, pod.incarnation)
        if self.on_bind is not None:
            self.on_bind(pod)

    def bind_wave(self, bindings, now: float) -> None:
        """Commit one wave of scheduler-chosen ``(pod, node)`` binds.

        Equivalent to calling :meth:`bind` per pair — per-pod object effects
        (incremental node accounting, ``Pod.bind``, the ``on_bind`` callback)
        happen in wave order — except that:

        * the SoA mirror's usage columns are synced **once per touched node**
          after the loop instead of once per bind (the mirror is written by
          assignment from the node's final accounting, so the result is
          bit-identical);
        * the per-bind feasibility assert is skipped: the wave already
          established feasibility against bit-identical free values, and the
          per-cycle ``check_invariants`` still guards capacity.
        """
        touched: Dict[str, Node] = {}
        on_bind = self.on_bind
        obs = self.obs
        store = self.pod_store
        for pod, node in bindings:
            node.pods[pod.uid] = pod
            node._account_add(pod)
            touched[node.node_id] = node
            pod.bind(node.node_id, now)
            if store is not None:
                store.sync_bind(pod, node._slot)
            if obs is not None:
                obs.bind(now, pod.uid, node.node_id,
                         now - pod.pending_since, pod.incarnation)
            if on_bind is not None:
                on_bind(pod)
        for node in touched.values():
            node._notify_usage()

    def bind_wave_store(self, bindings, now: float) -> None:
        """Commit one wave of ``(row, slot)`` binds straight into the SoA pod
        columns — the shell-less fast path of ``Orchestrator._cycle_wave``.

        Semantically :meth:`bind_wave` with ``Pod`` objects elided: node
        accounting applies the identical ``+=`` in the identical order, the
        pod's bind record lands in the store columns instead of object
        attributes, and residency is a lazy ``uid -> None`` entry that
        materializes into a shell only if something later asks for the
        object.  Rows that already carry a shell (a re-pended evictee placed
        by the wave) go through the full object transition so the shell and
        columns stay in lockstep.

        The caller guarantees no external ``on_bind`` observer is attached
        (an observer is an API boundary: ``Orchestrator._cycle_wave``
        detects one and falls back to the materializing :meth:`bind_wave`);
        orchestrator bookkeeping happens row-wise on the caller's side.
        """
        store = self.pod_store
        shells = store.shells
        slot_nodes = self._slot_nodes
        uid_col = store.uid
        cpu_col = store.cpu_m
        mem_col = store.mem_mb
        flag_col = store.flags
        phase_col = store.phase
        slot_col = store.node_slot
        bt_col = store.bound_time
        touched: Dict[int, Node] = {}
        F_BATCH = _engine.POD_F_BATCH
        F_MOVE = _engine.POD_F_MOVEABLE
        obs = self.obs
        if obs is not None:
            ps_col = store.pending_since
            inc_col = store.incarnation
            for row, slot in bindings:
                # Columns are untouched until the commit loop below, so the
                # open pending interval and incarnation read exactly.
                obs.bind(now, uid_col[row], slot_nodes[slot].node_id,
                         now - ps_col[row], inc_col[row])
        for row, slot in bindings:
            node = slot_nodes[slot]
            uid = uid_col[row]
            pod = shells.get(row)
            if pod is not None:
                node.pods[uid] = pod
                node._account_add(pod)
                pod.bind(node.node_id, now)
                phase_col[row] = _engine.POD_BOUND
                slot_col[row] = slot
                bt_col[row] = pod.bound_time
            else:
                # Lazy residency: uid -> None via the inherited C dict
                # setitem; the touched loop below arms the node's
                # ResidentPods (_store/_lazy) once per node, not per pod.
                node.pods[uid] = None
                # Same += order as Node._account_add, on the same scalars.
                node._used_cpu_m += cpu_col[row]
                node._used_mem_mb += mem_col[row]
                f = flag_col[row]
                if f & F_MOVE:
                    node._moveable_count += 1
                if f & F_BATCH:
                    node._batch_count += 1
                phase_col[row] = _engine.POD_BOUND
                slot_col[row] = slot
                bt_col[row] = now
            touched[slot] = node
        for node in touched.values():
            pods = node.pods
            pods._store = store
            pods._lazy = True
            node._notify_usage()

    def unbind(self, pod: Pod, now: float, *, failed: bool = False) -> None:
        node = self.node_of(pod)
        if node is not None:
            node.remove_pod(pod)
        pod.evict(now, failed=failed)
        if self.pod_store is not None:
            self.pod_store.sync_unbind(pod)
        if self.obs is not None:
            self.obs.evict(now, pod.uid,
                           node.node_id if node is not None else None,
                           pod.incarnation, failed)
        if self.on_unbind is not None:
            self.on_unbind(pod)

    def fail_node_store(self, node: Node, now: float,
                        on_row=None) -> List[int]:
        """Bulk-evict every resident of a failing node straight through the
        SoA pod columns — the shell-less fast path of
        ``Simulation._on_node_fail``.

        Semantically identical to calling :meth:`unbind` (``failed=True``)
        per resident in residency (insertion) order.  Residents that carry
        a shell — and checkpointable residents whose eviction would bank
        durable progress, which ``Pod._restore``'s progress-is-zero
        invariant requires to materialize — take the full object
        transition; everything else re-pends as pure column writes: node
        accounting decrements in the identical scalar order, the pending
        interval the bind opened recorded in
        ``PodStore.closed_intervals``, and lost work accumulated in the
        ``lost_work_s`` column with the identical float ops ``Pod.evict``
        applies (a shell-less row has ``progress_s == 0`` by
        construction, so ``0.0 + ran`` is bit-exact).  The mirror syncs
        once after the loop.  ``on_row`` is the orchestrator's row-level
        ``on_unbind`` equivalent for column-evicted rows; shelled
        residents still go through ``self.on_unbind``.  The caller
        guarantees no external ``on_unbind`` observer is attached
        (``Simulation._on_node_fail`` detects one and falls back to the
        per-pod object loop so observers see real pods, in order).

        Returns the evicted uids in residency order (the disruption log's
        victim list)."""
        store = self.pod_store
        shells = store.shells
        index = store.index
        flag_col = store.flags
        bt_col = store.bound_time
        ps_col = store.pending_since
        lost_col = store.lost_work_s
        cpu_col = store.cpu_m
        mem_col = store.mem_mb
        spec_of = store._spec_by_id
        sid_col = store.spec_id
        phase_col = store.phase
        slot_col = store.node_slot
        inc_col = store.incarnation
        closed = store.closed_intervals
        F_BATCH = _engine.POD_F_BATCH
        F_MOVE = _engine.POD_F_MOVEABLE
        F_CKPT = _engine.POD_F_CHECKPOINTABLE
        on_unbind = self.on_unbind
        obs = self.obs
        victims = list(dict.keys(node.pods))
        for uid in victims:
            row = index[uid]
            pod = shells.get(row)
            f = flag_col[row]
            if pod is None and f & F_CKPT:
                iv = spec_of[sid_col[row]].checkpoint_interval_s or 1.0
                total = 0.0 + (now - bt_col[row])
                if (total // iv) * iv > 0.0:
                    # Eviction would bank durable progress — materialize so
                    # the shell carries it (Pod._restore invariant).
                    pod = store.pod_at(row)
            if pod is not None:
                del node.pods[uid]
                node._account_remove(pod)
                pod.evict(now, failed=True)
                store.sync_unbind(pod)
                if obs is not None:
                    obs.evict(now, uid, node.node_id, pod.incarnation, True)
                if on_unbind is not None:
                    on_unbind(pod)
                continue
            dict.__delitem__(node.pods, uid)
            # Same -= order as Node._account_remove, on the same scalars.
            node._used_cpu_m -= cpu_col[row]
            node._used_mem_mb -= mem_col[row]
            if f & F_MOVE:
                node._moveable_count -= 1
            if f & F_BATCH:
                node._batch_count -= 1
            bt = bt_col[row]
            if f & F_BATCH:
                ran = now - bt
                if f & F_CKPT:
                    # Salvage is provably zero (guarded above): the whole
                    # run since bind is lost, via Pod.evict's exact ops.
                    iv = spec_of[sid_col[row]].checkpoint_interval_s or 1.0
                    total = 0.0 + ran
                    lost_col[row] += total - (total // iv) * iv
                else:
                    lost_col[row] += 0.0 + ran
            # Pod.evict column semantics: close the interval the bind
            # opened, re-pend as a fresh incarnation.
            closed.setdefault(row, []).append(bt - ps_col[row])
            phase_col[row] = _engine.POD_PENDING
            slot_col[row] = -1
            bt_col[row] = None
            ps_col[row] = now
            inc_col[row] += 1
            if obs is not None:
                obs.evict(now, uid, node.node_id, int(inc_col[row]), True)
            if on_row is not None:
                on_row(row)
        node._notify_usage()
        return victims

    def complete(self, pod: Pod, now: float) -> None:
        """A batch pod ran to completion: release capacity, mark SUCCEEDED."""
        node = self.node_of(pod)
        if node is not None:
            node.remove_pod(pod)
        pod.complete(now)
        if self.pod_store is not None:
            self.pod_store.sync_complete(pod)
        if self.on_complete is not None:
            self.on_complete(pod)

    def complete_wave(self, pods, now: float) -> None:
        """Commit one batch of completions sharing a timestamp.

        Equivalent to calling :meth:`complete` per pod in order — per-pod
        object effects (incremental node accounting, ``Pod.complete``, the
        ``on_complete`` callback) are identical — except the SoA mirror's
        usage columns sync **once per touched node** after the loop instead
        of once per pod (assignment from the node's final accounting, so the
        mirror lands on bit-identical values)."""
        touched: Dict[str, Node] = {}
        nodes = self.nodes
        on_complete = self.on_complete
        store = self.pod_store
        for pod in pods:
            node = nodes.get(pod.node_id)
            if node is not None:
                del node.pods[pod.uid]
                node._account_remove(pod)
                touched[node.node_id] = node
            pod.complete(now)
            if store is not None:
                store.sync_complete(pod)
            if on_complete is not None:
                on_complete(pod)
        for node in touched.values():
            node._notify_usage()

    def complete_wave_store(self, entries, now: float, on_row=None) -> None:
        """Commit one timestamp-bucket of completions on the store path.

        ``entries`` preserves bind order and may mix shell-less **rows**
        (ints) with materialized **Pod** objects (a shelled pod bound in the
        same bucket as shell-less ones): each entry applies the seed's
        per-completion effects — node accounting decrements in entry order,
        ``Pod.complete`` semantics (phase SUCCEEDED, finish time, node
        linkage retained) — with rows writing columns instead of attributes.
        ``on_row`` is the orchestrator's row-level ``on_complete``
        equivalent; ``Pod`` entries still go through ``self.on_complete``.
        The mirror syncs once per touched node, like :meth:`complete_wave`.
        """
        store = self.pod_store
        slot_nodes = self._slot_nodes
        uid_col = store.uid
        cpu_col = store.cpu_m
        mem_col = store.mem_mb
        flag_col = store.flags
        phase_col = store.phase
        ft_col = store.finish_time
        nodes = self.nodes
        on_complete = self.on_complete
        touched: Dict[int, Node] = {}   # id(node) -> node
        F_BATCH = _engine.POD_F_BATCH
        F_MOVE = _engine.POD_F_MOVEABLE
        shells = store.shells
        for entry in entries:
            if type(entry) is int:
                row = entry
                pod = shells.get(row)
                if pod is None:
                    uid = uid_col[row]
                    node = slot_nodes[store.node_slot[row]]
                    if node is not None:
                        del node.pods[uid]
                        # Same -= order as Node._account_remove.
                        node._used_cpu_m -= cpu_col[row]
                        node._used_mem_mb -= mem_col[row]
                        f = flag_col[row]
                        if f & F_MOVE:
                            node._moveable_count -= 1
                        if f & F_BATCH:
                            node._batch_count -= 1
                        touched[id(node)] = node
                    phase_col[row] = _engine.POD_SUCCEEDED
                    ft_col[row] = now
                    if on_row is not None:
                        on_row(row)
                    continue
                # A shell materialized since the bind: fall through to the
                # object transition so shell and columns stay in lockstep.
            else:
                pod = entry
            node = nodes.get(pod.node_id)
            if node is not None:
                del node.pods[pod.uid]
                node._account_remove(pod)
                touched[id(node)] = node
            pod.complete(now)
            store.sync_complete(pod)
            if on_complete is not None:
                on_complete(pod)
        for node in touched.values():
            node._notify_usage()

    # -- metrics fast path ----------------------------------------------------
    def utilization_totals(self):
        """``(n_nodes, ram_ratio_sum, cpu_ratio_sum, pod_count_sum)`` over
        READY|TAINTED nodes — the exact sums behind the Table-5 ratios.

        On the array engine this reads the mirror's incrementally-maintained
        sampling aggregates (O(dirty slots) since the last sample,
        ``engine.ClusterArrays.sample_totals``); the object path recomputes
        from scratch.  Both produce the correctly-rounded ``fsum`` of the
        same per-node ratios, so dividing by ``n_nodes`` gives Table-5
        values bit-identical across engines and across sampling strategies
        (``statistics.fmean(xs) == math.fsum(xs) / len(xs)``)."""
        if self.arrays is not None:
            return self.arrays.sample_totals()
        n, ram, cpu, ppn = self.utilization_view()
        return n, math.fsum(ram), math.fsum(cpu), sum(ppn)

    def utilization_view(self):
        """(n_nodes, ram_ratios, cpu_ratios, pods_per_node) over READY|TAINTED
        nodes, in insertion order.  Array path and object path produce
        bit-identical values (same floats, same elementwise ops).  The
        Table-5 sampler itself uses :meth:`utilization_totals`; this
        per-node view remains for diagnostics and as the from-scratch
        reference the aggregate parity tests compare against."""
        if self.arrays is not None:
            arr = self.arrays
            state = arr.live("state")
            mask = arr.live("active") & (
                (state == _engine.STATE_READY) | (state == _engine.STATE_TAINTED))
            alloc_c = arr.live("alloc_cpu")[mask]
            ram = arr.live("used_mem")[mask] / arr.live("alloc_mem")[mask]
            cpu = arr.live("used_cpu")[mask] / np.maximum(alloc_c, 1)
            ppn = arr.live("pod_count")[mask]
            return int(mask.sum()), ram, cpu, ppn
        nodes = [n for n in self.nodes.values()
                 if n.state in (NodeState.READY, NodeState.TAINTED)]
        ram = [n.used.mem_mb / n.allocatable.mem_mb for n in nodes]
        cpu = [n.used.cpu_m / max(n.allocatable.cpu_m, 1) for n in nodes]
        ppn = [len(n.pods) for n in nodes]
        return len(nodes), ram, cpu, ppn

    # -- invariant (property-tested) ------------------------------------------
    def check_invariants(self, deep: bool = False) -> None:
        if self.arrays is not None:
            # Vectorized fast path: capacity respected on every live node.
            # The orchestrator runs the deep check periodically so mirror
            # drift / pod-linkage bugs still surface on the array engine.
            arr = self.arrays
            live = arr.live("active") & ~arr.live("oversub")
            over_cpu = arr.live("used_cpu") > arr.live("alloc_cpu")
            over_mem = arr.live("used_mem") > arr.live("alloc_mem") + 1e-6
            bad = live & (over_cpu | over_mem)
            if bad.any():
                slot = int(np.argmax(bad))
                raise AssertionError(
                    f"capacity violated on {arr.node_ids[slot]}")
            if not deep:
                return
            if self.pod_store is not None:
                # Array-native deep audit: node accounting re-summed from
                # the PodStore columns with bincount reductions + shell
                # lockstep checks (engine.PodStore.audit_columns), then the
                # mirror cross-verified field-by-field against the object
                # model.  No shell is materialized; the per-node object
                # walk below remains for store-less clusters.
                self.pod_store.audit_columns(self)
                self.arrays.verify_against(self)
                return
        store = self.pod_store
        for n in self.nodes.values():
            if n.oversub:
                continue   # estimator-driven oversubscription is intentional
            used = n.used
            assert used.cpu_m <= n.allocatable.cpu_m, n
            assert used.mem_mb <= n.allocatable.mem_mb + 1e-6, n
            # Shell-less residents are checked against their columns rather
            # than materialized — a deep check must not defeat the lazy-shell
            # economics of the store fast path.
            lazy = n.pods.lazy_uids() if store is not None else []
            for p in n.pods.materialized_values():
                assert p.node_id == n.node_id, (p, n)
            for uid in lazy:
                row = store.index[uid]
                assert store.phase[row] == _engine.POD_BOUND, (uid, n)
                assert store.node_slot[row] == n._slot, (uid, n)
            if deep:
                # incremental accounting matches a fresh re-sum
                resum = sum_resources(
                    p.requests for p in n.pods.materialized_values())
                for uid in lazy:
                    row = store.index[uid]
                    resum = resum + Resources(store.cpu_m[row],
                                              store.mem_mb[row])
                assert used.cpu_m == resum.cpu_m, n
                assert abs(used.mem_mb - resum.mem_mb) < 1e-6, n
        if deep and self.arrays is not None:
            self.arrays.verify_against(self)
