"""Cluster + node state model (paper §4).

Nodes move through a small state machine::

    PROVISIONING --ready--> READY --taint--> TAINTED --untaint--> READY
          \\                                   |
           \\--------------- terminate --------+--> TERMINATED

``TAINTED`` mirrors the paper's *taint as unschedulable* (Alg. 6 step 3):
schedulers avoid tainted nodes unless no untainted node fits.

Capacity accounting is *request-based*, exactly like the default Kubernetes
scheduler (§4.1): the sum of requests of pods bound to a node never exceeds
its allocatable capacity, regardless of actual usage.

Accounting is **incremental**: ``Node.used`` is maintained on every
add_pod/remove_pod instead of re-summing resident pods on each access, and a
structure-of-arrays mirror (``repro.core.engine.ClusterArrays``) is kept in
lockstep so schedulers can vectorize filter+select.  Both the object path and
the array path read the *same* incrementally-maintained floats, so the two
engines are bit-for-bit identical.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.core import engine as _engine
from repro.core.pods import Pod
from repro.core.resources import Resources, sum_resources


class NodeState(enum.Enum):
    PROVISIONING = "provisioning"   # VM requested, not yet joined the cluster
    READY = "ready"
    TAINTED = "tainted"             # schedulable only as a last resort
    TERMINATED = "terminated"

    @property
    def value_code(self) -> int:
        """Int code used by the SoA mirror's state vector."""
        return _STATE_CODES[self]


_STATE_CODES = {
    NodeState.PROVISIONING: _engine.STATE_PROVISIONING,
    NodeState.READY: _engine.STATE_READY,
    NodeState.TAINTED: _engine.STATE_TAINTED,
    NodeState.TERMINATED: _engine.STATE_TERMINATED,
}

_node_seq = itertools.count()


@dataclasses.dataclass
class Node:
    """One worker (paper: m2.small VM; fleet: one TPU v5e host)."""

    allocatable: Resources
    node_type: str = "worker"
    autoscaled: bool = False            # created dynamically (Alg. 6 precondition)
    node_id: str = ""
    state: NodeState = NodeState.PROVISIONING
    provision_time: float = 0.0         # when the provider was asked for it
    ready_time: Optional[float] = None  # when it joined the cluster
    terminate_time: Optional[float] = None
    pods: Dict[int, Pod] = dataclasses.field(default_factory=dict)
    # Fleet extensions.
    speed_factor: float = 1.0           # <1.0 models a straggler node
    failed: bool = False
    oversub: bool = False               # request-sum may exceed allocatable

    def __post_init__(self):
        if not self.node_id:
            self.node_id = f"node-{next(_node_seq)}"
        # Incremental accounting (seeded from any pre-populated pods dict).
        self._used_cpu_m: int = 0
        self._used_mem_mb: float = 0.0
        self._moveable_count: int = 0
        self._batch_count: int = 0
        for p in self.pods.values():
            self._account_add(p)
        # SoA mirror back-references, set by Cluster.add_node.
        self._arrays: Optional[_engine.ClusterArrays] = None
        self._slot: Optional[int] = None

    # -- capacity ------------------------------------------------------------
    @property
    def used(self) -> Resources:
        return Resources(self._used_cpu_m, self._used_mem_mb)

    @property
    def free(self) -> Resources:
        return Resources(self.allocatable.cpu_m - self._used_cpu_m,
                         self.allocatable.mem_mb - self._used_mem_mb)

    def fits(self, req: Resources) -> bool:
        return req.fits_in(self.free)

    # -- queries used by the paper's algorithms ------------------------------
    @property
    def schedulable(self) -> bool:
        return self.state == NodeState.READY

    @property
    def last_resort(self) -> bool:
        return self.state == NodeState.TAINTED

    def moveable_pods(self) -> List[Pod]:
        return [p for p in self.pods.values() if p.moveable]

    def has_only_moveable(self) -> bool:
        return bool(self.pods) and self._moveable_count == len(self.pods)

    def has_moveable_and_batch(self) -> bool:
        return (self._moveable_count > 0 and self._batch_count > 0
                and self._moveable_count + self._batch_count == len(self.pods))

    # -- lifecycle -----------------------------------------------------------
    def _notify_state(self) -> None:
        if self._arrays is not None:
            self._arrays.sync_state(self._slot, self)

    def mark_ready(self, now: float) -> None:
        assert self.state == NodeState.PROVISIONING
        self.state = NodeState.READY
        self.ready_time = now
        self._notify_state()

    def taint(self) -> None:
        if self.state == NodeState.READY:
            self.state = NodeState.TAINTED
            self._notify_state()

    def untaint(self) -> None:
        if self.state == NodeState.TAINTED:
            self.state = NodeState.READY
            self._notify_state()

    def terminate(self, now: float) -> None:
        assert not self.pods, f"terminating non-empty node {self.node_id}"
        self.state = NodeState.TERMINATED
        self.terminate_time = now
        self._notify_state()

    # -- bindings ------------------------------------------------------------
    def _account_add(self, pod: Pod) -> None:
        self._used_cpu_m += pod.requests.cpu_m
        self._used_mem_mb += pod.requests.mem_mb
        if pod.moveable:
            self._moveable_count += 1
        if pod.is_batch:
            self._batch_count += 1

    def _account_remove(self, pod: Pod) -> None:
        self._used_cpu_m -= pod.requests.cpu_m
        self._used_mem_mb -= pod.requests.mem_mb
        if pod.moveable:
            self._moveable_count -= 1
        if pod.is_batch:
            self._batch_count -= 1

    def _notify_usage(self) -> None:
        if self._arrays is not None:
            self._arrays.sync_usage(self._slot, self)

    def add_pod(self, pod: Pod, *, enforce: bool = True) -> None:
        if enforce:
            assert pod.requests.fits_in(self.free), (
                f"overcommit on {self.node_id}: {pod} does not fit {self.free}")
        self.pods[pod.uid] = pod
        self._account_add(pod)
        self._notify_usage()

    def remove_pod(self, pod: Pod) -> None:
        del self.pods[pod.uid]
        self._account_remove(pod)
        self._notify_usage()

    def __repr__(self):
        return (f"Node({self.node_id}, {self.state.value}, "
                f"pods={len(self.pods)}, free={self.free})")


class Cluster:
    """The live cluster: the single source of truth (paper: etcd).

    ``arrays`` is the SoA mirror used by the vectorized schedulers / shadow
    capacity / scale-in; pass ``use_arrays=False`` (or set
    ``REPRO_SCHED_ENGINE=object``) to run the seed object-scan engine.

    The orchestrator registers ``on_bind`` / ``on_unbind`` / ``on_complete``
    callbacks so it can maintain its pending queue and running counters
    without rescanning every pod each cycle.
    """

    def __init__(self, use_arrays: Optional[bool] = None,
                 wave_select: Optional[str] = None):
        self.nodes: Dict[str, Node] = {}
        self.terminated: List[Node] = []    # kept for cost accounting
        if use_arrays is None:
            use_arrays = _engine.arrays_enabled_default()
        self.arrays: Optional[_engine.ClusterArrays] = (
            _engine.ClusterArrays(wave_select=wave_select)
            if use_arrays else None)
        self.on_bind: Optional[Callable[[Pod], None]] = None
        self.on_unbind: Optional[Callable[[Pod], None]] = None
        self.on_complete: Optional[Callable[[Pod], None]] = None

    # -- membership ----------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        self.nodes[node.node_id] = node
        if self.arrays is not None:
            node._arrays = self.arrays
            node._slot = self.arrays.add(node)
        return node

    def remove_node(self, node: Node, now: float) -> None:
        node.terminate(now)
        self.terminated.append(node)
        del self.nodes[node.node_id]
        if node._arrays is not None:
            node._arrays.remove(node._slot)
            node._arrays = None

    def get(self, node_id: str) -> Node:
        return self.nodes[node_id]

    # -- views ---------------------------------------------------------------
    def ready_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.state == NodeState.READY]

    def tainted_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.state == NodeState.TAINTED]

    def schedulable_nodes(self) -> List[Node]:
        """READY nodes; the scheduler falls back to TAINTED separately."""
        return self.ready_nodes()

    def provisioning_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values()
                if n.state == NodeState.PROVISIONING]

    def all_pods(self) -> List[Pod]:
        return [p for n in self.nodes.values() for p in n.pods.values()]

    def node_of(self, pod: Pod) -> Optional[Node]:
        return self.nodes.get(pod.node_id) if pod.node_id else None

    def node_by_slot(self, slot: int) -> Node:
        return self.nodes[self.arrays.node_ids[slot]]

    # -- bindings (paper §4.2 createBinding) ----------------------------------
    def bind(self, pod: Pod, node: Node, now: float, *,
             enforce: bool = True) -> None:
        node.add_pod(pod, enforce=enforce)
        pod.bind(node.node_id, now)
        if self.on_bind is not None:
            self.on_bind(pod)

    def bind_wave(self, bindings, now: float) -> None:
        """Commit one wave of scheduler-chosen ``(pod, node)`` binds.

        Equivalent to calling :meth:`bind` per pair — per-pod object effects
        (incremental node accounting, ``Pod.bind``, the ``on_bind`` callback)
        happen in wave order — except that:

        * the SoA mirror's usage columns are synced **once per touched node**
          after the loop instead of once per bind (the mirror is written by
          assignment from the node's final accounting, so the result is
          bit-identical);
        * the per-bind feasibility assert is skipped: the wave already
          established feasibility against bit-identical free values, and the
          per-cycle ``check_invariants`` still guards capacity.
        """
        touched: Dict[str, Node] = {}
        on_bind = self.on_bind
        for pod, node in bindings:
            node.pods[pod.uid] = pod
            node._account_add(pod)
            touched[node.node_id] = node
            pod.bind(node.node_id, now)
            if on_bind is not None:
                on_bind(pod)
        for node in touched.values():
            node._notify_usage()

    def unbind(self, pod: Pod, now: float, *, failed: bool = False) -> None:
        node = self.node_of(pod)
        if node is not None:
            node.remove_pod(pod)
        pod.evict(now, failed=failed)
        if self.on_unbind is not None:
            self.on_unbind(pod)

    def complete(self, pod: Pod, now: float) -> None:
        """A batch pod ran to completion: release capacity, mark SUCCEEDED."""
        node = self.node_of(pod)
        if node is not None:
            node.remove_pod(pod)
        pod.complete(now)
        if self.on_complete is not None:
            self.on_complete(pod)

    def complete_wave(self, pods, now: float) -> None:
        """Commit one batch of completions sharing a timestamp.

        Equivalent to calling :meth:`complete` per pod in order — per-pod
        object effects (incremental node accounting, ``Pod.complete``, the
        ``on_complete`` callback) are identical — except the SoA mirror's
        usage columns sync **once per touched node** after the loop instead
        of once per pod (assignment from the node's final accounting, so the
        mirror lands on bit-identical values)."""
        touched: Dict[str, Node] = {}
        nodes = self.nodes
        on_complete = self.on_complete
        for pod in pods:
            node = nodes.get(pod.node_id)
            if node is not None:
                del node.pods[pod.uid]
                node._account_remove(pod)
                touched[node.node_id] = node
            pod.complete(now)
            if on_complete is not None:
                on_complete(pod)
        for node in touched.values():
            node._notify_usage()

    # -- metrics fast path ----------------------------------------------------
    def utilization_totals(self):
        """``(n_nodes, ram_ratio_sum, cpu_ratio_sum, pod_count_sum)`` over
        READY|TAINTED nodes — the exact sums behind the Table-5 ratios.

        On the array engine this reads the mirror's incrementally-maintained
        sampling aggregates (O(dirty slots) since the last sample,
        ``engine.ClusterArrays.sample_totals``); the object path recomputes
        from scratch.  Both produce the correctly-rounded ``fsum`` of the
        same per-node ratios, so dividing by ``n_nodes`` gives Table-5
        values bit-identical across engines and across sampling strategies
        (``statistics.fmean(xs) == math.fsum(xs) / len(xs)``)."""
        if self.arrays is not None:
            return self.arrays.sample_totals()
        n, ram, cpu, ppn = self.utilization_view()
        return n, math.fsum(ram), math.fsum(cpu), sum(ppn)

    def utilization_view(self):
        """(n_nodes, ram_ratios, cpu_ratios, pods_per_node) over READY|TAINTED
        nodes, in insertion order.  Array path and object path produce
        bit-identical values (same floats, same elementwise ops).  The
        Table-5 sampler itself uses :meth:`utilization_totals`; this
        per-node view remains for diagnostics and as the from-scratch
        reference the aggregate parity tests compare against."""
        if self.arrays is not None:
            arr = self.arrays
            state = arr.live("state")
            mask = arr.live("active") & (
                (state == _engine.STATE_READY) | (state == _engine.STATE_TAINTED))
            alloc_c = arr.live("alloc_cpu")[mask]
            ram = arr.live("used_mem")[mask] / arr.live("alloc_mem")[mask]
            cpu = arr.live("used_cpu")[mask] / np.maximum(alloc_c, 1)
            ppn = arr.live("pod_count")[mask]
            return int(mask.sum()), ram, cpu, ppn
        nodes = [n for n in self.nodes.values()
                 if n.state in (NodeState.READY, NodeState.TAINTED)]
        ram = [n.used.mem_mb / n.allocatable.mem_mb for n in nodes]
        cpu = [n.used.cpu_m / max(n.allocatable.cpu_m, 1) for n in nodes]
        ppn = [len(n.pods) for n in nodes]
        return len(nodes), ram, cpu, ppn

    # -- invariant (property-tested) ------------------------------------------
    def check_invariants(self, deep: bool = False) -> None:
        if self.arrays is not None and not deep:
            # Vectorized fast path: capacity respected on every live node.
            # The orchestrator runs the deep check periodically so mirror
            # drift / pod-linkage bugs still surface on the array engine.
            arr = self.arrays
            live = arr.live("active") & ~arr.live("oversub")
            over_cpu = arr.live("used_cpu") > arr.live("alloc_cpu")
            over_mem = arr.live("used_mem") > arr.live("alloc_mem") + 1e-6
            bad = live & (over_cpu | over_mem)
            if bad.any():
                slot = int(np.argmax(bad))
                raise AssertionError(
                    f"capacity violated on {arr.node_ids[slot]}")
            return
        for n in self.nodes.values():
            if n.oversub:
                continue   # estimator-driven oversubscription is intentional
            used = n.used
            assert used.cpu_m <= n.allocatable.cpu_m, n
            assert used.mem_mb <= n.allocatable.mem_mb + 1e-6, n
            for p in n.pods.values():
                assert p.node_id == n.node_id, (p, n)
            if deep:
                # incremental accounting matches a fresh re-sum
                resum = sum_resources(p.requests for p in n.pods.values())
                assert used.cpu_m == resum.cpu_m, n
                assert abs(used.mem_mb - resum.mem_mb) < 1e-6, n
        if deep and self.arrays is not None:
            self.arrays.verify_against(self)
