"""The paper's contribution: cost-efficient scheduling, rescheduling and
autoscaling for container/job orchestration (Rodriguez & Buyya, 2018)."""

from repro.core.autoscaler import (AUTOSCALERS, Autoscaler, BindingAutoscaler,
                                   NodeProvider, PredictiveAutoscaler,
                                   SimpleAutoscaler, VoidAutoscaler)
from repro.core.cluster import Cluster, Node, NodeState
from repro.core.cost import CostModel
from repro.core.disruption import (CrashLoopInjector, DisruptionInjector,
                                   SpotReclaimInjector, ZoneOutageInjector)
from repro.core.experiment import (ExperimentSpec, build_simulation,
                                   run_all_combos, run_experiment,
                                   run_k8s_baseline)
from repro.core.failures import FailureInjector, StragglerInjector
from repro.core.metrics import ExperimentResult, MetricsCollector
from repro.core.orchestrator import Orchestrator
from repro.core.pods import Pod, PodKind, PodPhase, PodSpec
from repro.core.rescheduler import (RESCHEDULERS, BindingRescheduler,
                                    NonBindingRescheduler, Rescheduler,
                                    VoidRescheduler)
from repro.core.resources import Resources, gi
from repro.core.scheduler import (SCHEDULERS, BestFitBinPackingScheduler,
                                  FirstFitScheduler,
                                  KubernetesDefaultScheduler, Scheduler,
                                  WorstFitScheduler)
from repro.core.simulation import SimConfig, Simulation
from repro.core.workload import (JOB_TYPES, WORKLOAD_MIXES, Arrival,
                                 generate_workload, make_fleet_job_types)


def reset_id_counters() -> None:
    """Restart the global node/pod id sequences.

    Auto-generated node ids ("node-<seq>") order *lexicographically*, so any
    engine-vs-engine comparison (parity tests, benchmarks) must start both
    runs from the same counter value.  Test/bench isolation only — never
    call this inside a running simulation.
    """
    import itertools

    from repro.core import cluster as _cluster_mod
    from repro.core import pods as _pods_mod
    _cluster_mod._node_seq = itertools.count()
    _pods_mod._uid = itertools.count()

__all__ = [
    "AUTOSCALERS", "Autoscaler", "BindingAutoscaler", "NodeProvider",
    "PredictiveAutoscaler", "SimpleAutoscaler", "VoidAutoscaler",
    "Cluster", "Node", "NodeState",
    "CostModel", "CrashLoopInjector", "DisruptionInjector",
    "SpotReclaimInjector", "ZoneOutageInjector", "FailureInjector",
    "StragglerInjector", "ExperimentSpec", "build_simulation", "run_all_combos",
    "run_experiment", "run_k8s_baseline", "ExperimentResult",
    "MetricsCollector", "Orchestrator", "Pod", "PodKind", "PodPhase",
    "PodSpec", "RESCHEDULERS", "BindingRescheduler", "NonBindingRescheduler",
    "Rescheduler", "VoidRescheduler", "Resources", "gi", "SCHEDULERS",
    "BestFitBinPackingScheduler", "FirstFitScheduler",
    "KubernetesDefaultScheduler", "Scheduler", "WorstFitScheduler",
    "SimConfig", "Simulation", "JOB_TYPES", "WORKLOAD_MIXES", "Arrival",
    "generate_workload", "make_fleet_job_types", "reset_id_counters",
]
