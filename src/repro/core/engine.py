"""Array-backed cluster engine: a structure-of-arrays mirror of cluster state.

The discrete-event simulator's cycle hot path — filter+select over all nodes
for every pending pod — is O(pods x nodes) in Python objects.  This module
maintains a NumPy structure-of-arrays (SoA) mirror of the cluster
(:class:`ClusterArrays`) that is **incrementally updated** on
bind/unbind/add/remove/state changes, so the schedulers can run their
filter+select as a handful of masked vector reductions instead of object
scans.

On top of the mirror sits **wave placement** (:class:`WavePlacer`): instead
of binding pods one at a time through the object layer, the orchestrator
hands the scheduler a whole pending snapshot.  The scheduler places the wave
against the placer's *working copies* of the usage columns — accumulating
bind effects as array deltas — and the accumulated prefix is committed to
the ``Cluster``/``Node``/``Pod`` objects once per wave
(:meth:`repro.core.cluster.Cluster.bind_wave`) instead of once per pod.

Parity contract (enforced by ``tests/test_engine_parity.py``): every value in
the mirror is *assigned* from the corresponding node's incremental
accounting — never recomputed with a different operation order — so the
vectorized engine and the object-scan engine see bit-identical floats and
make bit-identical decisions.  Wave placement preserves the contract by
construction:

* working ``used_*`` columns are advanced with the same ``+=`` the object
  accounting uses, in the same bind order, on the same start values;
* working ``free_*`` entries are refreshed per bound slot as
  ``alloc[slot] - used[slot]`` — the identical elementwise operation
  ``free_views`` applies to the whole vector;
* selection reads the same masks/scores/tie-breaks as the per-pod path.

So pod *k* of a wave observes bit-identical frees to what it would have seen
had pods ``1..k-1`` been committed individually — same pods land on the same
nodes, with the same lowest-node_id tie-breaks.

The mirror also anchors three further array-native subsystems:

* **Table-5 sampling aggregates** — per-node utilization contribution
  columns with dirty tracking, so the 20 s metrics sampler costs O(dirty
  nodes) incremental maintenance plus one C-speed exact ``fsum`` instead of
  a per-node Python scan (see :meth:`ClusterArrays.sample_totals`);
* **segment-tree selection** (:class:`SegExtTree`) — an O(log n)
  first-extremum index over the wave path's cached score buffers, selectable
  against the flat argmin kernel via ``REPRO_WAVE_SELECT`` /
  ``ExperimentSpec(wave_select=...)`` (identical decisions, different
  constants; "auto" switches on cluster size);
* **pod state** (:class:`PodStore`) — uid-indexed SoA columns that are the
  source of truth for pod lifecycle on the array engine; ``Pod`` objects are
  lazily-materialized shells handed out only at API boundaries (callbacks,
  reschedulers/autoscalers, metrics, direct ``pods`` access, the object
  engine).  Arrival batches ingest in bulk, binds/completions commit as
  column writes, and the best-fit wave loop amortizes its extremum queries
  over runs of same-size pods (``Scheduler.select_wave_store``).

Slot discipline: slots are append-only (never reused), so ascending slot
order == ``Cluster.nodes`` insertion order.  This matters: Alg. 6 scale-in
iterates nodes in insertion order and termination order is behaviour.

Engine selection: the mirror is enabled by default; ``REPRO_SCHED_ENGINE=object``
(or ``Cluster(use_arrays=False)`` / ``ExperimentSpec(engine="object")``)
disables it, restoring the seed per-pod object-scan path (including the
per-pod scheduling loop in ``Orchestrator.cycle``) for parity testing and
benchmarking.
"""
from __future__ import annotations

import bisect
import itertools
import math
import os
from typing import List, Optional

import numpy as np

# Node-state codes (mirror of cluster.NodeState; kept as plain ints so the
# state array is an int8 vector).
STATE_PROVISIONING = 0
STATE_READY = 1
STATE_TAINTED = 2
STATE_TERMINATED = 3

# Pod-phase codes (PodStore.phase column).  Only the three *observable*
# phases exist at rest: ``Pod.evict`` passes through EVICTED/FAILED and lands
# back on PENDING within one call, so the column never needs those codes.
POD_PENDING = 0
POD_BOUND = 1
POD_SUCCEEDED = 2

# Pod-kind flag bits (PodStore.flags column, one byte per pod, derived from
# the immutable spec at ingest).
POD_F_BATCH = 1
POD_F_SERVICE = 2
POD_F_MOVEABLE = 4
POD_F_CHECKPOINTABLE = 8

# Below this many active nodes the flat C-speed argmin over the cached score
# buffer beats the Python-level O(log n) tree descent; "auto" wave selection
# switches to the segment tree only above it.  Measured on the CPU container
# (query + one real point update per placement): argmin 0.5us/2k nodes ->
# ~8us/64k nodes vs segtree ~4-6us roughly flat — crossover ~32k
# (``benchmarks/bench_sched_throughput.py --kernels`` re-measures).
SEGTREE_AUTO_MIN_NODES = 32768

WAVE_SELECT_MODES = ("auto", "argmin", "segtree")


def arrays_enabled_default() -> bool:
    """Engine selection: REPRO_SCHED_ENGINE=object forces the seed path."""
    return os.environ.get("REPRO_SCHED_ENGINE", "array").lower() != "object"


def wave_select_default() -> str:
    """Wave selection kernel: REPRO_WAVE_SELECT=argmin|segtree|auto (default
    auto — segment tree above SEGTREE_AUTO_MIN_NODES active nodes)."""
    return os.environ.get("REPRO_WAVE_SELECT", "auto").lower()


def wave_runlen_enabled() -> bool:
    """Run-length best-fit fast path: REPRO_WAVE_RUNLEN=0 disables it.

    Decision-identical to querying the extremum per pod (see
    ``Scheduler.select_wave_store``); the switch exists so parity tests can
    compare the two paths and so a regression can be bisected in the field.
    """
    return os.environ.get("REPRO_WAVE_RUNLEN", "1") != "0"


class ClusterArrays:
    """SoA mirror of per-node capacity, usage and lifecycle state.

    All arrays are capacity-doubling; the live prefix is ``[:self.n_slots]``.
    ``active`` masks out removed nodes (slots are never reused).

    **Metrics aggregates** (Table-5 sampling, paper §7.2): alongside the
    capacity columns the mirror maintains per-node *sampling contribution*
    columns — the RAM ratio, CPU ratio and pod count each READY|TAINTED node
    contributes to the 20 s utilization sample — plus running node/pod
    counters.  Any membership / state / usage mutation marks the slot
    *dirty*; :meth:`sample_totals` refreshes only the dirty slots
    (vectorized over the dirty index set) and produces the exact,
    correctly-rounded column sums the seed ``statistics.fmean``/``fsum``
    sampler computes — bit-identical by construction, because the final
    reduction is ``math.fsum`` over the contribution column (zeros for
    non-sampled slots change neither the exact sum nor its rounding).  A
    compensated running scalar cannot reproduce ``fsum``'s correct rounding
    bit-for-bit, so the per-tick cost is O(dirty) incremental maintenance
    plus one C-speed exact reduction, rather than the seed's per-node Python
    object scan.
    """

    def __init__(self, capacity: int = 64, wave_select: Optional[str] = None):
        self.n_slots = 0                       # slots ever allocated (monotone)
        # Monotone mutation counter: bumped on every membership / state /
        # usage change.  WavePlacer uses it to detect that its working
        # arrays went stale (e.g. a rescheduler evicted pods mid-cycle).
        self.version = 0
        if wave_select is None:
            wave_select = wave_select_default()
        if wave_select not in WAVE_SELECT_MODES:
            raise ValueError(f"wave_select must be one of {WAVE_SELECT_MODES},"
                             f" got {wave_select!r}")
        self.wave_select = wave_select
        self._cap = capacity
        self.alloc_cpu = np.zeros(capacity, np.int64)
        self.alloc_mem = np.zeros(capacity, np.float64)
        self.used_cpu = np.zeros(capacity, np.int64)
        self.used_mem = np.zeros(capacity, np.float64)
        self.state = np.full(capacity, STATE_TERMINATED, np.int8)
        self.autoscaled = np.zeros(capacity, bool)
        self.oversub = np.zeros(capacity, bool)
        self.active = np.zeros(capacity, bool)
        self.pod_count = np.zeros(capacity, np.int64)
        self.node_ids: List[str] = []          # slot -> node_id
        # Lexicographic-by-node_id order over *active* slots, for tie-breaks.
        self._sorted_ids: List[str] = []
        self._sorted_slot_list: List[int] = []
        self._sorted_slots = np.zeros(0, np.int64)
        self.id_rank = np.zeros(capacity, np.int64)   # slot -> rank in id order
        # Sampling contribution columns (plain Python containers: the exact
        # fsum reduction and the O(dirty) flush both run at scalar
        # granularity, where list/bytearray access beats NumPy indexing).
        self._samp_ram: List[float] = [0.0] * capacity   # slot -> RAM ratio
        self._samp_cpu: List[float] = [0.0] * capacity   # slot -> CPU ratio
        self._samp_ppn: List[int] = [0] * capacity       # slot -> pod count
        self._samp_in = bytearray(capacity)    # slot currently sampled?
        self._samp_n = 0                       # nodes contributing
        self._samp_pods = 0                    # exact running Σ pod_count
        self._dirty = bytearray(capacity)      # slot stale since last flush?
        self._dirty_slots: List[int] = []

    # -- growth ----------------------------------------------------------------
    def _grow(self) -> None:
        new_cap = self._cap * 2
        for name in ("alloc_cpu", "alloc_mem", "used_cpu", "used_mem",
                     "state", "autoscaled", "oversub", "active", "pod_count",
                     "id_rank"):
            old = getattr(self, name)
            new = np.zeros(new_cap, old.dtype)
            if name == "state":
                new[:] = STATE_TERMINATED
            new[:self._cap] = old
            setattr(self, name, new)
        extra = new_cap - self._cap
        self._samp_ram.extend([0.0] * extra)
        self._samp_cpu.extend([0.0] * extra)
        self._samp_ppn.extend([0] * extra)
        self._samp_in.extend(bytearray(extra))
        self._dirty.extend(bytearray(extra))
        self._cap = new_cap

    def _resync_order(self) -> None:
        self._sorted_slots = np.asarray(self._sorted_slot_list, np.int64)
        if self._sorted_slots.size:
            self.id_rank[self._sorted_slots] = np.arange(
                self._sorted_slots.size, dtype=np.int64)

    # -- membership ------------------------------------------------------------
    def add(self, node) -> int:
        """Register `node`, returning its (permanent) slot index."""
        if self.n_slots == self._cap:
            self._grow()
        slot = self.n_slots
        self.n_slots += 1
        self.node_ids.append(node.node_id)
        self.alloc_cpu[slot] = node.allocatable.cpu_m
        self.alloc_mem[slot] = node.allocatable.mem_mb
        self.autoscaled[slot] = node.autoscaled
        self.active[slot] = True
        pos = bisect.bisect_left(self._sorted_ids, node.node_id)
        self._sorted_ids.insert(pos, node.node_id)
        self._sorted_slot_list.insert(pos, slot)
        self._resync_order()
        self.sync_state(slot, node)
        self.sync_usage(slot, node)
        return slot

    def remove(self, slot: int) -> None:
        self.version += 1
        self.active[slot] = False
        self.state[slot] = STATE_TERMINATED
        if not self._dirty[slot]:
            self._dirty[slot] = 1
            self._dirty_slots.append(slot)
        pos = self._sorted_slot_list.index(slot)
        del self._sorted_ids[pos]
        del self._sorted_slot_list[pos]
        self._resync_order()

    # -- incremental sync (assignment-copy => bit-identical to the node) -------
    def sync_state(self, slot: int, node) -> None:
        self.version += 1
        self.state[slot] = node.state.value_code
        if not self._dirty[slot]:
            self._dirty[slot] = 1
            self._dirty_slots.append(slot)

    def sync_usage(self, slot: int, node) -> None:
        self.version += 1
        self.used_cpu[slot] = node._used_cpu_m
        self.used_mem[slot] = node._used_mem_mb
        self.pod_count[slot] = len(node.pods)
        self.oversub[slot] = node.oversub
        if not self._dirty[slot]:
            self._dirty[slot] = 1
            self._dirty_slots.append(slot)

    # -- vector views ----------------------------------------------------------
    def free_views(self):
        """(free_cpu, free_mem) over the live prefix — fresh arrays, same
        float op (alloc - used) the object path uses, so bit-identical."""
        m = self.n_slots
        return (self.alloc_cpu[:m] - self.used_cpu[:m],
                self.alloc_mem[:m] - self.used_mem[:m])

    def live(self, name: str) -> np.ndarray:
        return getattr(self, name)[:self.n_slots]

    # -- Table-5 sampling aggregates -------------------------------------------
    def sample_totals(self):
        """``(n_nodes, ram_ratio_sum, cpu_ratio_sum, pod_count_sum)`` over
        READY|TAINTED nodes — the exact sums the Table-5 sampler divides by
        ``n_nodes`` (paper §7.2).

        Incremental: only slots dirtied since the previous call are
        re-derived (one vectorized pass over the dirty index set); the float
        sums are then rounded exactly with ``math.fsum`` over the
        contribution columns, whose non-sampled entries are zero — so the
        result is bit-identical to the seed path's
        ``fsum(per-node ratios) `` regardless of which slots went dirty, in
        which order, or how the column is laid out."""
        d = self._dirty_slots
        if d:
            idx = np.fromiter(d, np.int64, len(d))
            st = self.state[idx]
            sampled = self.active[idx] & (
                (st == STATE_READY) | (st == STATE_TAINTED))
            # Same elementwise IEEE ops as the seed utilization scan.
            ram = self.used_mem[idx] / self.alloc_mem[idx]
            cpu = self.used_cpu[idx] / np.maximum(self.alloc_cpu[idx], 1)
            ppn = self.pod_count[idx]
            sr, sc = self._samp_ram, self._samp_cpu
            sp, si = self._samp_ppn, self._samp_in
            dirty = self._dirty
            dn = dp = 0
            for slot, f, r, c, p in zip(d, sampled.tolist(), ram.tolist(),
                                        cpu.tolist(), ppn.tolist()):
                if f:
                    sr[slot] = r
                    sc[slot] = c
                    if not si[slot]:
                        dn += 1
                        si[slot] = 1
                    dp += p - sp[slot]
                    sp[slot] = p
                elif si[slot]:
                    dn -= 1
                    dp -= sp[slot]
                    sr[slot] = 0.0
                    sc[slot] = 0.0
                    sp[slot] = 0
                    si[slot] = 0
                dirty[slot] = 0
            self._dirty_slots = []
            self._samp_n += dn
            self._samp_pods += dp
        return (self._samp_n, math.fsum(self._samp_ram),
                math.fsum(self._samp_cpu), self._samp_pods)

    # -- many-world export -----------------------------------------------------
    def lane_snapshot(self) -> dict:
        """Rank-ordered accounting columns for one many-world lane
        (`repro.manyworld`): copies of alloc/used/pod_count plus the READY
        mask over the active slots, permuted into lexicographic node_id
        order — the same permutation `WavePlacer` ranks by, so index ``r``
        here is the lane engine's node ``r``.  Fancy indexing copies float
        bits verbatim; the snapshot stays valid after the mirror moves on."""
        rank = self._sorted_slots
        return {
            "alloc_cpu": self.alloc_cpu[rank],
            "alloc_mem": self.alloc_mem[rank],
            "used_cpu": self.used_cpu[rank],
            "used_mem": self.used_mem[rank],
            "pod_count": self.pod_count[rank].copy(),
            "ready": self.state[rank] == STATE_READY,
        }

    # -- tie-breaks ------------------------------------------------------------
    def first_by_id(self, mask: np.ndarray) -> int:
        """Slot of the lexicographically-smallest node_id with mask True,
        or -1.  `mask` is over the live prefix."""
        s = self._sorted_slots
        if s.size == 0:
            return -1
        sel = mask[s]
        i = int(np.argmax(sel))
        return int(s[i]) if sel[i] else -1

    # -- consistency (deep invariant checks / property tests) ------------------
    def verify_against(self, cluster) -> None:
        """Assert the mirror matches the object model exactly."""
        seen = 0
        for node in cluster.nodes.values():
            slot = node._slot
            assert slot is not None and self.active[slot], node
            assert self.node_ids[slot] == node.node_id
            assert self.alloc_cpu[slot] == node.allocatable.cpu_m
            assert self.alloc_mem[slot] == node.allocatable.mem_mb
            assert self.used_cpu[slot] == node._used_cpu_m, node
            assert self.used_mem[slot] == node._used_mem_mb, node
            assert self.pod_count[slot] == len(node.pods), node
            assert self.state[slot] == node.state.value_code, node
            assert self.autoscaled[slot] == node.autoscaled
            seen += 1
        assert seen == int(self.active[:self.n_slots].sum())
        # id-order structure is a permutation of active slots, sorted
        ids = [self.node_ids[s] for s in self._sorted_slot_list]
        assert ids == sorted(ids)
        assert set(self._sorted_slot_list) == {
            n._slot for n in cluster.nodes.values()}
        # Sampling aggregates: a flush must reproduce a from-scratch scan.
        n, ram_sum, cpu_sum, pods_sum = self.sample_totals()
        m = self.n_slots
        st = self.state[:m]
        mask = self.active[:m] & ((st == STATE_READY) | (st == STATE_TAINTED))
        assert n == int(mask.sum()), (n, int(mask.sum()))
        ram = self.used_mem[:m][mask] / self.alloc_mem[:m][mask]
        cpu = self.used_cpu[:m][mask] / np.maximum(self.alloc_cpu[:m][mask], 1)
        assert ram_sum == math.fsum(ram.tolist()), "ram aggregate drifted"
        assert cpu_sum == math.fsum(cpu.tolist()), "cpu aggregate drifted"
        assert pods_sum == int(self.pod_count[:m][mask].sum())
        assert not self._dirty_slots and not any(self._dirty)


class SegExtTree:
    """First-extremum segment tree over one cached wave score buffer.

    Replaces the flat O(nodes) ``argmin``/``argmax`` of the cached-buffer
    wave path with an O(log nodes) descent: :meth:`argext` returns the
    *lowest rank attaining the extremum* (ties always prefer the left
    child), which in node-id rank order is exactly the lowest-node_id
    tie-break the flat reduction implements — so selections are
    bit-identical to the argmin path (``tests/test_engine_parity.py``
    asserts identical bind sequences under both kernels).

    Point updates (:meth:`update`) recompute the leaf's ancestors in
    O(log n), stopping early once an ancestor's value is unchanged.
    Construction is one vectorized pairwise reduction per level; levels are
    stored as plain Python lists because queries/updates run at scalar
    granularity, where list access beats NumPy indexing.

    Crossover: NumPy's flat argmin has far smaller constants, so the tree
    only wins above roughly ``SEGTREE_AUTO_MIN_NODES`` active nodes —
    ``wave_select="auto"`` picks per that threshold; ``"argmin"`` /
    ``"segtree"`` force a kernel.
    """

    __slots__ = ("levels", "mode_min", "fill", "n")

    def __init__(self, buf: np.ndarray, mode_min: bool):
        self.mode_min = mode_min
        self.fill = np.inf if mode_min else -np.inf
        self.n = int(buf.shape[0])
        red = np.minimum if mode_min else np.maximum
        lv = buf.astype(np.float64)            # bool masks become 0.0 / 1.0
        levels = []
        while True:
            if lv.shape[0] & 1 and lv.shape[0] > 1:
                lv = np.append(lv, self.fill)  # keep sibling pairs complete
            levels.append(lv.tolist())
            if lv.shape[0] <= 1:
                break
            lv = red(lv[0::2], lv[1::2])
        self.levels = levels

    def argext(self) -> int:
        """Lowest rank attaining the extremum, or -1 when the root is the
        fill value (every rank masked infeasible)."""
        levels = self.levels
        top = levels[-1][0]
        if top == self.fill:
            return -1
        i = 0
        # The extremum value propagates unchanged down the chosen path, and
        # preferring the left child on equality yields the first index.
        for k in range(len(levels) - 2, -1, -1):
            i <<= 1
            if levels[k][i] != top:
                i += 1
        return i

    def update(self, i: int, v: float) -> None:
        levels = self.levels
        levels[0][i] = v
        if self.mode_min:
            for k in range(1, len(levels)):
                j = i & ~1
                child = levels[k - 1]
                a, b = child[j], child[j + 1]
                nv = a if a < b else b
                i >>= 1
                parent = levels[k]
                if parent[i] == nv:
                    return
                parent[i] = nv
        else:
            for k in range(1, len(levels)):
                j = i & ~1
                child = levels[k - 1]
                a, b = child[j], child[j + 1]
                nv = a if a >= b else b
                i >>= 1
                parent = levels[k]
                if parent[i] == nv:
                    return
                parent[i] = nv


class WavePlacer:
    """Working state for placing one wave of pods against the SoA mirror.

    A placer snapshots the usage columns (working *copies*) and the lifecycle
    masks (READY / TAINTED) of a :class:`ClusterArrays` mirror.
    ``Scheduler.select_wave`` advances the working copies with :meth:`bind`
    as it places pods, so later pods of the wave see earlier placements
    **without any object-layer commit**; the orchestrator commits the
    accumulated bindings once per wave via ``Cluster.bind_wave``.

    Rank order: the working arrays cover the *active* slots permuted into
    **lexicographic node_id order** (``slot_of_rank[r]`` maps back to the
    mirror slot).  In rank space, ``argmin``/``argmax`` over a masked score
    buffer returns the *first* extremum — i.e. the lowest-node_id tie-break —
    in a single vector pass, replacing the per-pod masked-reduction +
    explicit tie-break chain of the iterated ``select_slot`` path.

    Bit-parity with committing per pod:

    * :meth:`bind` applies the identical ``+=`` the object accounting
      (``Node._account_add``) would apply, in the same order, on the same
      start values, then refreshes the bound rank's free entries as
      ``alloc[r] - used[r]`` — the same elementwise op
      ``ClusterArrays.free_views`` uses;
    * permuting into rank order copies float bits verbatim, and extremum /
      equality comparisons are order-independent, so selection decisions are
      identical to the slot-ordered per-pod path;
    * lifecycle masks cannot change inside a wave (reschedulers/autoscalers
      only run between waves), so snapshotting them is exact.

    ``cache`` memoizes, per request size, the feasibility mask and the
    policy's ready-masked score buffer; ``Scheduler.select_wave`` refreshes
    only the just-bound rank after each placement, making the per-pod filter
    cost O(1) amortized for repeated request sizes.

    Staleness: ``version`` captures ``ClusterArrays.version`` at snapshot
    time.  Any mirror mutation that did not flow through this placer (an
    eviction, a node add/remove/taint) bumps the mirror's counter;
    :meth:`in_sync` turning False tells the orchestrator to rebuild the
    placer before placing the rest of the snapshot.  After committing its own
    wave the orchestrator re-arms ``version`` to the post-commit value.
    """

    def __init__(self, arr: ClusterArrays):
        self.arr = arr
        self.version = arr.version
        rank = arr._sorted_slots            # active slots in node_id order
        self.slot_of_rank = rank
        self.slot_of_rank_list = rank.tolist()   # scalar reads in the pod loop
        self.n = rank.size
        self.used_cpu = arr.used_cpu[rank]  # fancy index => working copies
        self.used_mem = arr.used_mem[rank]
        self.alloc_cpu = arr.alloc_cpu[rank]
        self.alloc_mem = arr.alloc_mem[rank]
        self.free_cpu = self.alloc_cpu - self.used_cpu
        self.free_mem = self.alloc_mem - self.used_mem
        state = arr.state[rank]
        self.ready = state == STATE_READY
        self.tainted = state == STATE_TAINTED
        # Selection kernel for this wave: flat argmin/argmax over the cached
        # buffer, or the O(log n) segment tree (identical decisions).
        mode = arr.wave_select
        self.use_tree = (mode == "segtree"
                         or (mode == "auto" and self.n >= SEGTREE_AUTO_MIN_NODES))
        # (cpu_m, mem_mb) -> (fits, ready_mask, score_buf, requests, tree,
        #                     cpu_m, mem_mb); cache_list mirrors the values
        # for the per-bind refresh loop (no dict-view overhead per pod).
        self.cache: dict = {}
        self.cache_list: list = []
        # Request keys proven infeasible against this placer.  Sound as a
        # *latch* because working frees only decrease over a placer's
        # lifetime (binds consume capacity; anything that frees capacity
        # bumps the mirror version and forces a placer rebuild), so a size
        # that once found no READY or TAINTED node never fits again — a
        # saturated cycle's backlog skips the extremum query entirely.
        self.blocked_keys: set = set()

    def in_sync(self) -> bool:
        """True while no mirror mutation bypassed this placer."""
        return self.version == self.arr.version

    def bind(self, r: int, req) -> None:
        """Record a placement at rank ``r`` in the working arrays (no object
        commit).  Same ``+=`` / ``alloc - used`` float ops as the object
        path, so the rest of the wave sees bit-identical frees.
        (``Scheduler.select_wave`` inlines these four ops in its pod loop;
        this method is the documented reference implementation.)"""
        self.used_cpu[r] += req.cpu_m
        self.used_mem[r] += req.mem_mb
        self.free_cpu[r] = self.alloc_cpu[r] - self.used_cpu[r]
        self.free_mem[r] = self.alloc_mem[r] - self.used_mem[r]


# Phase-code <-> PodPhase mapping for shell materialization (built lazily so
# this module keeps importing before repro.core.pods on cold starts).
_PHASE_OBJ = None


def _phase_objects():
    global _PHASE_OBJ
    if _PHASE_OBJ is None:
        from repro.core.pods import PodPhase
        _PHASE_OBJ = {POD_PENDING: PodPhase.PENDING,
                      POD_BOUND: PodPhase.BOUND,
                      POD_SUCCEEDED: PodPhase.SUCCEEDED}
    return _PHASE_OBJ


class PodStore:
    """Uid-indexed SoA columns for pod state; ``Pod`` objects become shells.

    On the array engine the store — not a ``Pod`` instance — is the source
    of truth for every pod the orchestrator ingests:

    * ``Orchestrator.submit_wave`` bulk-ingests each presorted ARRIVAL batch
      straight into the columns (:meth:`ingest`) — no ``Pod`` construction,
      no per-pod heap push;
    * the wave scheduler reads request sizes and phases from the columns
      (``Scheduler.select_wave_store``);
    * bind/complete effects commit as column writes
      (``Cluster.bind_wave_store`` / ``Cluster.complete_wave_store``) when no
      external observer needs the objects.

    A ``Pod`` *shell* is materialized on demand (:meth:`pod_at`) only at API
    boundaries: registered callbacks, reschedulers/autoscalers handling a
    blocked pod, evictions, metrics/`_result`, direct ``orch.pods`` /
    ``node.pods`` access, and the seed object engine (which bypasses the
    store entirely).  Materialization reads the columns verbatim, so a shell
    is attribute-for-attribute identical to the object the seed path would
    have produced (property-tested).  Once a shell exists it becomes the
    mutable face of the pod and every subsequent transition — object-path or
    column-path — keeps the two in lockstep via the ``sync_*`` hooks, the
    same assignment-copy discipline :class:`ClusterArrays` uses for nodes.

    Storage: plain Python lists / bytearrays, not NumPy arrays — every hot
    access is scalar-granular (one pod at a time), where list indexing beats
    NumPy boxing; bulk ingest uses C-speed ``list.extend``.  Rows are
    append-only and allocated in uid order (uids come from the same global
    counter ``Pod.__init__`` uses), so row order == uid order == submission
    order.
    """

    def __init__(self, arr: ClusterArrays):
        self.arr = arr                     # node_id lookup for shells
        self.n_rows = 0
        self.index = {}                    # uid -> row
        # -- columns (one entry per row) --------------------------------------
        self.uid = []                      # int
        self.spec_id = []                  # int -> _spec_by_id
        self.cpu_m = []                    # int   (spec.requests.cpu_m)
        self.mem_mb = []                   # float (spec.requests.mem_mb)
        self.duration_s = []               # float (spec.duration_s)
        self.submit_time = []              # float
        self.pending_since = []            # float (current pending interval)
        self.phase = bytearray()           # POD_PENDING/BOUND/SUCCEEDED
        self.node_slot = []                # int, -1 == unbound
        self.bound_time = []               # float | None
        self.finish_time = []              # float | None
        self.incarnation = []              # int
        self.flags = bytearray()           # POD_F_* bits, from the spec
        self.lost_work_s = []              # float (Σ executed-but-not-durable)
        # Pending intervals closed by column-native bulk evictions
        # (Cluster.fail_node_store) for rows that never had a shell; a
        # later materialization transfers them onto the Pod and drops the
        # entry.  row -> [interval, ...]
        self.closed_intervals = {}
        # -- completion log ---------------------------------------------------
        # Append-only finish-time index written by the simulation's
        # completion scheduler: each cycle appends its newly bound batch
        # rows sorted by completion timestamp and pushes one POD_DONE event
        # per distinct timestamp carrying a ``(lo, hi)`` range into these
        # columns — replacing the per-pod ``(uid, incarnation)`` dict the
        # event path used to maintain.  ``done_incs`` snapshots each row's
        # incarnation at schedule time (the staleness check at fire time);
        # ``done_consumed`` counts fired entries, and the log resets to
        # empty whenever every scheduled entry has fired (bounding it by
        # the in-flight completion window, not the trace length).
        self.done_rows = []                # int (store row)
        self.done_incs = []                # int (incarnation when scheduled)
        self.done_consumed = 0
        # -- interned spec table ----------------------------------------------
        # Keyed by id(spec), not value: shells must carry the *identical*
        # spec object the seed path would have stored (``pod.spec is
        # arrival.spec``), the table keeps every interned spec alive so ids
        # stay unique, and identity hashing skips the frozen-dataclass
        # value hash on the ingest hot path.
        self._spec_by_id = []
        self._spec_ids = {}                # id(PodSpec) -> spec id
        self._spec_flags = []              # spec id -> POD_F_* byte
        self._spec_cpu = []                # spec id -> requests.cpu_m
        self._spec_mem = []                # spec id -> requests.mem_mb
        self._spec_dur = []                # spec id -> duration_s
        # -- materialized shells ----------------------------------------------
        self.shells = {}                   # row -> Pod

    # -- completion log --------------------------------------------------------
    def log_completions(self, rows, incs) -> tuple:
        """Append one same-timestamp completion bucket; returns its
        ``(lo, hi)`` range (the POD_DONE payload)."""
        lo = len(self.done_rows)
        self.done_rows.extend(rows)
        self.done_incs.extend(incs)
        return lo, len(self.done_rows)

    def consume_completions(self, lo: int, hi: int) -> None:
        """Mark one fired ``(lo, hi)`` bucket consumed; when every logged
        entry has fired the log resets, so its footprint tracks the
        in-flight completion window (POD_DONE events fire in time order,
        not log order — ranges stay valid because the reset only happens
        at quiescence)."""
        self.done_consumed += hi - lo
        if self.done_consumed == len(self.done_rows):
            self.done_rows.clear()
            self.done_incs.clear()
            self.done_consumed = 0

    # -- many-world export -----------------------------------------------------
    def lane_columns(self) -> dict:
        """Pending-row workload columns for one many-world lane
        (`repro.manyworld.lanes.stack_lanes` input): float64 request /
        duration / submit columns plus the batch-kind mask over the rows
        still PENDING, in row (== FIFO submission) order — the order the
        wave walks them.  Integer cpu_m is exact in float64."""
        pend = [row for row in range(self.n_rows)
                if self.phase[row] == POD_PENDING]
        return {
            "arrival_t": np.array([self.pending_since[r] for r in pend]),
            "cpu_m": np.array([float(self.cpu_m[r]) for r in pend]),
            "mem_mb": np.array([self.mem_mb[r] for r in pend]),
            "duration_s": np.array([self.duration_s[r] for r in pend]),
            "is_batch": np.array([not (self.flags[r] & POD_F_SERVICE)
                                  for r in pend], bool),
        }

    # -- spec interning --------------------------------------------------------
    def _intern_spec(self, spec) -> int:
        sid = self._spec_ids.get(id(spec))
        if sid is None:
            from repro.core.pods import PodKind
            sid = len(self._spec_by_id)
            self._spec_ids[id(spec)] = sid
            self._spec_by_id.append(spec)
            f = 0
            if spec.kind == PodKind.BATCH:
                f |= POD_F_BATCH
            elif spec.kind == PodKind.SERVICE:
                f |= POD_F_SERVICE
            if spec.moveable:
                f |= POD_F_MOVEABLE
            if spec.checkpointable:
                f |= POD_F_CHECKPOINTABLE
            self._spec_flags.append(f)
            self._spec_cpu.append(spec.requests.cpu_m)
            self._spec_mem.append(spec.requests.mem_mb)
            self._spec_dur.append(spec.duration_s)
        return sid

    # -- ingestion -------------------------------------------------------------
    def ingest(self, arrivals):
        """Bulk-ingest one presorted ARRIVAL batch; returns ``(rows, uids)``.

        Semantically identical to constructing one PENDING ``Pod`` per
        arrival in order — uids are drawn from the same global counter, and
        ``submit_time == pending_since == arrival.time`` — but pod state
        lands directly in the columns: the only per-pod Python work is spec
        interning (a dict hit) plus C-speed column extends.
        """
        from repro.core import pods as _pods_mod
        n = len(arrivals)
        first = self.n_rows
        ids = self._spec_ids
        intern = self._intern_spec
        for a in arrivals:               # register any first-seen specs
            if id(a.spec) not in ids:
                intern(a.spec)
        sids = [ids[id(a.spec)] for a in arrivals]
        times = [a.time for a in arrivals]
        uids = list(itertools.islice(_pods_mod._uid, n))
        spec_cpu, spec_mem, spec_dur = (self._spec_cpu, self._spec_mem,
                                        self._spec_dur)
        self.uid.extend(uids)
        self.spec_id.extend(sids)
        self.cpu_m.extend([spec_cpu[s] for s in sids])
        self.mem_mb.extend([spec_mem[s] for s in sids])
        self.duration_s.extend([spec_dur[s] for s in sids])
        self.submit_time.extend(times)
        self.pending_since.extend(times)
        self.phase.extend(bytes(n))              # POD_PENDING == 0
        self.node_slot.extend([-1] * n)
        self.bound_time.extend([None] * n)
        self.finish_time.extend([None] * n)
        self.incarnation.extend([0] * n)
        spec_flags = self._spec_flags
        self.flags.extend(bytes(spec_flags[s] for s in sids))
        self.lost_work_s.extend([0.0] * n)
        self.n_rows = first + n
        index = self.index
        for row, u in enumerate(uids, first):
            index[u] = row
        return range(first, first + n), uids

    def ingest_trace(self, trace, lo: int, hi: int):
        """Bulk-ingest rows ``[lo, hi)`` of a columnar trace
        (``repro.scenarios.trace.TraceStore``); returns
        ``(rows, uids, times)``.

        The trace-native twin of :meth:`ingest` — identical column writes
        (uids drawn from the same global counter, request sizes read from
        the interned spec tables, so the values are bit-identical to the
        ``Arrival`` path), but no ``Arrival`` or ``Pod`` object exists at
        any point: the per-row Python work is C-speed list building from
        the trace's NumPy columns.  ``duration_s`` copies the trace's
        per-row column — equal to the template's duration for plain traces,
        row-specific for heavy-tailed scenario families (shells for such
        rows materialize a ``dataclasses.replace``-d spec, see
        :meth:`pod_at`)."""
        from repro.core import pods as _pods_mod
        n = hi - lo
        first = self.n_rows
        sid_of = [self._intern_spec(s) for s in trace.templates]
        sids = [sid_of[t] for t in trace.template_id[lo:hi].tolist()]
        times = trace.arrival_time[lo:hi].tolist()
        uids = list(itertools.islice(_pods_mod._uid, n))
        spec_cpu, spec_mem = self._spec_cpu, self._spec_mem
        self.uid.extend(uids)
        self.spec_id.extend(sids)
        self.cpu_m.extend([spec_cpu[s] for s in sids])
        self.mem_mb.extend([spec_mem[s] for s in sids])
        self.duration_s.extend(trace.duration_s[lo:hi].tolist())
        self.submit_time.extend(times)
        self.pending_since.extend(times)
        self.phase.extend(bytes(n))              # POD_PENDING == 0
        self.node_slot.extend([-1] * n)
        self.bound_time.extend([None] * n)
        self.finish_time.extend([None] * n)
        self.incarnation.extend([0] * n)
        spec_flags = self._spec_flags
        self.flags.extend(bytes(spec_flags[s] for s in sids))
        self.lost_work_s.extend([0.0] * n)
        self.n_rows = first + n
        index = self.index
        for row, u in enumerate(uids, first):
            index[u] = row
        return range(first, first + n), uids, times

    def adopt(self, pod) -> int:
        """Register an externally-constructed (PENDING) ``Pod`` as a row.

        The object-path entry point (``Orchestrator.submit``, live-cluster
        submissions, tests): the pod itself stays the mutable face, the
        columns mirror it from day one."""
        row = self.index.get(pod.uid)
        if row is not None:
            return row
        row = self.n_rows
        self.n_rows = row + 1
        self.index[pod.uid] = row
        sid = self._intern_spec(pod.spec)
        self.uid.append(pod.uid)
        self.spec_id.append(sid)
        self.cpu_m.append(pod.spec.requests.cpu_m)
        self.mem_mb.append(pod.spec.requests.mem_mb)
        self.duration_s.append(pod.spec.duration_s)
        self.submit_time.append(pod.submit_time)
        self.pending_since.append(pod.pending_since)
        from repro.core.pods import PodPhase
        code = {PodPhase.PENDING: POD_PENDING, PodPhase.BOUND: POD_BOUND,
                PodPhase.SUCCEEDED: POD_SUCCEEDED}[pod.phase]
        self.phase.append(code)
        self.node_slot.append(-1)
        self.bound_time.append(pod.bound_time)
        self.finish_time.append(pod.finish_time)
        self.incarnation.append(pod.incarnation)
        self.flags.append(self._spec_flags[sid])
        self.lost_work_s.append(pod.lost_work_s)
        self.shells[row] = pod
        return row

    # -- shells ----------------------------------------------------------------
    def pod_at(self, row: int):
        """The ``Pod`` for ``row``, materializing (and caching) a shell from
        the columns on first access."""
        pod = self.shells.get(row)
        if pod is None:
            import dataclasses

            from repro.core.pods import Pod
            code = self.phase[row]
            slot = self.node_slot[row]
            bt = self.bound_time[row]
            spec = self._spec_by_id[self.spec_id[row]]
            if self.duration_s[row] != spec.duration_s:
                # Trace-native ingest with a per-row duration override
                # (heavy-tailed scenario families): the shell must carry
                # the row's true duration — an API-boundary object, so the
                # replace costs nothing on the hot path.
                spec = dataclasses.replace(
                    spec, duration_s=self.duration_s[row])
            # Intervals closed while the row was shell-less: bulk evictions
            # recorded them in closed_intervals (chronological), and an open
            # binding closes with the same `bound_time - pending_since`
            # float op Pod.bind applies — so the shell's list is exactly
            # what the seed object would carry.
            closed = self.closed_intervals.pop(row, None)
            intervals = list(closed) if closed is not None else []
            if bt is not None:
                intervals.append(bt - self.pending_since[row])
            pod = Pod._restore(
                spec=spec,
                submit_time=self.submit_time[row],
                uid=self.uid[row],
                phase=_phase_objects()[code],
                node_id=self.arr.node_ids[slot] if slot >= 0 else None,
                pending_since=self.pending_since[row],
                bound_time=bt,
                finish_time=self.finish_time[row],
                incarnation=self.incarnation[row],
                pending_intervals=intervals,
                lost_work_s=self.lost_work_s[row],
            )
            self.shells[row] = pod
        return pod

    def pod_by_uid(self, uid: int):
        return self.pod_at(self.index[uid])

    # -- object-path writeback (assignment-copy => bit-identical) --------------
    def sync_bind(self, pod, slot: int) -> None:
        row = self.index.get(pod.uid)
        if row is None:
            return
        self.phase[row] = POD_BOUND
        self.node_slot[row] = slot
        self.bound_time[row] = pod.bound_time

    def sync_unbind(self, pod) -> None:
        row = self.index.get(pod.uid)
        if row is None:
            return
        self.phase[row] = POD_PENDING
        self.node_slot[row] = -1
        self.bound_time[row] = None
        self.pending_since[row] = pod.pending_since
        self.incarnation[row] = pod.incarnation
        self.lost_work_s[row] = pod.lost_work_s

    def sync_complete(self, pod) -> None:
        row = self.index.get(pod.uid)
        if row is None:
            return
        self.phase[row] = POD_SUCCEEDED
        self.finish_time[row] = pod.finish_time

    # Column-path bind/complete commits live in Cluster.bind_wave_store /
    # Cluster.complete_wave_store, which interleave the column writes with
    # node accounting per entry; Pod semantics are preserved there (complete
    # retains node_slot/bound_time exactly like the object keeps node_id).

    # -- end-of-run aggregates -------------------------------------------------
    def pending_intervals_all(self):
        """Every pod's pending intervals (the multiset `_result` feeds to the
        metrics collector): shells contribute their recorded lists, shell-less
        rows derive their single interval from the columns."""
        out = []
        shells = self.shells
        ps = self.pending_since
        bt = self.bound_time
        closed = self.closed_intervals
        for row in range(self.n_rows):
            pod = shells.get(row)
            if pod is not None:
                out.extend(pod.pending_intervals)
            else:
                ci = closed.get(row)
                if ci is not None:
                    out.extend(ci)
                b = bt[row]
                if b is not None:
                    out.append(b - ps[row])
        return out

    def total_incarnations(self) -> int:
        """Σ incarnation — the seed's eviction count (columns are synced on
        every eviction, so no shell walk is needed)."""
        return sum(self.incarnation)

    def total_lost_work_s(self) -> float:
        """Σ lost_work_s over every row — bulk evictions write the column
        directly, object-path evictions sync it, so no shell walk is
        needed and the left-fold order (row == uid == submission order)
        matches the object engine's ``sum`` over ``orch.pods``."""
        return sum(self.lost_work_s, 0.0)

    # -- consistency (deep periodic invariant check) ---------------------------
    def audit_columns(self, cluster) -> None:
        """Vectorized deep audit: re-derive per-node accounting straight
        from the pod columns and compare against the mirror.

        Replaces the per-node object walk of
        ``Cluster.check_invariants(deep=True)`` on the array engine (a
        ROADMAP "next bottlenecks" item): the re-sum that used to
        materialize shells and iterate every resident in Python is now
        three ``bincount`` reductions over the bound rows — O(rows) at C
        speed, and **zero shells are materialized by the audit itself**.
        Shells that already exist are cross-checked attribute-for-attribute
        against their columns (the lockstep contract), which is O(shells),
        not O(rows).

        Checks:

        * every BOUND row sits on an active mirror slot;
        * per-slot Σcpu / Σmem / row-count over BOUND rows equal the
          mirror's ``used_cpu`` / ``used_mem`` / ``pod_count`` (cpu and
          counts exactly; mem to the seed walk's 1e-6 absolute tolerance —
          the re-sum's accumulation order differs from the incremental
          event order);
        * row ↔ residency linkage, bidirectionally: the BOUND uids grouped
          per slot equal each node's resident uid *set* (C-speed set
          equality — catches swapped residency between equal-request pods,
          which every aggregate above would miss);
        * materialized shells agree with their columns (phase,
          pending_since, bound/finish time, incarnation, node linkage).
        """
        arr = self.arr
        m = arr.n_slots
        n_rows = self.n_rows
        if n_rows:
            phase = np.frombuffer(self.phase, np.uint8, n_rows)
            bound = phase == POD_BOUND
            slots = np.asarray(self.node_slot, np.int64)[bound]
            assert slots.size == 0 or (
                slots.min() >= 0 and arr.active[slots].all()), \
                "bound pod on a missing/inactive node slot"
            cpu = np.asarray(self.cpu_m, np.float64)[bound]
            mem = np.asarray(self.mem_mb, np.float64)[bound]
            used_cpu = np.bincount(slots, weights=cpu, minlength=m)[:m]
            used_mem = np.bincount(slots, weights=mem, minlength=m)[:m]
            counts = np.bincount(slots, minlength=m)[:m]
            # Row ↔ residency linkage: group bound uids by slot and compare
            # against the node's resident key set.  (pod_count equality
            # below pins nodes with residents but no rows, so checking the
            # slots that *have* rows covers both directions.)
            if slots.size:
                uids = np.asarray(self.uid, np.int64)[bound]
                order = np.argsort(slots, kind="stable")
                s_sorted, u_sorted = slots[order], uids[order]
                cuts = np.flatnonzero(np.diff(s_sorted)) + 1
                slot_nodes = cluster._slot_nodes
                for slot, group in zip(
                        s_sorted[np.concatenate(([0], cuts))].tolist(),
                        np.split(u_sorted, cuts)):
                    node = slot_nodes[slot]
                    assert node is not None, f"bound rows on dead slot {slot}"
                    assert set(group.tolist()) == set(node.pods), \
                        f"row/residency drift on {node.node_id}"
        else:
            used_cpu = used_mem = np.zeros(m)
            counts = np.zeros(m, np.int64)
        live = arr.active[:m]
        # int64 column == float64 bincount sum: exact below 2**53.
        assert (arr.used_cpu[:m][live] == used_cpu[live]).all(), \
            "node used_cpu drifted from the pod columns"
        assert (np.abs(arr.used_mem[:m][live] - used_mem[live]) < 1e-6).all(), \
            "node used_mem drifted from the pod columns"
        assert (arr.pod_count[:m][live] == counts[live]).all(), \
            "node pod_count drifted from the pod columns"
        # Materialized shells stay in lockstep with their columns.
        from repro.core.pods import PodPhase
        rev = {PodPhase.PENDING: POD_PENDING, PodPhase.BOUND: POD_BOUND,
               PodPhase.SUCCEEDED: POD_SUCCEEDED}
        node_ids = arr.node_ids
        for row, pod in self.shells.items():
            assert rev[pod.phase] == self.phase[row], pod
            assert self.pending_since[row] == pod.pending_since, pod
            assert self.bound_time[row] == pod.bound_time, pod
            assert self.finish_time[row] == pod.finish_time, pod
            assert self.incarnation[row] == pod.incarnation, pod
            assert self.lost_work_s[row] == pod.lost_work_s, pod
            assert row not in self.closed_intervals, \
                f"closed_intervals survived materialization for row {row}"
            if pod.phase is PodPhase.BOUND:
                slot = self.node_slot[row]
                assert slot >= 0 and node_ids[slot] == pod.node_id, pod
                node = cluster.nodes.get(pod.node_id)
                assert node is not None and pod.uid in node.pods, pod

    # -- consistency (property tests) ------------------------------------------
    def verify_against(self, cluster) -> None:
        """Assert columns, shells and node residency agree exactly."""
        from repro.core.pods import PodPhase
        rev = {PodPhase.PENDING: POD_PENDING, PodPhase.BOUND: POD_BOUND,
               PodPhase.SUCCEEDED: POD_SUCCEEDED}
        assert len(self.uid) == self.n_rows == len(self.index)
        for row in range(self.n_rows):
            uid = self.uid[row]
            assert self.index[uid] == row
            pod = self.shells.get(row)
            if pod is not None:
                assert pod.uid == uid
                assert rev[pod.phase] == self.phase[row], pod
                assert self.pending_since[row] == pod.pending_since, pod
                assert self.bound_time[row] == pod.bound_time, pod
                assert self.finish_time[row] == pod.finish_time, pod
                assert self.incarnation[row] == pod.incarnation, pod
                assert self.lost_work_s[row] == pod.lost_work_s, pod
                if pod.phase == PodPhase.BOUND:
                    slot = self.node_slot[row]
                    assert slot >= 0
                    assert self.arr.node_ids[slot] == pod.node_id, pod
            if self.phase[row] == POD_BOUND:
                slot = self.node_slot[row]
                node = cluster.nodes.get(self.arr.node_ids[slot])
                assert node is not None, f"bound row {row} on dead node"
                assert uid in node.pods, f"bound row {row} missing from node"
