"""Array-backed cluster engine: a structure-of-arrays mirror of cluster state.

The discrete-event simulator's cycle hot path — filter+select over all nodes
for every pending pod — is O(pods x nodes) in Python objects.  This module
maintains a NumPy structure-of-arrays (SoA) mirror of the cluster that is
**incrementally updated** on bind/unbind/add/remove/state changes, so the
schedulers can run their filter+select as a handful of masked vector
reductions instead of object scans.

Parity contract (enforced by ``tests/test_engine_parity.py``): every value in
the mirror is *assigned* from the corresponding node's incremental
accounting — never recomputed with a different operation order — so the
vectorized engine and the object-scan engine see bit-identical floats and
make bit-identical decisions.

Slot discipline: slots are append-only (never reused), so ascending slot
order == ``Cluster.nodes`` insertion order.  This matters: Alg. 6 scale-in
iterates nodes in insertion order and termination order is behaviour.

Engine selection: the mirror is enabled by default; ``REPRO_SCHED_ENGINE=object``
(or ``Cluster(use_arrays=False)`` / ``ExperimentSpec(engine="object")``)
disables it, restoring the seed object-scan path for parity testing and
benchmarking.
"""
from __future__ import annotations

import bisect
import os
from typing import List, Optional

import numpy as np

# Node-state codes (mirror of cluster.NodeState; kept as plain ints so the
# state array is an int8 vector).
STATE_PROVISIONING = 0
STATE_READY = 1
STATE_TAINTED = 2
STATE_TERMINATED = 3


def arrays_enabled_default() -> bool:
    """Engine selection: REPRO_SCHED_ENGINE=object forces the seed path."""
    return os.environ.get("REPRO_SCHED_ENGINE", "array").lower() != "object"


class ClusterArrays:
    """SoA mirror of per-node capacity, usage and lifecycle state.

    All arrays are capacity-doubling; the live prefix is ``[:self.n_slots]``.
    ``active`` masks out removed nodes (slots are never reused).
    """

    def __init__(self, capacity: int = 64):
        self.n_slots = 0                       # slots ever allocated (monotone)
        self._cap = capacity
        self.alloc_cpu = np.zeros(capacity, np.int64)
        self.alloc_mem = np.zeros(capacity, np.float64)
        self.used_cpu = np.zeros(capacity, np.int64)
        self.used_mem = np.zeros(capacity, np.float64)
        self.state = np.full(capacity, STATE_TERMINATED, np.int8)
        self.autoscaled = np.zeros(capacity, bool)
        self.oversub = np.zeros(capacity, bool)
        self.active = np.zeros(capacity, bool)
        self.pod_count = np.zeros(capacity, np.int64)
        self.node_ids: List[str] = []          # slot -> node_id
        # Lexicographic-by-node_id order over *active* slots, for tie-breaks.
        self._sorted_ids: List[str] = []
        self._sorted_slot_list: List[int] = []
        self._sorted_slots = np.zeros(0, np.int64)
        self.id_rank = np.zeros(capacity, np.int64)   # slot -> rank in id order

    # -- growth ----------------------------------------------------------------
    def _grow(self) -> None:
        new_cap = self._cap * 2
        for name in ("alloc_cpu", "alloc_mem", "used_cpu", "used_mem",
                     "state", "autoscaled", "oversub", "active", "pod_count",
                     "id_rank"):
            old = getattr(self, name)
            new = np.zeros(new_cap, old.dtype)
            if name == "state":
                new[:] = STATE_TERMINATED
            new[:self._cap] = old
            setattr(self, name, new)
        self._cap = new_cap

    def _resync_order(self) -> None:
        self._sorted_slots = np.asarray(self._sorted_slot_list, np.int64)
        if self._sorted_slots.size:
            self.id_rank[self._sorted_slots] = np.arange(
                self._sorted_slots.size, dtype=np.int64)

    # -- membership ------------------------------------------------------------
    def add(self, node) -> int:
        """Register `node`, returning its (permanent) slot index."""
        if self.n_slots == self._cap:
            self._grow()
        slot = self.n_slots
        self.n_slots += 1
        self.node_ids.append(node.node_id)
        self.alloc_cpu[slot] = node.allocatable.cpu_m
        self.alloc_mem[slot] = node.allocatable.mem_mb
        self.autoscaled[slot] = node.autoscaled
        self.active[slot] = True
        pos = bisect.bisect_left(self._sorted_ids, node.node_id)
        self._sorted_ids.insert(pos, node.node_id)
        self._sorted_slot_list.insert(pos, slot)
        self._resync_order()
        self.sync_state(slot, node)
        self.sync_usage(slot, node)
        return slot

    def remove(self, slot: int) -> None:
        self.active[slot] = False
        self.state[slot] = STATE_TERMINATED
        pos = self._sorted_slot_list.index(slot)
        del self._sorted_ids[pos]
        del self._sorted_slot_list[pos]
        self._resync_order()

    # -- incremental sync (assignment-copy => bit-identical to the node) -------
    def sync_state(self, slot: int, node) -> None:
        self.state[slot] = node.state.value_code

    def sync_usage(self, slot: int, node) -> None:
        self.used_cpu[slot] = node._used_cpu_m
        self.used_mem[slot] = node._used_mem_mb
        self.pod_count[slot] = len(node.pods)
        self.oversub[slot] = node.oversub

    # -- vector views ----------------------------------------------------------
    def free_views(self):
        """(free_cpu, free_mem) over the live prefix — fresh arrays, same
        float op (alloc - used) the object path uses, so bit-identical."""
        m = self.n_slots
        return (self.alloc_cpu[:m] - self.used_cpu[:m],
                self.alloc_mem[:m] - self.used_mem[:m])

    def live(self, name: str) -> np.ndarray:
        return getattr(self, name)[:self.n_slots]

    # -- tie-breaks ------------------------------------------------------------
    def first_by_id(self, mask: np.ndarray) -> int:
        """Slot of the lexicographically-smallest node_id with mask True,
        or -1.  `mask` is over the live prefix."""
        s = self._sorted_slots
        if s.size == 0:
            return -1
        sel = mask[s]
        i = int(np.argmax(sel))
        return int(s[i]) if sel[i] else -1

    # -- consistency (deep invariant checks / property tests) ------------------
    def verify_against(self, cluster) -> None:
        """Assert the mirror matches the object model exactly."""
        seen = 0
        for node in cluster.nodes.values():
            slot = node._slot
            assert slot is not None and self.active[slot], node
            assert self.node_ids[slot] == node.node_id
            assert self.alloc_cpu[slot] == node.allocatable.cpu_m
            assert self.alloc_mem[slot] == node.allocatable.mem_mb
            assert self.used_cpu[slot] == node._used_cpu_m, node
            assert self.used_mem[slot] == node._used_mem_mb, node
            assert self.pod_count[slot] == len(node.pods), node
            assert self.state[slot] == node.state.value_code, node
            assert self.autoscaled[slot] == node.autoscaled
            seen += 1
        assert seen == int(self.active[:self.n_slots].sum())
        # id-order structure is a permutation of active slots, sorted
        ids = [self.node_ids[s] for s in self._sorted_slot_list]
        assert ids == sorted(ids)
        assert set(self._sorted_slot_list) == {
            n._slot for n in cluster.nodes.values()}
