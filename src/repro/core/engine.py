"""Array-backed cluster engine: a structure-of-arrays mirror of cluster state.

The discrete-event simulator's cycle hot path — filter+select over all nodes
for every pending pod — is O(pods x nodes) in Python objects.  This module
maintains a NumPy structure-of-arrays (SoA) mirror of the cluster
(:class:`ClusterArrays`) that is **incrementally updated** on
bind/unbind/add/remove/state changes, so the schedulers can run their
filter+select as a handful of masked vector reductions instead of object
scans.

On top of the mirror sits **wave placement** (:class:`WavePlacer`): instead
of binding pods one at a time through the object layer, the orchestrator
hands the scheduler a whole pending snapshot.  The scheduler places the wave
against the placer's *working copies* of the usage columns — accumulating
bind effects as array deltas — and the accumulated prefix is committed to
the ``Cluster``/``Node``/``Pod`` objects once per wave
(:meth:`repro.core.cluster.Cluster.bind_wave`) instead of once per pod.

Parity contract (enforced by ``tests/test_engine_parity.py``): every value in
the mirror is *assigned* from the corresponding node's incremental
accounting — never recomputed with a different operation order — so the
vectorized engine and the object-scan engine see bit-identical floats and
make bit-identical decisions.  Wave placement preserves the contract by
construction:

* working ``used_*`` columns are advanced with the same ``+=`` the object
  accounting uses, in the same bind order, on the same start values;
* working ``free_*`` entries are refreshed per bound slot as
  ``alloc[slot] - used[slot]`` — the identical elementwise operation
  ``free_views`` applies to the whole vector;
* selection reads the same masks/scores/tie-breaks as the per-pod path.

So pod *k* of a wave observes bit-identical frees to what it would have seen
had pods ``1..k-1`` been committed individually — same pods land on the same
nodes, with the same lowest-node_id tie-breaks.

Slot discipline: slots are append-only (never reused), so ascending slot
order == ``Cluster.nodes`` insertion order.  This matters: Alg. 6 scale-in
iterates nodes in insertion order and termination order is behaviour.

Engine selection: the mirror is enabled by default; ``REPRO_SCHED_ENGINE=object``
(or ``Cluster(use_arrays=False)`` / ``ExperimentSpec(engine="object")``)
disables it, restoring the seed per-pod object-scan path (including the
per-pod scheduling loop in ``Orchestrator.cycle``) for parity testing and
benchmarking.
"""
from __future__ import annotations

import bisect
import os
from typing import List, Optional

import numpy as np

# Node-state codes (mirror of cluster.NodeState; kept as plain ints so the
# state array is an int8 vector).
STATE_PROVISIONING = 0
STATE_READY = 1
STATE_TAINTED = 2
STATE_TERMINATED = 3


def arrays_enabled_default() -> bool:
    """Engine selection: REPRO_SCHED_ENGINE=object forces the seed path."""
    return os.environ.get("REPRO_SCHED_ENGINE", "array").lower() != "object"


class ClusterArrays:
    """SoA mirror of per-node capacity, usage and lifecycle state.

    All arrays are capacity-doubling; the live prefix is ``[:self.n_slots]``.
    ``active`` masks out removed nodes (slots are never reused).
    """

    def __init__(self, capacity: int = 64):
        self.n_slots = 0                       # slots ever allocated (monotone)
        # Monotone mutation counter: bumped on every membership / state /
        # usage change.  WavePlacer uses it to detect that its working
        # arrays went stale (e.g. a rescheduler evicted pods mid-cycle).
        self.version = 0
        self._cap = capacity
        self.alloc_cpu = np.zeros(capacity, np.int64)
        self.alloc_mem = np.zeros(capacity, np.float64)
        self.used_cpu = np.zeros(capacity, np.int64)
        self.used_mem = np.zeros(capacity, np.float64)
        self.state = np.full(capacity, STATE_TERMINATED, np.int8)
        self.autoscaled = np.zeros(capacity, bool)
        self.oversub = np.zeros(capacity, bool)
        self.active = np.zeros(capacity, bool)
        self.pod_count = np.zeros(capacity, np.int64)
        self.node_ids: List[str] = []          # slot -> node_id
        # Lexicographic-by-node_id order over *active* slots, for tie-breaks.
        self._sorted_ids: List[str] = []
        self._sorted_slot_list: List[int] = []
        self._sorted_slots = np.zeros(0, np.int64)
        self.id_rank = np.zeros(capacity, np.int64)   # slot -> rank in id order

    # -- growth ----------------------------------------------------------------
    def _grow(self) -> None:
        new_cap = self._cap * 2
        for name in ("alloc_cpu", "alloc_mem", "used_cpu", "used_mem",
                     "state", "autoscaled", "oversub", "active", "pod_count",
                     "id_rank"):
            old = getattr(self, name)
            new = np.zeros(new_cap, old.dtype)
            if name == "state":
                new[:] = STATE_TERMINATED
            new[:self._cap] = old
            setattr(self, name, new)
        self._cap = new_cap

    def _resync_order(self) -> None:
        self._sorted_slots = np.asarray(self._sorted_slot_list, np.int64)
        if self._sorted_slots.size:
            self.id_rank[self._sorted_slots] = np.arange(
                self._sorted_slots.size, dtype=np.int64)

    # -- membership ------------------------------------------------------------
    def add(self, node) -> int:
        """Register `node`, returning its (permanent) slot index."""
        if self.n_slots == self._cap:
            self._grow()
        slot = self.n_slots
        self.n_slots += 1
        self.node_ids.append(node.node_id)
        self.alloc_cpu[slot] = node.allocatable.cpu_m
        self.alloc_mem[slot] = node.allocatable.mem_mb
        self.autoscaled[slot] = node.autoscaled
        self.active[slot] = True
        pos = bisect.bisect_left(self._sorted_ids, node.node_id)
        self._sorted_ids.insert(pos, node.node_id)
        self._sorted_slot_list.insert(pos, slot)
        self._resync_order()
        self.sync_state(slot, node)
        self.sync_usage(slot, node)
        return slot

    def remove(self, slot: int) -> None:
        self.version += 1
        self.active[slot] = False
        self.state[slot] = STATE_TERMINATED
        pos = self._sorted_slot_list.index(slot)
        del self._sorted_ids[pos]
        del self._sorted_slot_list[pos]
        self._resync_order()

    # -- incremental sync (assignment-copy => bit-identical to the node) -------
    def sync_state(self, slot: int, node) -> None:
        self.version += 1
        self.state[slot] = node.state.value_code

    def sync_usage(self, slot: int, node) -> None:
        self.version += 1
        self.used_cpu[slot] = node._used_cpu_m
        self.used_mem[slot] = node._used_mem_mb
        self.pod_count[slot] = len(node.pods)
        self.oversub[slot] = node.oversub

    # -- vector views ----------------------------------------------------------
    def free_views(self):
        """(free_cpu, free_mem) over the live prefix — fresh arrays, same
        float op (alloc - used) the object path uses, so bit-identical."""
        m = self.n_slots
        return (self.alloc_cpu[:m] - self.used_cpu[:m],
                self.alloc_mem[:m] - self.used_mem[:m])

    def live(self, name: str) -> np.ndarray:
        return getattr(self, name)[:self.n_slots]

    # -- tie-breaks ------------------------------------------------------------
    def first_by_id(self, mask: np.ndarray) -> int:
        """Slot of the lexicographically-smallest node_id with mask True,
        or -1.  `mask` is over the live prefix."""
        s = self._sorted_slots
        if s.size == 0:
            return -1
        sel = mask[s]
        i = int(np.argmax(sel))
        return int(s[i]) if sel[i] else -1

    # -- consistency (deep invariant checks / property tests) ------------------
    def verify_against(self, cluster) -> None:
        """Assert the mirror matches the object model exactly."""
        seen = 0
        for node in cluster.nodes.values():
            slot = node._slot
            assert slot is not None and self.active[slot], node
            assert self.node_ids[slot] == node.node_id
            assert self.alloc_cpu[slot] == node.allocatable.cpu_m
            assert self.alloc_mem[slot] == node.allocatable.mem_mb
            assert self.used_cpu[slot] == node._used_cpu_m, node
            assert self.used_mem[slot] == node._used_mem_mb, node
            assert self.pod_count[slot] == len(node.pods), node
            assert self.state[slot] == node.state.value_code, node
            assert self.autoscaled[slot] == node.autoscaled
            seen += 1
        assert seen == int(self.active[:self.n_slots].sum())
        # id-order structure is a permutation of active slots, sorted
        ids = [self.node_ids[s] for s in self._sorted_slot_list]
        assert ids == sorted(ids)
        assert set(self._sorted_slot_list) == {
            n._slot for n in cluster.nodes.values()}


class WavePlacer:
    """Working state for placing one wave of pods against the SoA mirror.

    A placer snapshots the usage columns (working *copies*) and the lifecycle
    masks (READY / TAINTED) of a :class:`ClusterArrays` mirror.
    ``Scheduler.select_wave`` advances the working copies with :meth:`bind`
    as it places pods, so later pods of the wave see earlier placements
    **without any object-layer commit**; the orchestrator commits the
    accumulated bindings once per wave via ``Cluster.bind_wave``.

    Rank order: the working arrays cover the *active* slots permuted into
    **lexicographic node_id order** (``slot_of_rank[r]`` maps back to the
    mirror slot).  In rank space, ``argmin``/``argmax`` over a masked score
    buffer returns the *first* extremum — i.e. the lowest-node_id tie-break —
    in a single vector pass, replacing the per-pod masked-reduction +
    explicit tie-break chain of the iterated ``select_slot`` path.

    Bit-parity with committing per pod:

    * :meth:`bind` applies the identical ``+=`` the object accounting
      (``Node._account_add``) would apply, in the same order, on the same
      start values, then refreshes the bound rank's free entries as
      ``alloc[r] - used[r]`` — the same elementwise op
      ``ClusterArrays.free_views`` uses;
    * permuting into rank order copies float bits verbatim, and extremum /
      equality comparisons are order-independent, so selection decisions are
      identical to the slot-ordered per-pod path;
    * lifecycle masks cannot change inside a wave (reschedulers/autoscalers
      only run between waves), so snapshotting them is exact.

    ``cache`` memoizes, per request size, the feasibility mask and the
    policy's ready-masked score buffer; ``Scheduler.select_wave`` refreshes
    only the just-bound rank after each placement, making the per-pod filter
    cost O(1) amortized for repeated request sizes.

    Staleness: ``version`` captures ``ClusterArrays.version`` at snapshot
    time.  Any mirror mutation that did not flow through this placer (an
    eviction, a node add/remove/taint) bumps the mirror's counter;
    :meth:`in_sync` turning False tells the orchestrator to rebuild the
    placer before placing the rest of the snapshot.  After committing its own
    wave the orchestrator re-arms ``version`` to the post-commit value.
    """

    def __init__(self, arr: ClusterArrays):
        self.arr = arr
        self.version = arr.version
        rank = arr._sorted_slots            # active slots in node_id order
        self.slot_of_rank = rank
        self.n = rank.size
        self.used_cpu = arr.used_cpu[rank]  # fancy index => working copies
        self.used_mem = arr.used_mem[rank]
        self.alloc_cpu = arr.alloc_cpu[rank]
        self.alloc_mem = arr.alloc_mem[rank]
        self.free_cpu = self.alloc_cpu - self.used_cpu
        self.free_mem = self.alloc_mem - self.used_mem
        state = arr.state[rank]
        self.ready = state == STATE_READY
        self.tainted = state == STATE_TAINTED
        # (cpu_m, mem_mb) -> [fits, ready_mask, score_buf, requests]
        self.cache: dict = {}

    def in_sync(self) -> bool:
        """True while no mirror mutation bypassed this placer."""
        return self.version == self.arr.version

    def bind(self, r: int, req) -> None:
        """Record a placement at rank ``r`` in the working arrays (no object
        commit).  Same ``+=`` / ``alloc - used`` float ops as the object
        path, so the rest of the wave sees bit-identical frees."""
        self.used_cpu[r] += req.cpu_m
        self.used_mem[r] += req.mem_mb
        self.free_cpu[r] = self.alloc_cpu[r] - self.used_cpu[r]
        self.free_mem[r] = self.alloc_mem[r] - self.used_mem[r]
