"""Spot-market disruptions (robustness extension; paper §8 "reliability").

`repro.core.failures.FailureInjector` models independent hardware loss.
Real fleets built on preemptible capacity see three *additional* disruption
shapes, each with its own event kind in `repro.core.simulation`:

* `SpotReclaimInjector` — the provider reclaims a spot instance, but sends
  a **notice** (`NODE_NOTICE`) `notice_s` seconds before the kill.  The
  notice taints the node (no new placements) and gives the autoscaler a
  head start (`notify_preemption_notice`) so replacement capacity boots
  while the doomed node drains.  Reclaim pressure is per instance type —
  cheap types are flakier — keyed on `Node.node_type`.
* `ZoneOutageInjector` — correlated failure: nodes carry a zone label and
  a `ZONE_OUTAGE` event kills every live node in one seeded zone at once.
* `CrashLoopInjector` — software failure: a bound batch pod crashes
  (`POD_CRASH`), restarts with exponential backoff, and is abandoned after
  `restart_budget` crashes.

All injectors speak the `FailureInjector` protocol (`prime(sim)` /
`arm_node(sim, node)`) so they plug into `ExperimentSpec.failure_injector`
unchanged; `DisruptionInjector` composes several into one.  Every random
draw comes from a per-injector `np.random.default_rng(seed)` and both
engines replay the identical event sequence — disruption schedules are
part of the parity contract (see tests/test_chaos_trace.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.cluster import Node, NodeState
from repro.core.simulation import NODE_NOTICE, POD_CRASH, ZONE_OUTAGE
from repro.obs.recorder import R_CRASH, R_UNSPEC


@dataclasses.dataclass
class SpotReclaimInjector:
    """Per-instance-type spot reclaims with a notice-before-kill window.

    ``reclaim_mtbr_s`` maps ``Node.node_type`` to the mean time between
    reclaims for that type; types absent from the map use
    ``default_mtbr_s`` (``None`` → that type is never reclaimed).  Static
    nodes model on-demand capacity and are exempt unless
    ``arm_static_nodes`` is set.
    """

    reclaim_mtbr_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    default_mtbr_s: Optional[float] = None
    notice_s: float = 120.0
    seed: int = 0
    arm_static_nodes: bool = False

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def prime(self, sim) -> None:
        for node in sim.cluster.nodes.values():
            if self.arm_static_nodes or node.autoscaled:
                self.arm_node(sim, node)

    def arm_node(self, sim, node: Node) -> None:
        if not (self.arm_static_nodes or node.autoscaled):
            return
        mtbr = self.reclaim_mtbr_s.get(node.node_type, self.default_mtbr_s)
        if mtbr is None or mtbr <= 0.0 or mtbr == float("inf"):
            return   # this instance type is never reclaimed
        ttr = float(self._rng.exponential(mtbr))
        sim.push(sim.now + ttr, NODE_NOTICE, (node, self.notice_s))


@dataclasses.dataclass
class ZoneOutageInjector:
    """Correlated zone failure.

    Nodes are labelled round-robin over ``zones`` in the deterministic
    order they are armed (static nodes at `prime`, then `NODE_READY`
    order — identical on both engines).  Each outage event draws one zone
    and fails every live labelled node in it via `sim.fail_node`, so the
    victims flow through the ordinary NODE_FAIL recovery path (bulk
    column eviction, autoscaler cleanup, cost retirement).

    Schedule: either a fixed list of ``outage_times`` (absolute sim
    seconds) or a seeded renewal process with ``mean_interval_s``.
    PROVISIONING nodes are unlabelled (``zone == ""``) until they are
    armed at readiness, so an outage never targets capacity that has not
    booted — the provider's control plane, not the zone, owns it.
    """

    zones: Tuple[str, ...] = ("zone-a", "zone-b", "zone-c")
    outage_times: Tuple[float, ...] = ()
    mean_interval_s: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._next_zone = 0
        if self.outage_times and self.mean_interval_s is not None:
            raise ValueError("pass outage_times or mean_interval_s, not both")

    def _label(self, node: Node) -> None:
        if not node.zone:
            node.zone = self.zones[self._next_zone % len(self.zones)]
            self._next_zone += 1

    def prime(self, sim) -> None:
        for node in sim.cluster.nodes.values():
            self._label(node)
        for t in self.outage_times:
            sim.push(float(t), ZONE_OUTAGE, self)
        if self.mean_interval_s is not None:
            self._schedule_next(sim)

    def arm_node(self, sim, node: Node) -> None:
        self._label(node)

    def _schedule_next(self, sim) -> None:
        dt = float(self._rng.exponential(self.mean_interval_s))
        sim.push(sim.now + dt, ZONE_OUTAGE, self)

    def on_outage(self, sim) -> None:
        zone = self.zones[int(self._rng.integers(len(self.zones)))]
        victims = [node for node in list(sim.cluster.nodes.values())
                   if node.zone == zone
                   and node.state != NodeState.TERMINATED]
        sim.disruption_log.append(
            (sim.now, "zone_outage", zone, [n.node_id for n in victims]))
        for node in victims:
            sim.fail_node(node)
        if self.mean_interval_s is not None:
            self._schedule_next(sim)


@dataclasses.dataclass
class CrashLoopInjector:
    """Software crash-loops over bound batch pods.

    Every ``~Exp(mtbc_s)`` seconds one currently bound batch pod (chosen
    uniformly among those still under budget and past their backoff
    window) crashes: it is evicted ``failed=True`` and re-pends through
    the normal recovery machinery.  Each crash doubles the pod's backoff
    (``backoff_base_s * 2**(n-1)``) during which it cannot be chosen
    again; after ``restart_budget`` crashes the pod is never re-targeted
    (the restart budget is exhausted — it still runs, we just stop
    kicking it).
    """

    mtbc_s: float = 600.0
    seed: int = 0
    restart_budget: int = 3
    backoff_base_s: float = 60.0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._crashes: Dict[int, int] = {}      # pod uid -> crash count
        self._eligible_at: Dict[int, float] = {}  # uid -> backoff expiry

    def prime(self, sim) -> None:
        self._schedule_next(sim)

    def arm_node(self, sim, node: Node) -> None:
        pass   # crash-loops target pods, not nodes

    def _schedule_next(self, sim) -> None:
        dt = float(self._rng.exponential(self.mtbc_s))
        sim.push(sim.now + dt, POD_CRASH, self)

    def on_crash_event(self, sim) -> None:
        candidates = [uid for uid in sim.orch.bound_batch_uids()
                      if self._crashes.get(uid, 0) < self.restart_budget
                      and self._eligible_at.get(uid, 0.0) <= sim.now]
        if candidates:
            # Draw only when there is a choice: an empty tick must not
            # consume randomness, or the schedule would depend on how
            # often the workload happens to be idle.
            uid = candidates[int(self._rng.integers(len(candidates)))]
            pod = sim.orch.bound_batch_pod(uid)
            n = self._crashes.get(uid, 0) + 1
            self._crashes[uid] = n
            self._eligible_at[uid] = (
                sim.now + self.backoff_base_s * 2.0 ** (n - 1))
            obs = sim.obs
            if obs is not None:
                obs.reason = R_CRASH   # eviction attribution context
            try:
                sim.cluster.unbind(pod, sim.now, failed=True)
            finally:
                if obs is not None:
                    obs.reason = R_UNSPEC
            sim.disruption_log.append((sim.now, "pod_crash", uid, [n]))
        self._schedule_next(sim)

    def crash_counts(self) -> Dict[int, int]:
        return dict(self._crashes)


@dataclasses.dataclass
class DisruptionInjector:
    """Composite: fans `prime`/`arm_node` out to several injectors."""

    injectors: Tuple[object, ...] = ()

    def prime(self, sim) -> None:
        for inj in self.injectors:
            inj.prime(sim)

    def arm_node(self, sim, node: Node) -> None:
        for inj in self.injectors:
            inj.arm_node(sim, node)
