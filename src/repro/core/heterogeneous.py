"""Heterogeneous VM types (paper §8, implemented as a beyond-paper feature).

The paper assumes homogeneous workers and names heterogeneity as its first
future direction: "considering heterogeneous VMs could lead to a more
efficient use of resources and decreased cost."  This module provides:

* `InstanceCatalog` — priced VM/slice templates (cpu, mem, $/s);
* `HeterogeneousBindingAutoscaler` — the paper's binding autoscaler
  (Alg. 7 association semantics) that, on launch, picks the template with
  the lowest $/s among those that fit the triggering pod *and* best matches
  its shape (smallest feasible — bin-packing's "tight bin" intuition at
  provisioning time);
* pricing flows through `CostModel.price_table` so Fig.-3-style cost
  accounting just works.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.autoscaler import BindingAutoscaler, NodeProvider
from repro.core.cluster import Cluster, Node
from repro.core.cost import CostModel
from repro.core.pods import Pod
from repro.core.resources import Resources


@dataclasses.dataclass(frozen=True)
class InstanceType:
    name: str
    allocatable: Resources
    price_per_s: float
    provisioning_delay_s: float = 50.0


@dataclasses.dataclass
class InstanceCatalog:
    types: Tuple[InstanceType, ...]

    def price_table(self) -> Dict[str, float]:
        return {t.name: t.price_per_s for t in self.types}

    def type_by_name(self, name: str) -> Optional[InstanceType]:
        return next((t for t in self.types if t.name == name), None)

    def cheapest_fitting(self, req: Resources) -> Optional[InstanceType]:
        feasible = [t for t in self.types if req.fits_in(t.allocatable)]
        if not feasible:
            return None
        # lowest price first; tie-break on smallest capacity (tightest bin)
        return min(feasible, key=lambda t: (t.price_per_s,
                                            t.allocatable.mem_mb))


# The paper's testbed family, extended with two plausible Nectar siblings.
NECTAR_CATALOG = InstanceCatalog(types=(
    InstanceType("m2.tiny", Resources(460, 1.5 * 1024), 0.0055),
    InstanceType("m2.small", Resources(940, 3.5 * 1024), 0.011),
    InstanceType("m2.medium", Resources(1900, 5.5 * 1024), 0.022),
))


class HeterogeneousProvider(NodeProvider):
    """Sim provider that launches a *specific* instance type."""

    def __init__(self, catalog: InstanceCatalog, cost: CostModel):
        self.catalog = catalog
        self.cost = cost
        cost.price_table.update(catalog.price_table())
        self._sim = None
        self.launched_types: List[str] = []

    def attach(self, sim) -> None:
        self._sim = sim

    def make_static_node(self, itype: InstanceType, now: float = 0.0) -> Node:
        node = Node(allocatable=itype.allocatable, node_type=itype.name,
                    autoscaled=False, provision_time=now)
        node.mark_ready(now)
        self.cost.on_provision(node, now)
        return node

    def launch_node(self, now: float,
                    itype: Optional[InstanceType] = None) -> Node:
        itype = itype or self.catalog.types[-1]
        node = Node(allocatable=itype.allocatable, node_type=itype.name,
                    autoscaled=True, provision_time=now)
        self.cost.on_provision(node, now)
        self.launched_types.append(itype.name)
        assert self._sim is not None, "attach(sim) first"
        self._sim.schedule_node_ready(node, now + itype.provisioning_delay_s)
        return node

    def terminate_node(self, node: Node, now: float) -> None:
        self.cost.on_deprovision(node, now)


class HeterogeneousBindingAutoscaler(BindingAutoscaler):
    """Alg. 7 with a per-launch instance-type decision (paper §4.2: "the
    autoscaler can then decide the number and *type* of VMs to launch")."""

    name = "binding-hetero"

    def __init__(self, provider: HeterogeneousProvider):
        super().__init__(provider)
        self.catalog = provider.catalog

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        if pod.uid in self._pod_to_node:
            return
        for tracker in sorted(self._tracked.values(),
                              key=lambda t: t.node.node_id):
            if pod.requests.fits_in(tracker.planned_free):
                tracker.assigned[pod.uid] = pod.requests
                self._pod_to_node[pod.uid] = tracker.node.node_id
                return
        itype = self.catalog.cheapest_fitting(pod.requests)
        if itype is None:
            return   # no instance type can ever host this pod
        node = self.provider.launch_node(now, itype)
        cluster.add_node(node)
        from repro.core.autoscaler import _ProvisioningTracker
        self._tracked[node.node_id] = _ProvisioningTracker(
            node=node, assigned={pod.uid: pod.requests})
        self._pod_to_node[pod.uid] = node.node_id

    def _launch_replacement(self, node: Node, now: float) -> Node:
        """Replace a reclaimed spot node with its own instance type (the
        workload that fit there fits its twin); unknown types fall back to
        the provider's default (largest) template."""
        return self.provider.launch_node(
            now, self.catalog.type_by_name(node.node_type))
