"""Synthetic workloads (paper §7.1, Tables 1 & 2).

Six job types (three batch sizes that "sleep", three nginx-like services) and
three arrival patterns:

* **bursty** — exponential inter-arrivals, mean 10 s;
* **slow**   — exponential inter-arrivals, mean 60 s;
* **mixed**  — alternating bursty/slow periods, first chosen at random,
  ≥ 10 jobs per period.

NOTE (documented in DESIGN.md §7): the paper's Table 2 swaps the bursty/slow
means relative to the prose; we follow the prose (bursty = 10 s, slow = 60 s),
which also matches the Table 5 pending-time pattern.

The fleet adaptation exposes the same generator with job templates whose
requests are chips/HBM and whose payloads are real JAX train/serve jobs
(`repro.cloud.local_provider`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.pods import PodKind, PodSpec
from repro.core.resources import Resources, gi

# --- Table 1: job types -------------------------------------------------------

JOB_TYPES: Dict[str, PodSpec] = {
    "batch_small": PodSpec("batch_small", PodKind.BATCH,
                           Resources(100, gi(0.3)), duration_s=5 * 60),
    "batch_med": PodSpec("batch_med", PodKind.BATCH,
                         Resources(200, gi(0.6)), duration_s=10 * 60),
    "batch_large": PodSpec("batch_large", PodKind.BATCH,
                           Resources(300, gi(0.9)), duration_s=15 * 60),
    "service_small": PodSpec("service_small", PodKind.SERVICE,
                             Resources(100, gi(1.0)), moveable=True),
    "service_med": PodSpec("service_med", PodKind.SERVICE,
                           Resources(200, gi(1.4)), moveable=True),
    "service_large": PodSpec("service_large", PodKind.SERVICE,
                             Resources(300, gi(2.359)), moveable=True),
}

# --- Table 2: workload mixes (counts per type) --------------------------------

WORKLOAD_MIXES: Dict[str, Dict[str, int]] = {
    "bursty": {"batch_small": 10, "batch_med": 8, "batch_large": 5,
               "service_small": 6, "service_med": 12, "service_large": 9},
    "slow": {"batch_small": 17, "batch_med": 11, "batch_large": 4,
             "service_small": 6, "service_med": 7, "service_large": 5},
    "mixed": {"batch_small": 6, "batch_med": 7, "batch_large": 9,
              "service_small": 7, "service_med": 11, "service_large": 10},
}

BURSTY_MEAN_S = 10.0
SLOW_MEAN_S = 60.0
MIN_JOBS_PER_PERIOD = 10


def mix_templates(name: str):
    """One Table-2 mix as ``(templates, probabilities)``.

    The sampling distribution behind the workload: scenario generators
    (``repro.scenarios.generators``) draw template ids from it instead of
    materializing the finite multiset, which generalizes the paper's 50-job
    mixes to traces of any length."""
    if name not in WORKLOAD_MIXES:
        raise KeyError(f"unknown workload {name!r}; one of {list(WORKLOAD_MIXES)}")
    mix = WORKLOAD_MIXES[name]
    templates = [JOB_TYPES[t] for t in mix]
    total = float(sum(mix.values()))
    return templates, [c / total for c in mix.values()]


@dataclasses.dataclass(frozen=True)
class Arrival:
    time: float
    spec: PodSpec


def _job_multiset(mix: Dict[str, int]) -> List[PodSpec]:
    jobs: List[PodSpec] = []
    for type_name, count in mix.items():
        jobs.extend([JOB_TYPES[type_name]] * count)
    return jobs


def generate_workload(name: str, seed: int = 0,
                      moveable_services: bool = True) -> List[Arrival]:
    """Returns the arrival sequence for one of the paper's three workloads.

    Jobs are drawn without replacement from the Table 2 multiset in random
    order ("jobs were selected at random with equal probability"); delays are
    exponential with the workload's mean.
    """
    if name not in WORKLOAD_MIXES:
        raise KeyError(f"unknown workload {name!r}; one of {list(WORKLOAD_MIXES)}")
    rng = np.random.default_rng(seed)
    jobs = _job_multiset(WORKLOAD_MIXES[name])
    order = rng.permutation(len(jobs))
    jobs = [jobs[i] for i in order]
    if not moveable_services:
        jobs = [dataclasses.replace(j, moveable=False) if j.moveable else j
                for j in jobs]

    arrivals: List[Arrival] = []
    t = 0.0
    if name == "mixed":
        # Alternating bursty/slow periods, first chosen at random, >=10 jobs each.
        bursty_first = bool(rng.integers(0, 2))
        idx = 0
        period = 0
        while idx < len(jobs):
            is_bursty = (period % 2 == 0) == bursty_first
            mean = BURSTY_MEAN_S if is_bursty else SLOW_MEAN_S
            remaining = len(jobs) - idx
            if remaining <= 2 * MIN_JOBS_PER_PERIOD:
                n = remaining          # avoid a trailing too-short period
            else:
                n = int(rng.integers(MIN_JOBS_PER_PERIOD, remaining -
                                     MIN_JOBS_PER_PERIOD + 1))
            for _ in range(n):
                t += float(rng.exponential(mean))
                arrivals.append(Arrival(t, jobs[idx]))
                idx += 1
            period += 1
    else:
        mean = BURSTY_MEAN_S if name == "bursty" else SLOW_MEAN_S
        for spec in jobs:
            t += float(rng.exponential(mean))
            arrivals.append(Arrival(t, spec))
    return arrivals


def make_fleet_job_types(chips_per_host: int = 4,
                         hbm_gb_per_chip: float = 16.0) -> Dict[str, PodSpec]:
    """TPU-fleet job templates with the same small/med/large structure.

    Requests are expressed in the host's resource units: ``cpu_m`` = chip
    milli-shares (1000 per chip), ``mem_mb`` = HBM MB.  Training jobs are
    checkpointable (the fleet's notion of a moveable batch workload is
    resume-from-checkpoint rather than K8s-moveable, see pods.py).
    """
    hbm = chips_per_host * hbm_gb_per_chip * 1024.0
    return {
        "train_small": PodSpec("train_small", PodKind.BATCH,
                               Resources(1000, hbm * 0.10), duration_s=5 * 60,
                               checkpointable=True, checkpoint_interval_s=30),
        "train_med": PodSpec("train_med", PodKind.BATCH,
                             Resources(2000, hbm * 0.20), duration_s=10 * 60,
                             checkpointable=True, checkpoint_interval_s=30),
        "train_large": PodSpec("train_large", PodKind.BATCH,
                               Resources(3000, hbm * 0.30), duration_s=15 * 60,
                               checkpointable=True, checkpoint_interval_s=30),
        "serve_small": PodSpec("serve_small", PodKind.SERVICE,
                               Resources(1000, hbm * 0.25), moveable=True),
        "serve_med": PodSpec("serve_med", PodKind.SERVICE,
                             Resources(2000, hbm * 0.35), moveable=True),
        "serve_large": PodSpec("serve_large", PodKind.SERVICE,
                               Resources(3000, hbm * 0.60), moveable=True),
    }
