"""The control loop (paper §4.2, Algorithm 1).

::

    while the scheduler exit condition is not satisfied
        get all pending tasks
        for each pending task t
            schedule t
            if t cannot be placed
                reschedule
                if rescheduling failed
                    scale out
        scale in

Semantics matched to the paper:

* a successful **non-binding** reschedule leaves the evictees *and* the
  triggering pod in the queue for the *next* cycle — so that cycle is not
  "fully successful" and scale-in is skipped;
* **scale-in runs only when every pending pod of the cycle was placed**;
* pods created by evictions during a cycle wait until the next cycle
  (we iterate over a snapshot of the queue).

Two cycle engines implement the "for each pending task t: schedule t" body:

* **wave placement** (array engine, default) — the whole pending snapshot is
  handed to ``Scheduler.select_wave``, which places it against a
  ``WavePlacer``'s working arrays; the placed prefix is committed to the
  object model once per wave (``Cluster.bind_wave``) instead of once per
  pod.  When a pod blocks, the wave flushes, the paper's
  reschedule/scale-out path runs for that pod, and the wave resumes after
  it — reusing the same placer when the mirror's version counter shows the
  blocked-pod handling didn't mutate the cluster.  Decisions are
  bit-identical to the per-pod loop (``tests/test_engine_parity.py``).
* **per-pod loop** (seed object engine, ``REPRO_SCHED_ENGINE=object``) —
  one ``Scheduler.schedule`` call per pending pod, kept verbatim as the
  parity reference.

Queueing is event-driven, not scan-driven: the orchestrator registers
bind/unbind/complete callbacks on the cluster and maintains the pending set
as a min-heap keyed on ``(pending_since, uid)`` with lazy invalidation, so a
cycle's FIFO snapshot costs O(k) pops for the k pending pods (plus dropping
any entries staled by binds since) instead of filtering and re-sorting a
buffer of every pod ever submitted.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import engine as _engine
from repro.core.autoscaler import Autoscaler
from repro.core.cluster import Cluster
from repro.core.pods import Pod, PodPhase
from repro.core.rescheduler import Rescheduler, RescheduleOutcome
from repro.core.scheduler import Scheduler


@dataclasses.dataclass
class CycleStats:
    placed: int = 0
    unschedulable: int = 0
    rescheduled: int = 0
    scale_out_requests: int = 0
    scale_ins: int = 0
    all_placed: bool = True


class Orchestrator:
    """Glues scheduler + rescheduler + autoscaler over one cluster.

    Owns the pending queue (two-level (pending_since, uid) structure fed by
    cluster bind/unbind callbacks) and the running counters the simulator's
    exit condition reads.  ``cycle`` is paper Alg. 1; on the array engine it
    places each cycle's snapshot in waves (see ``_cycle_wave``), on the
    object engine it runs the seed per-pod loop — both bit-identical."""

    def __init__(self, cluster: Cluster, scheduler: Scheduler,
                 rescheduler: Rescheduler, autoscaler: Autoscaler,
                 straggler_threshold: float = 0.0,
                 on_evict: Optional[Callable[[Pod, float], None]] = None):
        self.cluster = cluster
        self.scheduler = scheduler
        self.rescheduler = rescheduler
        self.autoscaler = autoscaler
        self.pods: List[Pod] = []          # every pod ever submitted
        self.total_evictions = 0
        self.total_scale_outs = 0
        self.total_scale_ins = 0
        # Fleet extension: evict checkpointable batch pods running on nodes
        # slower than `straggler_threshold` × nominal speed (0 disables).
        self.straggler_threshold = straggler_threshold
        self.on_evict = on_evict
        # Event-driven queue + counters (maintained via cluster callbacks).
        # Two-level pending queue keyed on (pending_since, uid): a min-heap
        # of entries pushed since the last snapshot, merged into the carried
        # sorted pending prefix by pending_pods().  Entries go stale when
        # their pod binds (and possibly re-pends with a new pending_since) —
        # snapshots drop them lazily.  push_seq only breaks ties between
        # duplicate (pending_since, uid) entries so the heap never compares
        # Pod objects.
        self._pending_heap: List[Tuple[float, int, int, Pod]] = []
        self._pending_sorted: List[Tuple[float, int, int, Pod]] = []
        self._push_seq = itertools.count()
        self._bound_batch: Dict[int, Pod] = {}     # uid -> BOUND batch pod
        self._newly_bound_batch: List[Pod] = []    # drained by the simulator
        self.n_pending = 0
        self.n_batch_total = 0
        self.n_batch_done = 0
        self.n_service_total = 0
        self.n_service_bound = 0
        self._cycle_count = 0
        cluster.on_bind = self._on_pod_bound
        cluster.on_unbind = self._on_pod_unbound
        cluster.on_complete = self._on_pod_completed

    # -- cluster callbacks -------------------------------------------------------
    def _on_pod_bound(self, pod: Pod) -> None:
        self.n_pending -= 1
        if pod.is_batch:
            self._bound_batch[pod.uid] = pod
            self._newly_bound_batch.append(pod)
        elif pod.is_service:
            self.n_service_bound += 1

    def _on_pod_unbound(self, pod: Pod) -> None:
        # evict() recreates the pod as a fresh PENDING incarnation
        self.n_pending += 1
        self._push_pending(pod)
        if pod.is_batch:
            self._bound_batch.pop(pod.uid, None)
        elif pod.is_service:
            self.n_service_bound -= 1

    def _on_pod_completed(self, pod: Pod) -> None:
        self._bound_batch.pop(pod.uid, None)
        self.n_batch_done += 1

    def drain_newly_bound_batch(self) -> List[Pod]:
        """Batch pods bound (or re-bound) since the last drain; the simulator
        schedules one completion event per (pod, incarnation)."""
        out = self._newly_bound_batch
        self._newly_bound_batch = []
        return out

    # -- queue ------------------------------------------------------------------
    def _push_pending(self, pod: Pod) -> None:
        heapq.heappush(self._pending_heap,
                       (pod.pending_since, pod.uid, next(self._push_seq), pod))

    def submit(self, pod: Pod) -> None:
        """Enqueue a newly-created pod (simulator ARRIVAL handler)."""
        self.pods.append(pod)
        self._push_pending(pod)
        self.n_pending += 1
        if pod.is_batch:
            self.n_batch_total += 1
        elif pod.is_service:
            self.n_service_total += 1

    def submit_wave(self, arrivals) -> None:
        """Create and enqueue one pod per arrival of an ARRIVAL batch.

        Equivalent to ``submit(Pod(spec=a.spec, submit_time=a.time))`` per
        entry, with the per-pod call overhead hoisted out of the loop —
        the simulator's batched-arrival handler is the only caller."""
        pods = self.pods
        heap = self._pending_heap
        seq = self._push_seq
        n_batch = n_service = 0
        for a in arrivals:
            pod = Pod(spec=a.spec, submit_time=a.time)
            pods.append(pod)
            heapq.heappush(heap, (pod.pending_since, pod.uid, next(seq), pod))
            if pod.is_batch:
                n_batch += 1
            elif pod.is_service:
                n_service += 1
        self.n_pending += len(arrivals)
        self.n_batch_total += n_batch
        self.n_service_total += n_service

    def pending_pods(self) -> List[Pod]:
        """Currently-pending pods, FIFO by (pending_since, uid).

        O(k + j·log j) snapshot for k pending pods and j pushes since the
        last snapshot: the previous snapshot is carried forward *already
        sorted*, the j new entries drain from the heap in key order, and the
        two sorted streams merge in one pass — nothing is re-sorted.  Lazy
        invalidation drops each stale entry exactly once during the merge:
        an entry is stale when its pod is no longer PENDING, when it was
        re-pended with a newer ``pending_since`` (bound then evicted — the
        eviction pushed a fresh entry), or when it is a same-key duplicate
        (bound and evicted twice at one timestamp)."""
        heap = self._pending_heap
        if heap:
            # Draining the whole heap == sorting it (keys are unique), and
            # one C-level sort beats n heappops.
            fresh = sorted(heap)
            heap.clear()
            merged = (heapq.merge(self._pending_sorted, fresh)
                      if self._pending_sorted else fresh)
        else:
            merged = self._pending_sorted
        out: List[Pod] = []
        entries: List[Tuple[float, int, int, Pod]] = []
        seen = set()
        pending = PodPhase.PENDING
        for entry in merged:
            ps, uid, _, pod = entry
            if (pod.phase is pending and pod.pending_since == ps
                    and uid not in seen):
                seen.add(uid)
                out.append(pod)
                entries.append(entry)
        self._pending_sorted = entries
        return out

    def running_pods(self) -> List[Pod]:
        return [p for p in self.pods if p.phase == PodPhase.BOUND]

    def batch_all_done(self) -> bool:
        return self.n_batch_done == self.n_batch_total

    def services_all_bound(self) -> bool:
        return self.n_service_bound == self.n_service_total

    def has_running_batch(self) -> bool:
        return bool(self._bound_batch)

    # -- Algorithm 1 --------------------------------------------------------------
    def cycle(self, now: float) -> CycleStats:
        """One scheduling cycle (paper Alg. 1): place the pending snapshot,
        reschedule/scale-out per blocked pod, scale in after a fully
        successful cycle.  Dispatches to wave placement on the array engine
        and to the seed per-pod loop otherwise; both produce bit-identical
        bindings and stats."""
        stats = CycleStats()
        if self.straggler_threshold > 0:
            self._mitigate_stragglers(now)
        snapshot = self.pending_pods()
        if self.cluster.arrays is not None:
            self._cycle_wave(snapshot, now, stats)
        else:
            self._cycle_per_pod(snapshot, now, stats)
        if stats.all_placed:
            removed = self.autoscaler.scale_in(self.cluster, now)
            stats.scale_ins = len(removed)
            self.total_scale_ins += len(removed)
        # Fast (vectorized) invariant every cycle; full object-walk +
        # mirror cross-check periodically so drift can't hide for a run.
        self._cycle_count += 1
        self.cluster.check_invariants(deep=self._cycle_count % 64 == 0)
        return stats

    def _cycle_wave(self, snapshot: List[Pod], now: float,
                    stats: CycleStats) -> None:
        """Wave placement (array engine): place the snapshot in batches.

        Each ``select_wave`` call places a maximal prefix of the remaining
        snapshot against the placer's working arrays; the prefix is committed
        to the object model in one ``bind_wave``, then the blocked pod (if
        any) goes through the paper's reschedule/scale-out path and the wave
        resumes after it.  The placer — including its per-request-size filter
        caches — is reused across waves as long as the mirror's version
        counter proves nothing mutated cluster state behind its back."""
        arr = self.cluster.arrays
        placer = None
        start = 0
        while start < len(snapshot):
            if placer is None or not placer.in_sync():
                placer = _engine.WavePlacer(arr)
            bindings, blocked = self.scheduler.select_wave(
                placer, snapshot, start)
            if bindings:
                by_slot = self.cluster.node_by_slot
                self.cluster.bind_wave(
                    [(pod, by_slot(slot)) for pod, slot in bindings], now)
                placer.version = arr.version   # re-arm: our own commit
                stats.placed += len(bindings)
            if blocked is None:
                return
            self._handle_unschedulable(snapshot[blocked], now, stats)
            start = blocked + 1

    def _cycle_per_pod(self, snapshot: List[Pod], now: float,
                       stats: CycleStats) -> None:
        """Seed per-pod loop (object engine): the parity reference."""
        for pod in snapshot:
            if pod.phase != PodPhase.PENDING:
                continue   # a binding rescheduler may have placed it already
            if self.scheduler.schedule(self.cluster, pod, now):
                stats.placed += 1
                continue
            self._handle_unschedulable(pod, now, stats)

    def _handle_unschedulable(self, pod: Pod, now: float,
                              stats: CycleStats) -> None:
        """Alg. 1 fallback chain for one unplaceable pod: reschedule, and on
        failure request scale-out (shared by both cycle engines)."""
        stats.unschedulable += 1
        stats.all_placed = False
        outcome = self.rescheduler.reschedule(self.cluster, pod, now)
        if outcome == RescheduleOutcome.WAIT:
            return   # age gate: suppress autoscaling for this pod too
        if outcome == RescheduleOutcome.RESCHEDULED:
            stats.rescheduled += 1
            # Binding rescheduler may have bound the pod itself.
            if pod.phase != PodPhase.PENDING:
                stats.placed += 1
                stats.unschedulable -= 1
            return
        stats.scale_out_requests += 1
        self.total_scale_outs += 1
        self.autoscaler.scale_out(self.cluster, pod, now)

    # -- fleet extension: straggler mitigation -----------------------------------
    def _mitigate_stragglers(self, now: float) -> None:
        # uid order == submission order (uids are monotone), matching the
        # seed's scan over self.pods.
        for uid in sorted(self._bound_batch):
            pod = self._bound_batch[uid]
            if not pod.spec.checkpointable:
                continue
            node = self.cluster.node_of(pod)
            if node is None or node.speed_factor >= self.straggler_threshold:
                continue
            if self.on_evict:
                self.on_evict(pod, now)
            self.cluster.unbind(pod, now)   # checkpoint + requeue elsewhere
            node.taint()                    # cordon the straggler
            self.total_evictions += 1
