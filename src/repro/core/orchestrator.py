"""The control loop (paper §4.2, Algorithm 1).

::

    while the scheduler exit condition is not satisfied
        get all pending tasks
        for each pending task t
            schedule t
            if t cannot be placed
                reschedule
                if rescheduling failed
                    scale out
        scale in

Semantics matched to the paper:

* a successful **non-binding** reschedule leaves the evictees *and* the
  triggering pod in the queue for the *next* cycle — so that cycle is not
  "fully successful" and scale-in is skipped;
* **scale-in runs only when every pending pod of the cycle was placed**;
* pods created by evictions during a cycle wait until the next cycle
  (we iterate over a snapshot of the queue).

Queueing is event-driven, not scan-driven: the orchestrator registers
bind/unbind/complete callbacks on the cluster and maintains a real pending
buffer plus running counters, so each cycle sorts only the currently-pending
pods instead of re-sorting every pod ever submitted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.autoscaler import Autoscaler
from repro.core.cluster import Cluster
from repro.core.pods import Pod, PodPhase
from repro.core.rescheduler import Rescheduler, RescheduleOutcome
from repro.core.scheduler import Scheduler


@dataclasses.dataclass
class CycleStats:
    placed: int = 0
    unschedulable: int = 0
    rescheduled: int = 0
    scale_out_requests: int = 0
    scale_ins: int = 0
    all_placed: bool = True


class Orchestrator:
    """Glues scheduler + rescheduler + autoscaler over one cluster."""

    def __init__(self, cluster: Cluster, scheduler: Scheduler,
                 rescheduler: Rescheduler, autoscaler: Autoscaler,
                 straggler_threshold: float = 0.0,
                 on_evict: Optional[Callable[[Pod, float], None]] = None):
        self.cluster = cluster
        self.scheduler = scheduler
        self.rescheduler = rescheduler
        self.autoscaler = autoscaler
        self.pods: List[Pod] = []          # every pod ever submitted
        self.total_evictions = 0
        self.total_scale_outs = 0
        self.total_scale_ins = 0
        # Fleet extension: evict checkpointable batch pods running on nodes
        # slower than `straggler_threshold` × nominal speed (0 disables).
        self.straggler_threshold = straggler_threshold
        self.on_evict = on_evict
        # Event-driven queue + counters (maintained via cluster callbacks).
        self._pending_buf: List[Pod] = []
        self._bound_batch: Dict[int, Pod] = {}     # uid -> BOUND batch pod
        self._newly_bound_batch: List[Pod] = []    # drained by the simulator
        self.n_pending = 0
        self.n_batch_total = 0
        self.n_batch_done = 0
        self.n_service_total = 0
        self.n_service_bound = 0
        self._cycle_count = 0
        cluster.on_bind = self._on_pod_bound
        cluster.on_unbind = self._on_pod_unbound
        cluster.on_complete = self._on_pod_completed

    # -- cluster callbacks -------------------------------------------------------
    def _on_pod_bound(self, pod: Pod) -> None:
        self.n_pending -= 1
        if pod.is_batch:
            self._bound_batch[pod.uid] = pod
            self._newly_bound_batch.append(pod)
        elif pod.is_service:
            self.n_service_bound += 1

    def _on_pod_unbound(self, pod: Pod) -> None:
        # evict() recreates the pod as a fresh PENDING incarnation
        self.n_pending += 1
        self._pending_buf.append(pod)
        if pod.is_batch:
            self._bound_batch.pop(pod.uid, None)
        elif pod.is_service:
            self.n_service_bound -= 1

    def _on_pod_completed(self, pod: Pod) -> None:
        self._bound_batch.pop(pod.uid, None)
        self.n_batch_done += 1

    def drain_newly_bound_batch(self) -> List[Pod]:
        """Batch pods bound (or re-bound) since the last drain; the simulator
        schedules one completion event per (pod, incarnation)."""
        out = self._newly_bound_batch
        self._newly_bound_batch = []
        return out

    # -- queue ------------------------------------------------------------------
    def submit(self, pod: Pod) -> None:
        self.pods.append(pod)
        self._pending_buf.append(pod)
        self.n_pending += 1
        if pod.is_batch:
            self.n_batch_total += 1
        elif pod.is_service:
            self.n_service_total += 1

    def pending_pods(self) -> List[Pod]:
        """Currently-pending pods, FIFO by (pending_since, uid).  Compacts the
        buffer: stale entries (bound since) drop out, duplicates (bound then
        evicted while still buffered) dedupe by uid."""
        seen = set()
        out = []
        for p in self._pending_buf:
            if p.phase == PodPhase.PENDING and p.uid not in seen:
                seen.add(p.uid)
                out.append(p)
        out.sort(key=lambda p: (p.pending_since, p.uid))
        self._pending_buf = list(out)
        return out

    def running_pods(self) -> List[Pod]:
        return [p for p in self.pods if p.phase == PodPhase.BOUND]

    def batch_all_done(self) -> bool:
        return self.n_batch_done == self.n_batch_total

    def services_all_bound(self) -> bool:
        return self.n_service_bound == self.n_service_total

    def has_running_batch(self) -> bool:
        return bool(self._bound_batch)

    # -- Algorithm 1 --------------------------------------------------------------
    def cycle(self, now: float) -> CycleStats:
        stats = CycleStats()
        if self.straggler_threshold > 0:
            self._mitigate_stragglers(now)
        snapshot = self.pending_pods()
        for pod in snapshot:
            if pod.phase != PodPhase.PENDING:
                continue   # a binding rescheduler may have placed it already
            if self.scheduler.schedule(self.cluster, pod, now):
                stats.placed += 1
                continue
            stats.unschedulable += 1
            stats.all_placed = False
            outcome = self.rescheduler.reschedule(self.cluster, pod, now)
            if outcome == RescheduleOutcome.WAIT:
                continue   # age gate: suppress autoscaling for this pod too
            if outcome == RescheduleOutcome.RESCHEDULED:
                stats.rescheduled += 1
                # Binding rescheduler may have bound the pod itself.
                if pod.phase != PodPhase.PENDING:
                    stats.placed += 1
                    stats.unschedulable -= 1
                continue
            stats.scale_out_requests += 1
            self.total_scale_outs += 1
            self.autoscaler.scale_out(self.cluster, pod, now)
        if stats.all_placed:
            removed = self.autoscaler.scale_in(self.cluster, now)
            stats.scale_ins = len(removed)
            self.total_scale_ins += len(removed)
        # Fast (vectorized) invariant every cycle; full object-walk +
        # mirror cross-check periodically so drift can't hide for a run.
        self._cycle_count += 1
        self.cluster.check_invariants(deep=self._cycle_count % 64 == 0)
        return stats

    # -- fleet extension: straggler mitigation -----------------------------------
    def _mitigate_stragglers(self, now: float) -> None:
        # uid order == submission order (uids are monotone), matching the
        # seed's scan over self.pods.
        for uid in sorted(self._bound_batch):
            pod = self._bound_batch[uid]
            if not pod.spec.checkpointable:
                continue
            node = self.cluster.node_of(pod)
            if node is None or node.speed_factor >= self.straggler_threshold:
                continue
            if self.on_evict:
                self.on_evict(pod, now)
            self.cluster.unbind(pod, now)   # checkpoint + requeue elsewhere
            node.taint()                    # cordon the straggler
            self.total_evictions += 1
