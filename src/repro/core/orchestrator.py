"""The control loop (paper §4.2, Algorithm 1).

::

    while the scheduler exit condition is not satisfied
        get all pending tasks
        for each pending task t
            schedule t
            if t cannot be placed
                reschedule
                if rescheduling failed
                    scale out
        scale in

Semantics matched to the paper:

* a successful **non-binding** reschedule leaves the evictees *and* the
  triggering pod in the queue for the *next* cycle — so that cycle is not
  "fully successful" and scale-in is skipped;
* **scale-in runs only when every pending pod of the cycle was placed**;
* pods created by evictions during a cycle wait until the next cycle
  (we iterate over a snapshot of the queue).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.autoscaler import Autoscaler
from repro.core.cluster import Cluster
from repro.core.pods import Pod, PodPhase
from repro.core.rescheduler import Rescheduler, RescheduleOutcome
from repro.core.scheduler import Scheduler


@dataclasses.dataclass
class CycleStats:
    placed: int = 0
    unschedulable: int = 0
    rescheduled: int = 0
    scale_out_requests: int = 0
    scale_ins: int = 0
    all_placed: bool = True


class Orchestrator:
    """Glues scheduler + rescheduler + autoscaler over one cluster."""

    def __init__(self, cluster: Cluster, scheduler: Scheduler,
                 rescheduler: Rescheduler, autoscaler: Autoscaler,
                 straggler_threshold: float = 0.0,
                 on_evict: Optional[Callable[[Pod, float], None]] = None):
        self.cluster = cluster
        self.scheduler = scheduler
        self.rescheduler = rescheduler
        self.autoscaler = autoscaler
        self.pods: List[Pod] = []          # every pod ever submitted
        self.total_evictions = 0
        self.total_scale_outs = 0
        self.total_scale_ins = 0
        # Fleet extension: evict checkpointable batch pods running on nodes
        # slower than `straggler_threshold` × nominal speed (0 disables).
        self.straggler_threshold = straggler_threshold
        self.on_evict = on_evict

    # -- queue ------------------------------------------------------------------
    def submit(self, pod: Pod) -> None:
        self.pods.append(pod)

    def pending_pods(self) -> List[Pod]:
        return sorted((p for p in self.pods if p.phase == PodPhase.PENDING),
                      key=lambda p: (p.pending_since, p.uid))

    def running_pods(self) -> List[Pod]:
        return [p for p in self.pods if p.phase == PodPhase.BOUND]

    def batch_all_done(self) -> bool:
        return all(p.phase == PodPhase.SUCCEEDED
                   for p in self.pods if p.is_batch)

    # -- Algorithm 1 --------------------------------------------------------------
    def cycle(self, now: float) -> CycleStats:
        stats = CycleStats()
        if self.straggler_threshold > 0:
            self._mitigate_stragglers(now)
        snapshot = self.pending_pods()
        for pod in snapshot:
            if pod.phase != PodPhase.PENDING:
                continue   # a binding rescheduler may have placed it already
            if self.scheduler.schedule(self.cluster, pod, now):
                stats.placed += 1
                continue
            stats.unschedulable += 1
            stats.all_placed = False
            outcome = self.rescheduler.reschedule(self.cluster, pod, now)
            if outcome == RescheduleOutcome.WAIT:
                continue   # age gate: suppress autoscaling for this pod too
            if outcome == RescheduleOutcome.RESCHEDULED:
                stats.rescheduled += 1
                # Binding rescheduler may have bound the pod itself.
                if pod.phase != PodPhase.PENDING:
                    stats.placed += 1
                    stats.unschedulable -= 1
                continue
            stats.scale_out_requests += 1
            self.total_scale_outs += 1
            self.autoscaler.scale_out(self.cluster, pod, now)
        if stats.all_placed:
            removed = self.autoscaler.scale_in(self.cluster, now)
            stats.scale_ins = len(removed)
            self.total_scale_ins += len(removed)
        self.cluster.check_invariants()
        return stats

    # -- fleet extension: straggler mitigation -----------------------------------
    def _mitigate_stragglers(self, now: float) -> None:
        for pod in self.running_pods():
            if not (pod.is_batch and pod.spec.checkpointable):
                continue
            node = self.cluster.node_of(pod)
            if node is None or node.speed_factor >= self.straggler_threshold:
                continue
            if self.on_evict:
                self.on_evict(pod, now)
            self.cluster.unbind(pod, now)   # checkpoint + requeue elsewhere
            node.taint()                    # cordon the straggler
            self.total_evictions += 1
