"""The control loop (paper §4.2, Algorithm 1).

::

    while the scheduler exit condition is not satisfied
        get all pending tasks
        for each pending task t
            schedule t
            if t cannot be placed
                reschedule
                if rescheduling failed
                    scale out
        scale in

Semantics matched to the paper:

* a successful **non-binding** reschedule leaves the evictees *and* the
  triggering pod in the queue for the *next* cycle — so that cycle is not
  "fully successful" and scale-in is skipped;
* **scale-in runs only when every pending pod of the cycle was placed**;
* pods created by evictions during a cycle wait until the next cycle
  (we iterate over a snapshot of the queue).

Two cycle engines implement the "for each pending task t: schedule t" body:

* **wave placement** (array engine, default) — pod state lives in the SoA
  ``engine.PodStore`` (uid-indexed columns; ``Pod`` objects are shells
  materialized on demand at API boundaries) and the whole pending snapshot
  of store *rows* is handed to ``Scheduler.select_wave_store``, which
  places it against a ``WavePlacer``'s working arrays; the placed prefix
  commits once per wave — as pure column writes
  (``Cluster.bind_wave_store``) when no external ``on_bind`` observer is
  attached, through the object-path ``Cluster.bind_wave`` (shells
  materialize) otherwise.  When a pod blocks, the wave flushes, the paper's
  reschedule/scale-out path runs for that pod (materialized — policies are
  an object API), and the wave resumes after it — reusing the same placer
  when the mirror's version counter shows the blocked-pod handling didn't
  mutate the cluster.  Decisions are bit-identical to the per-pod loop
  (``tests/test_engine_parity.py``).
* **per-pod loop** (seed object engine, ``REPRO_SCHED_ENGINE=object``) —
  one ``Scheduler.schedule`` call per pending pod over real ``Pod``
  objects, kept verbatim as the parity reference.

Queueing is event-driven, not scan-driven: the orchestrator registers
bind/unbind/complete callbacks on the cluster and maintains the pending set
keyed on ``(pending_since, uid)`` with lazy invalidation, so a cycle's FIFO
snapshot costs O(k) for the k pending pods instead of filtering and
re-sorting a buffer of every pod ever submitted.  On the store path the
arrival stream never touches a heap at all: ``submit_wave`` bulk-ingests
each presorted ARRIVAL batch into the columns and *appends* its queue
entries (batch times are nondecreasing and uids monotone, so the whole
stream is sorted by construction); only eviction re-pends and object-path
submissions go through a small heap, and ``pending_rows`` merges the three
sorted streams in one pass.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import engine as _engine
from repro.core.autoscaler import Autoscaler, VoidAutoscaler
from repro.core.cluster import Cluster
from repro.core.pods import Pod, PodPhase
from repro.core.rescheduler import (Rescheduler, RescheduleOutcome,
                                    VoidRescheduler)
from repro.core.scheduler import Scheduler


@dataclasses.dataclass
class CycleStats:
    placed: int = 0
    unschedulable: int = 0
    rescheduled: int = 0
    scale_out_requests: int = 0
    scale_ins: int = 0
    all_placed: bool = True


class _StorePodSeq:
    """``Orchestrator.pods`` on the store path: a sequence view over every
    ingested row, in submission (uid) order.

    ``len``/truthiness are O(1) column reads — the simulator's exit condition
    polls them every cycle — while indexing/iteration materialize ``Pod``
    shells on demand (an API boundary: external readers get full-fidelity
    objects, the hot path never touches this)."""

    __slots__ = ("_store",)

    def __init__(self, store):
        self._store = store

    def __len__(self) -> int:
        return self._store.n_rows

    def __bool__(self) -> bool:
        return self._store.n_rows > 0

    def __getitem__(self, i):
        store = self._store
        if isinstance(i, slice):
            return [store.pod_at(r) for r in range(store.n_rows)[i]]
        return store.pod_at(range(store.n_rows)[i])

    def __iter__(self):
        store = self._store
        for row in range(store.n_rows):
            yield store.pod_at(row)


class Orchestrator:
    """Glues scheduler + rescheduler + autoscaler over one cluster.

    Owns the pending queue (two-level (pending_since, uid) structure fed by
    cluster bind/unbind callbacks) and the running counters the simulator's
    exit condition reads.  ``cycle`` is paper Alg. 1; on the array engine it
    places each cycle's snapshot in waves (see ``_cycle_wave``), on the
    object engine it runs the seed per-pod loop — both bit-identical."""

    def __init__(self, cluster: Cluster, scheduler: Scheduler,
                 rescheduler: Rescheduler, autoscaler: Autoscaler,
                 straggler_threshold: float = 0.0,
                 on_evict: Optional[Callable[[Pod, float], None]] = None):
        self.cluster = cluster
        self.scheduler = scheduler
        self.rescheduler = rescheduler
        self.autoscaler = autoscaler
        # Pod state: on the array engine the SoA PodStore is the source of
        # truth and `pods` is a lazy sequence view (shells on demand); on the
        # seed object engine `pods` is the plain list of every Pod submitted.
        if cluster.arrays is not None:
            self.store = _engine.PodStore(cluster.arrays)
            cluster.pod_store = self.store
            self.pods = _StorePodSeq(self.store)
        else:
            self.store = None
            self.pods: List[Pod] = []      # every pod ever submitted
        self.total_evictions = 0
        self.total_scale_outs = 0
        self.total_scale_ins = 0
        # Flight recorder (repro.obs.ObsRecorder), attached by
        # build_simulation when ExperimentSpec.obs is set; None = obs
        # compiled out (one attribute test per cycle phase).
        self.obs = None
        # Fleet extension: evict checkpointable batch pods running on nodes
        # slower than `straggler_threshold` × nominal speed (0 disables).
        self.straggler_threshold = straggler_threshold
        self.on_evict = on_evict
        # Event-driven queue + counters (maintained via cluster callbacks).
        # Two-level pending queue keyed on (pending_since, uid): a min-heap
        # of entries pushed since the last snapshot, merged into the carried
        # sorted pending prefix by pending_pods().  Entries go stale when
        # their pod binds (and possibly re-pends with a new pending_since) —
        # snapshots drop them lazily.  push_seq only breaks ties between
        # duplicate (pending_since, uid) entries so the heap never compares
        # Pod objects.
        self._pending_heap: List[Tuple[float, int, int, Pod]] = []
        self._pending_sorted: List[Tuple[float, int, int, Pod]] = []
        self._push_seq = itertools.count()
        # Store-path pending queue: three sorted (pending_since, uid, row)
        # streams.  Arrival ingests *append* (batches are presorted and uids
        # monotone, so the whole arrival stream is sorted by construction —
        # zero heap pushes); evictions/adoptions go through the small heap;
        # snapshots carry their sorted prefix forward.  Same keys, same lazy
        # invalidation, same FIFO order as the seed queue.
        self._arrival_entries: List[Tuple[float, int, int]] = []
        self._row_heap: List[Tuple[float, int, int]] = []
        self._row_sorted: List[Tuple[float, int, int]] = []
        # uid -> BOUND batch pod; values are None on the store fast path
        # until something needs the shell (e.g. straggler mitigation).
        self._bound_batch: Dict[int, Optional[Pod]] = {}
        # Batch pods bound since the last drain, in global bind order:
        # Pod objects from object-path binds, store rows (ints) from
        # fast-path wave commits.  One list so completion scheduling sees
        # the exact seed bucketing order even when the two mix in a cycle.
        self._newly_bound_batch: list = []
        self.n_pending = 0
        self.n_batch_total = 0
        self.n_batch_done = 0
        self.n_service_total = 0
        self.n_service_bound = 0
        self._cycle_count = 0
        cluster.on_bind = self._on_pod_bound
        cluster.on_unbind = self._on_pod_unbound
        cluster.on_complete = self._on_pod_completed

    # -- cluster callbacks -------------------------------------------------------
    def _on_pod_bound(self, pod: Pod) -> None:
        self.n_pending -= 1
        if pod.is_batch:
            self._bound_batch[pod.uid] = pod
            self._newly_bound_batch.append(pod)
        elif pod.is_service:
            self.n_service_bound += 1

    def _on_pod_unbound(self, pod: Pod) -> None:
        # evict() recreates the pod as a fresh PENDING incarnation
        self.n_pending += 1
        self._push_pending(pod)
        if pod.is_batch:
            self._bound_batch.pop(pod.uid, None)
        elif pod.is_service:
            self.n_service_bound -= 1

    def _on_row_unbound(self, row: int) -> None:
        """Store-path ``_on_pod_unbound`` for one column-evicted shell-less
        row (``Cluster.fail_node_store``): same bookkeeping, no shell.
        The caller already re-pended the row, so ``pending_since[row]`` is
        the eviction instant — the same key ``_push_pending`` would use."""
        store = self.store
        self.n_pending += 1
        heapq.heappush(self._row_heap,
                       (store.pending_since[row], store.uid[row], row))
        f = store.flags[row]
        if f & _engine.POD_F_BATCH:
            self._bound_batch.pop(store.uid[row], None)
        elif f & _engine.POD_F_SERVICE:
            self.n_service_bound -= 1

    def _on_row_completed(self, row: int) -> None:
        """Store-path ``_on_pod_completed``: same bookkeeping, no shell."""
        self._bound_batch.pop(self.store.uid[row], None)
        self.n_batch_done += 1

    def _on_pod_completed(self, pod: Pod) -> None:
        self._bound_batch.pop(pod.uid, None)
        self.n_batch_done += 1

    def bound_batch_uids(self) -> list:
        """Uids of currently-BOUND batch pods, in uid (submission) order —
        the crash-loop injector's candidate set.  O(1) membership state,
        no shell materialization."""
        return sorted(self._bound_batch)

    def bound_batch_pod(self, uid: int) -> Pod:
        """The BOUND batch pod for ``uid``, materializing (and caching) its
        shell on the store path — same idiom as ``_mitigate_stragglers``."""
        pod = self._bound_batch[uid]
        if pod is None:
            pod = self.store.pod_at(self.store.index[uid])
            self._bound_batch[uid] = pod
        return pod

    def drain_newly_bound_batch(self) -> list:
        """Batch pods bound (or re-bound) since the last drain, in bind
        order; the simulator schedules one completion event per
        (pod, incarnation).  Entries are ``Pod`` objects (object-path binds)
        or ``PodStore`` rows (ints, shell-less fast-path binds)."""
        out = self._newly_bound_batch
        self._newly_bound_batch = []
        return out

    # -- queue ------------------------------------------------------------------
    def _push_pending(self, pod: Pod) -> None:
        if self.store is not None:
            row = self.store.index.get(pod.uid)
            if row is None:
                row = self.store.adopt(pod)
            heapq.heappush(self._row_heap, (pod.pending_since, pod.uid, row))
            return
        heapq.heappush(self._pending_heap,
                       (pod.pending_since, pod.uid, next(self._push_seq), pod))

    def submit(self, pod: Pod) -> None:
        """Enqueue a newly-created pod (object-path entry point: the seed
        ARRIVAL handler, live-cluster submissions, tests).  On the array
        engine the pod is adopted into the PodStore — it stays the mutable
        face, the columns mirror it."""
        if self.store is None:
            self.pods.append(pod)
        self._push_pending(pod)   # adopts into the store on the array engine
        self.n_pending += 1
        if pod.is_batch:
            self.n_batch_total += 1
        elif pod.is_service:
            self.n_service_total += 1

    def submit_wave(self, arrivals) -> None:
        """Enqueue one pod per arrival of a presorted ARRIVAL batch.

        Store path (array engine): the batch ingests straight into the SoA
        columns — no ``Pod`` construction, no heap pushes.  Queue entries
        append to the sorted arrival stream: batch times are nondecreasing,
        uids are allocated in batch order, and every entry pushed before
        this event carries ``pending_since <= now <= arrivals[0].time``, so
        appends preserve the stream's sort (property-tested against
        one-at-a-time heappush in ``tests/test_pod_store.py``).

        Object path: equivalent to ``submit(Pod(...))`` per entry with the
        per-pod call overhead hoisted out of the loop."""
        if self.store is not None:
            rows, uids = self.store.ingest(arrivals)
            entries = self._arrival_entries
            flags = self.store.flags
            n_batch = n_service = 0
            first = rows[0] if len(rows) else 0
            for off, a in enumerate(arrivals):
                row = first + off
                entries.append((a.time, uids[off], row))
                f = flags[row]
                if f & _engine.POD_F_BATCH:
                    n_batch += 1
                elif f & _engine.POD_F_SERVICE:
                    n_service += 1
            self.n_pending += len(arrivals)
            self.n_batch_total += n_batch
            self.n_service_total += n_service
            return
        pods = self.pods
        heap = self._pending_heap
        seq = self._push_seq
        n_batch = n_service = 0
        for a in arrivals:
            pod = Pod(spec=a.spec, submit_time=a.time)
            pods.append(pod)
            heapq.heappush(heap, (pod.pending_since, pod.uid, next(seq), pod))
            if pod.is_batch:
                n_batch += 1
            elif pod.is_service:
                n_service += 1
        self.n_pending += len(arrivals)
        self.n_batch_total += n_batch
        self.n_service_total += n_service

    def submit_trace(self, trace, lo: int, hi: int) -> None:
        """Trace-native :meth:`submit_wave`: enqueue rows ``[lo, hi)`` of a
        columnar trace (``repro.scenarios.trace.TraceStore``).

        Store path (array engine): the batch bulk-ingests straight from the
        trace columns into the PodStore columns
        (``PodStore.ingest_trace``) — zero per-arrival Python objects, no
        heap pushes; queue entries append to the sorted arrival stream
        under the same sortedness argument as :meth:`submit_wave`, and the
        batch/service counters update from one vector pass over the
        trace's ``kind`` column.  Object path: falls back to materializing
        the slice as ``Arrival`` objects (the seed engine is object-speed
        anyway)."""
        if self.store is None:
            self.submit_wave(trace.arrivals_slice(lo, hi))
            return
        rows, uids, times = self.store.ingest_trace(trace, lo, hi)
        self._arrival_entries.extend(zip(times, uids, rows))
        n_batch, n_service = trace.count_kinds(lo, hi)
        self.n_pending += hi - lo
        self.n_batch_total += n_batch
        self.n_service_total += n_service

    def pending_pods(self) -> List[Pod]:
        """Currently-pending pods, FIFO by (pending_since, uid).

        O(k + j·log j) snapshot for k pending pods and j pushes since the
        last snapshot: the previous snapshot is carried forward *already
        sorted*, the j new entries drain from the heap in key order, and the
        two sorted streams merge in one pass — nothing is re-sorted.  Lazy
        invalidation drops each stale entry exactly once during the merge:
        an entry is stale when its pod is no longer PENDING, when it was
        re-pended with a newer ``pending_since`` (bound then evicted — the
        eviction pushed a fresh entry), or when it is a same-key duplicate
        (bound and evicted twice at one timestamp).

        On the store path this is an API boundary: the row snapshot comes
        from :meth:`pending_rows` (idempotent — the carried prefix is
        preserved) and each row materializes its ``Pod`` shell."""
        if self.store is not None:
            store = self.store
            return [store.pod_at(r) for r in self.pending_rows()]
        heap = self._pending_heap
        if heap:
            # Draining the whole heap == sorting it (keys are unique), and
            # one C-level sort beats n heappops.
            fresh = sorted(heap)
            heap.clear()
            merged = (heapq.merge(self._pending_sorted, fresh)
                      if self._pending_sorted else fresh)
        else:
            merged = self._pending_sorted
        out: List[Pod] = []
        entries: List[Tuple[float, int, int, Pod]] = []
        seen = set()
        pending = PodPhase.PENDING
        for entry in merged:
            ps, uid, _, pod = entry
            if (pod.phase is pending and pod.pending_since == ps
                    and uid not in seen):
                seen.add(uid)
                out.append(pod)
                entries.append(entry)
        self._pending_sorted = entries
        return out

    def pending_rows(self) -> List[int]:
        """Store-path :meth:`pending_pods`: currently-pending store rows,
        FIFO by (pending_since, uid).

        Same three-way merge discipline, row-native: the carried sorted
        prefix, the bulk-appended arrival stream (already sorted — see
        :meth:`submit_wave`) and the sorted eviction heap merge in one pass,
        with stale entries (phase or pending_since moved on, or same-key
        duplicates) dropped lazily against the SoA columns instead of Pod
        attributes."""
        heap = self._row_heap
        arrivals = self._arrival_entries
        streams = []
        if self._row_sorted:
            streams.append(self._row_sorted)
        if arrivals:
            streams.append(arrivals)
            self._arrival_entries = []
        if heap:
            fresh = sorted(heap)
            heap.clear()
            streams.append(fresh)
        if len(streams) == 1:
            merged = streams[0]
        elif streams:
            merged = heapq.merge(*streams)
        else:
            merged = ()
        store = self.store
        phase = store.phase
        ps_col = store.pending_since
        pending = _engine.POD_PENDING
        out: List[int] = []
        entries: List[Tuple[float, int, int]] = []
        seen = set()
        for entry in merged:
            ps, uid, row = entry
            if (phase[row] == pending and ps_col[row] == ps
                    and uid not in seen):
                seen.add(uid)
                out.append(row)
                entries.append(entry)
        self._row_sorted = entries
        return out

    def running_pods(self) -> List[Pod]:
        if self.store is not None:
            store = self.store
            bound = _engine.POD_BOUND
            return [store.pod_at(r) for r in range(store.n_rows)
                    if store.phase[r] == bound]
        return [p for p in self.pods if p.phase == PodPhase.BOUND]

    def batch_all_done(self) -> bool:
        return self.n_batch_done == self.n_batch_total

    def services_all_bound(self) -> bool:
        return self.n_service_bound == self.n_service_total

    def has_running_batch(self) -> bool:
        return bool(self._bound_batch)

    # -- Algorithm 1 --------------------------------------------------------------
    def cycle(self, now: float) -> CycleStats:
        """One scheduling cycle (paper Alg. 1): place the pending snapshot,
        reschedule/scale-out per blocked pod, scale in after a fully
        successful cycle.  Dispatches to wave placement on the array engine
        and to the seed per-pod loop otherwise; both produce bit-identical
        bindings and stats."""
        stats = CycleStats()
        obs = self.obs
        prof = obs.prof if obs is not None else None
        if self.straggler_threshold > 0:
            self._mitigate_stragglers(now)
        # Predictive prelaunch hook (no-op for the paper's autoscalers):
        # runs before placement so capacity requested for a forecast burst
        # starts booting in the same cycle that observes the demand.
        if prof is None:
            self.autoscaler.on_cycle(self.cluster, now)
        else:
            t0 = prof.start()
            self.autoscaler.on_cycle(self.cluster, now)
            prof.stop("autoscaler_step", t0, now)
        if self.store is not None:
            self._cycle_wave(self.pending_rows(), now, stats)
        else:
            self._cycle_per_pod(self.pending_pods(), now, stats)
        if stats.all_placed:
            if prof is None:
                removed = self.autoscaler.scale_in(self.cluster, now)
            else:
                t0 = prof.start()
                removed = self.autoscaler.scale_in(self.cluster, now)
                prof.stop("scale_in", t0, now)
            stats.scale_ins = len(removed)
            self.total_scale_ins += len(removed)
        # Fast (vectorized) invariant every cycle; full object-walk +
        # mirror cross-check periodically so drift can't hide for a run.
        self._cycle_count += 1
        self.cluster.check_invariants(deep=self._cycle_count % 64 == 0)
        return stats

    def _cycle_wave(self, snapshot: List[int], now: float,
                    stats: CycleStats) -> None:
        """Wave placement (array engine): place the snapshot of store rows
        in batches.

        Each ``select_wave_store`` call places a maximal prefix of the
        remaining snapshot against the placer's working arrays; the prefix
        commits once per wave, then the blocked pod (if any) goes through
        the paper's reschedule/scale-out path — materialized to a ``Pod``
        shell, since reschedulers/autoscalers are an object API — and the
        wave resumes after it.  The placer — including its per-request-size
        filter caches — is reused across waves as long as the mirror's
        version counter proves nothing mutated cluster state behind its
        back.

        Commit flavour: when ``cluster.on_bind`` is still this
        orchestrator's own handler, the wave commits shell-less
        (``Cluster.bind_wave_store`` — pure column/accounting writes, with
        the orchestrator bookkeeping done row-wise here).  Any *external*
        ``on_bind`` observer (parity spies, user callbacks) is an API
        boundary: shells materialize and the wave commits through the
        object-path ``bind_wave`` so the observer sees real pods, in order.
        """
        arr = self.cluster.arrays
        store = self.store
        fast = self.cluster.on_bind == self._on_pod_bound
        # Void rescheduler + void autoscaler (exact types: subclasses may
        # override behaviour) ignore the pod entirely — Alg. 1's fallback
        # chain degenerates to counter updates, so a blocked pod needs no
        # shell.  This is the static-cluster regime (fig-4 baseline,
        # throughput benchmarks), where a saturated cluster re-blocks tens
        # of thousands of pending pods every cycle.
        void_fallback = (type(self.rescheduler) is VoidRescheduler
                         and type(self.autoscaler) is VoidAutoscaler)
        obs = self.obs
        prof = obs.prof if obs is not None else None
        placer = None
        start = 0
        while start < len(snapshot):
            if placer is None or not placer.in_sync():
                placer = _engine.WavePlacer(arr)
            if prof is None:
                bindings, blocked = self.scheduler.select_wave_store(
                    placer, store, snapshot, start)
            else:
                t0 = prof.start()
                bindings, blocked = self.scheduler.select_wave_store(
                    placer, store, snapshot, start)
                prof.stop("wave_select", t0, now)
            if bindings:
                t0 = prof.start() if prof is not None else 0.0
                if fast:
                    self.cluster.bind_wave_store(bindings, now)
                    self._note_bound_rows(bindings)
                else:
                    by_slot = self.cluster.node_by_slot
                    self.cluster.bind_wave(
                        [(store.pod_at(row), by_slot(slot))
                         for row, slot in bindings], now)
                if prof is not None:
                    prof.stop("bind_commit", t0, now)
                placer.version = arr.version   # re-arm: our own commit
                stats.placed += len(bindings)
            if blocked is None:
                return
            if void_fallback:
                # Inlined _handle_unschedulable for the void/void chain:
                # reschedule FAILED -> scale-out request -> ignored.
                stats.unschedulable += 1
                stats.all_placed = False
                stats.scale_out_requests += 1
                self.total_scale_outs += 1
                if obs is not None:
                    # Same event _handle_unschedulable records, shell-less.
                    obs.resched(now, store.uid[snapshot[blocked]], 2)
            else:
                self._handle_unschedulable(store.pod_at(snapshot[blocked]),
                                           now, stats)
            start = blocked + 1

    def _note_bound_rows(self, bindings) -> None:
        """Row-wise ``_on_pod_bound`` for one fast-committed wave."""
        store = self.store
        flags = store.flags
        uid_col = store.uid
        shells = store.shells
        bound_batch = self._bound_batch
        newly = self._newly_bound_batch
        n_service = 0
        F_BATCH = _engine.POD_F_BATCH
        F_SERVICE = _engine.POD_F_SERVICE
        for row, _slot in bindings:
            f = flags[row]
            if f & F_BATCH:
                bound_batch[uid_col[row]] = shells.get(row)
                newly.append(row)
            elif f & F_SERVICE:
                n_service += 1
        self.n_pending -= len(bindings)
        self.n_service_bound += n_service

    def _cycle_per_pod(self, snapshot: List[Pod], now: float,
                       stats: CycleStats) -> None:
        """Seed per-pod loop (object engine): the parity reference."""
        obs = self.obs
        prof = obs.prof if obs is not None else None
        for pod in snapshot:
            if pod.phase != PodPhase.PENDING:
                continue   # a binding rescheduler may have placed it already
            if prof is None:
                placed = self.scheduler.schedule(self.cluster, pod, now)
            else:
                t0 = prof.start()
                placed = self.scheduler.schedule(self.cluster, pod, now)
                prof.stop("wave_select", t0, now)
            if placed:
                stats.placed += 1
                continue
            self._handle_unschedulable(pod, now, stats)

    def _handle_unschedulable(self, pod: Pod, now: float,
                              stats: CycleStats) -> None:
        """Alg. 1 fallback chain for one unplaceable pod: reschedule, and on
        failure request scale-out (shared by both cycle engines)."""
        stats.unschedulable += 1
        stats.all_placed = False
        obs = self.obs
        prof = obs.prof if obs is not None else None
        if prof is None:
            outcome = self.rescheduler.reschedule(self.cluster, pod, now)
        else:
            t0 = prof.start()
            outcome = self.rescheduler.reschedule(self.cluster, pod, now)
            prof.stop("reschedule", t0, now)
        if outcome == RescheduleOutcome.WAIT:
            if obs is not None:
                obs.resched(now, pod.uid, 0)   # RS_WAIT
            return   # age gate: suppress autoscaling for this pod too
        if outcome == RescheduleOutcome.RESCHEDULED:
            stats.rescheduled += 1
            # Binding rescheduler may have bound the pod itself.
            # (The RESCHEDULED event — with victim node + relocation count
            # attribution — is recorded by the rescheduler, which knows
            # the plan it committed.)
            if pod.phase != PodPhase.PENDING:
                stats.placed += 1
                stats.unschedulable -= 1
            return
        if obs is not None:
            obs.resched(now, pod.uid, 2)       # RS_FAILED
        stats.scale_out_requests += 1
        self.total_scale_outs += 1
        self.autoscaler.scale_out(self.cluster, pod, now)

    # -- fleet extension: straggler mitigation -----------------------------------
    def _mitigate_stragglers(self, now: float) -> None:
        # uid order == submission order (uids are monotone), matching the
        # seed's scan over self.pods.
        store = self.store
        for uid in sorted(self._bound_batch):
            pod = self._bound_batch[uid]
            if pod is None:
                # Shell-less fast-path resident: gate on the spec flag first
                # (same decision the object path takes) and materialize only
                # candidates that pass it.
                row = store.index[uid]
                if not store.flags[row] & _engine.POD_F_CHECKPOINTABLE:
                    continue
                pod = store.pod_at(row)
                self._bound_batch[uid] = pod
            if not pod.spec.checkpointable:
                continue
            node = self.cluster.node_of(pod)
            if node is None or node.speed_factor >= self.straggler_threshold:
                continue
            if self.on_evict:
                self.on_evict(pod, now)
            obs = self.obs
            if obs is not None:
                obs.reason = 4   # R_STRAGGLER eviction attribution
            try:
                self.cluster.unbind(pod, now)   # checkpoint + requeue
            finally:
                if obs is not None:
                    obs.reason = 0
            node.taint()                    # cordon the straggler
            self.total_evictions += 1
