"""Resource-consumption estimation (paper §4.2 + §8, implemented here).

The paper leaves the Resource Consumption Estimator unintegrated ("currently,
this functionality has not been integrated") and lists it as future work.  We
implement it as a beyond-paper feature, **off by default** so the faithful
reproduction schedules on raw requests:

* `UsageModel` — ground truth for the simulation: each job type actually uses
  ``usage_fraction`` of its request (the paper observes requests are
  "usually misestimated and overestimated by users").
* `EmaEstimator` — online exponential-moving-average estimate of per-type
  usage, learned from (simulated) metrics-server samples.
* `OversubscribingScheduler` — wraps any scheduler; feasibility uses
  ``effective = max(headroom × estimate, floor × request)`` instead of the raw
  request, packing more pods per node.  The CPU axis is compressible so it is
  oversubscribed more aggressively than memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.cluster import Cluster, Node
from repro.core.pods import Pod
from repro.core.resources import Resources
from repro.core.scheduler import Scheduler


@dataclasses.dataclass
class UsageModel:
    """Simulated true usage as a fraction of the request, per job type."""

    fractions: Dict[str, float]
    default_fraction: float = 0.6

    def usage(self, pod: Pod) -> Resources:
        f = self.fractions.get(pod.spec.type_name, self.default_fraction)
        return pod.requests * f


class EmaEstimator:
    """Per-job-type EMA of observed usage/request ratios."""

    def __init__(self, alpha: float = 0.3, prior: float = 1.0):
        self.alpha = alpha
        self.prior = prior
        self._ratio: Dict[str, float] = {}

    #: Single zero-division guard for usage/request ratios on both axes.
    EPS = 1e-9

    def observe(self, pod: Pod, used: Resources) -> None:
        req = pod.requests
        ratio = max(used.cpu_m / max(req.cpu_m, self.EPS),
                    used.mem_mb / max(req.mem_mb, self.EPS))
        prev = self._ratio.get(pod.spec.type_name, self.prior)
        self._ratio[pod.spec.type_name] = (
            self.alpha * ratio + (1 - self.alpha) * prev)

    def ratio(self, type_name: str) -> float:
        return self._ratio.get(type_name, self.prior)

    def effective_request(self, pod: Pod, *, mem_floor: float = 0.7,
                          cpu_floor: float = 0.3,
                          headroom: float = 1.2) -> Resources:
        r = min(1.0, self.ratio(pod.spec.type_name) * headroom)
        # Round half-up with a floor of 1 millicore: plain int() truncates
        # toward zero, so a 1-millicore request at any ratio < 1 would
        # estimate to 0 cpu_m and look free to every feasibility check.
        return Resources(
            cpu_m=max(1, int(pod.requests.cpu_m * max(r, cpu_floor) + 0.5)),
            mem_mb=pod.requests.mem_mb * max(r, mem_floor),
        )


class OversubscribingScheduler(Scheduler):
    """Scheduler decorator: feasibility on estimated (not requested) usage.

    Binding still records the *full* request (Kubernetes guaranteed QoS), but
    node feasibility is checked against estimated usage sums, allowing
    controlled oversubscription.  ``max_oversub`` caps total estimated usage
    relative to allocatable capacity.
    """

    name = "oversubscribing"

    def __init__(self, inner: Scheduler, estimator: EmaEstimator,
                 max_oversub: float = 1.0):
        self.inner = inner
        self.estimator = estimator
        self.max_oversub = max_oversub

    def _estimated_used(self, node: Node) -> Resources:
        total = Resources.zero()
        for p in node.pods.values():
            total = total + self.estimator.effective_request(p)
        return total

    def suitable_nodes(self, cluster: Cluster, pod: Pod) -> List[Node]:
        eff = self.estimator.effective_request(pod)
        cap = self.max_oversub
        out = []
        for n in cluster.ready_nodes():
            free = (n.allocatable * cap) - self._estimated_used(n)
            if eff.fits_in(free):
                out.append(n)
        if out:
            return out
        return [n for n in cluster.tainted_nodes()
                if eff.fits_in((n.allocatable * cap) - self._estimated_used(n))]

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        return self.inner.select(nodes, pod)

    def schedule(self, cluster: Cluster, pod: Pod, now: float) -> bool:
        nodes = self.suitable_nodes(cluster, pod)
        node = self.select(nodes, pod) if nodes else None
        if node is None:
            return False
        # Bind without the hard request-fits assertion: oversubscription is
        # the point.  Guaranteed QoS accounting still tracks full requests.
        if not pod.requests.fits_in(node.free):
            node.oversub = True
        cluster.bind(pod, node, now, enforce=False)
        return True
