"""Autoscalers (paper §6.3, Algorithms 5, 6, 7).

Scale-out policies:

* **Void** — ignore scale requests (static cluster).
* **Simple / non-binding (NBAS, Alg. 5)** — launch at most one instance per
  ``provisioning_interval`` (set to the provisioning delay + contingency).
* **Binding (BAS, Alg. 7)** — track pod↔provisioning-node associations: a pod
  already assigned to a booting node never triggers another launch, and a
  booting node with spare planned room absorbs further unschedulable pods.

Scale-in (Alg. 6) is shared by both active autoscalers and runs only after a
fully successful scheduling cycle:

1. terminate empty dynamically-created nodes;
2. drain nodes whose pods are all moveable *and* all placeable elsewhere;
3. for mixed moveable+batch nodes whose moveables are placeable elsewhere,
   evict the moveables and **taint** the node so it drains as batch completes.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine as _engine
from repro.core.cluster import Cluster, Node, NodeState
from repro.core.pods import Pod
from repro.core.rescheduler import _ShadowBase, _ShadowCapacity
from repro.core.resources import Resources
from repro.obs.recorder import (R_CONSOLIDATE, R_UNSPEC, SO_ABSORBED,
                                SO_ASSOCIATED, SO_LAUNCH, SO_LIMITED,
                                SO_PRELAUNCH)


class NodeProvider(abc.ABC):
    """What the autoscaler needs from the cloud adapter (repro.cloud)."""

    @abc.abstractmethod
    def launch_node(self, now: float) -> Node:
        """Request a new worker; returns it in PROVISIONING state."""

    @abc.abstractmethod
    def terminate_node(self, node: Node, now: float) -> None:
        """Deprovision (stops billing)."""


class Autoscaler(abc.ABC):
    name = "autoscaler"

    #: Set (by instances) that want `observe_arrivals` called with every
    #: arrival batch.  A plain class attribute so the simulation's hot
    #: path can gate on one attribute read; False keeps existing
    #: autoscalers' event handling byte-identical.
    observes_arrivals = False

    def __init__(self, provider: NodeProvider,
                 scale_in_util_ceiling: Optional[float] = None):
        self.provider = provider
        # Observability recorder (repro.obs.ObsRecorder.attach sets it);
        # None = compiled out — decision sites pay one is-None test.
        self.obs = None
        # Policy-search knob (the "lower threshold" of threshold-based
        # cluster autoscalers): run Alg. 6 consolidation only while mean
        # RAM utilization is at or below this ceiling — a busy cluster
        # skips the drain/taint pass entirely.  None (default) preserves
        # the paper's unconditional scale-in.
        self.scale_in_util_ceiling = scale_in_util_ceiling
        # Version-invalidated shadow snapshot shared by the Alg. 6
        # placeability checks (same cache the reschedulers use): step 2/3
        # candidates that don't consolidate reuse one base instead of
        # re-snapshotting the free vectors per candidate.
        self._shadow_base = _ShadowBase()

    @abc.abstractmethod
    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        """Called per unschedulable pod after rescheduling failed."""

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        """Alg. 6; returns ids of nodes terminated or tainted (for logs)."""
        return []

    def notify_node_ready(self, node: Node) -> None:
        """Provider callback once a node joins the cluster."""

    def notify_node_lost(self, node: Node) -> None:
        """``node`` died (failure/reclaim), possibly while still
        PROVISIONING: drop any provisioning association so its pods can
        trigger replacement capacity instead of staying stranded.
        Default: stateless autoscalers have nothing to clean up."""

    def notify_node_removed(self, node: Node) -> None:
        """Scale-in (Alg. 6) removed ``node`` from the cluster.  A node
        that leaves this way never gets its pending NODE_FAIL delivered
        (the kill early-returns once the node is gone), so per-node
        bookkeeping keyed on the node id must be released here.
        Default: stateless autoscalers track nothing per node."""

    def notify_preemption_notice(self, cluster: Cluster, node: Node,
                                 now: float) -> None:
        """``node`` received a spot reclaim notice and will be killed when
        the notice window closes (``Simulation._on_node_notice``).
        Default: do nothing — react after the kill like any failure."""

    def observe_arrivals(self, times, cpu_m=None, mem_mb=None) -> None:
        """Arrival observation feed (only delivered when
        ``observes_arrivals`` is True): the batch's arrival instants plus,
        when available, per-arrival requested cpu_m/mem_mb columns.
        Default: reactive autoscalers ignore demand history."""

    def on_cycle(self, cluster: Cluster, now: float) -> None:
        """Per-scheduling-cycle hook, called before placement.  Default:
        no-op — the paper's autoscalers act only on unschedulable pods."""

    # -- shared Alg. 6 body ----------------------------------------------------
    @staticmethod
    def _step1_candidates(cluster: Cluster) -> List[Node]:
        """Empty dynamically-created nodes (READY or TAINTED), in cluster
        insertion order (slots are append-only, so ascending slot order is
        insertion order — termination order is behaviour)."""
        arr = cluster.arrays
        if arr is not None:
            state = arr.live("state")
            mask = (arr.live("active") & arr.live("autoscaled")
                    & (arr.live("pod_count") == 0)
                    & ((state == _engine.STATE_READY)
                       | (state == _engine.STATE_TAINTED)))
            return [cluster.node_by_slot(int(s)) for s in np.nonzero(mask)[0]]
        return [node for node in list(cluster.nodes.values())
                if (node.autoscaled and not node.pods
                    and node.state in (NodeState.READY, NodeState.TAINTED))]

    @staticmethod
    def _step23_candidates(cluster: Cluster) -> List[Node]:
        """Non-empty autoscaled READY nodes, in cluster insertion order."""
        arr = cluster.arrays
        if arr is not None:
            mask = (arr.live("active") & arr.live("autoscaled")
                    & (arr.live("pod_count") > 0)
                    & (arr.live("state") == _engine.STATE_READY))
            return [cluster.node_by_slot(int(s)) for s in np.nonzero(mask)[0]]
        return [node for node in list(cluster.nodes.values())
                if node.autoscaled and node.state == NodeState.READY
                and node.pods]

    def _utilization(self, cluster: Cluster) -> float:
        """Mean RAM req/cap ratio over READY|TAINTED nodes — the Table-5
        quantity the threshold knobs gate on (0.0 on an empty cluster).
        ``utilization_totals`` is incremental on the array engine and its
        fsum reduction is flush-order independent, so reading it here does
        not disturb the 20 s sampler."""
        n_nodes, ram_sum, _cpu, _ppn = cluster.utilization_totals()
        return ram_sum / n_nodes if n_nodes else 0.0

    def _scale_in_impl(self, cluster: Cluster, now: float) -> List[str]:
        if (self.scale_in_util_ceiling is not None
                and self._utilization(cluster) > self.scale_in_util_ceiling):
            return []
        touched: List[str] = []
        obs = self.obs

        # 1. Shut down empty dynamically-created nodes (READY or TAINTED).
        for node in self._step1_candidates(cluster):
            if obs is not None:   # record before removal mutates utilization
                obs.scale_in(now, node.node_id, 1)
            self.provider.terminate_node(node, now)
            cluster.remove_node(node, now)
            self.notify_node_removed(node)
            touched.append(node.node_id)

        # 2./3. Consolidate moveable pods off candidate nodes.
        if obs is not None:
            obs.reason = R_CONSOLIDATE   # eviction attribution context
        try:
            for node in self._step23_candidates(cluster):
                if node.has_only_moveable():
                    if self._all_placeable(cluster, node,
                                           node.moveable_pods()):
                        pods = list(node.pods.values())
                        if obs is not None:
                            obs.scale_in(now, node.node_id, 2, len(pods))
                        for pod in pods:
                            cluster.unbind(pod, now)   # recreated next cycle
                        self.provider.terminate_node(node, now)
                        cluster.remove_node(node, now)
                        self.notify_node_removed(node)
                        touched.append(node.node_id)
                elif node.has_moveable_and_batch():
                    movers = node.moveable_pods()
                    if movers and self._all_placeable(cluster, node, movers):
                        if obs is not None:
                            obs.scale_in(now, node.node_id, 3, len(movers))
                        for pod in movers:
                            cluster.unbind(pod, now)
                        node.taint()                # drains as batch completes
                        touched.append(node.node_id)
        finally:
            if obs is not None:
                obs.reason = R_UNSPEC
        return touched

    def _all_placeable(self, cluster: Cluster, exclude: Node,
                       pods: List[Pod]) -> bool:
        """True iff *all* of `pods` fit on other nodes (shadow accounting)."""
        base = self._shadow_base if cluster.arrays is not None else None
        shadow = _ShadowCapacity(cluster, exclude=exclude, base=base)
        try:
            ordered = sorted(pods, key=lambda p: (p.requests.mem_mb, p.uid),
                             reverse=True)
            return all(shadow.place_best_fit(p.requests) is not None
                       for p in ordered)
        finally:
            shadow.rollback()


class VoidAutoscaler(Autoscaler):
    """Paper: ignores scale-out and scale-in — a fixed-size cluster."""

    name = "void"

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        return

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        return []


class SimpleAutoscaler(Autoscaler):
    """Paper Alg. 5 (+6) — the *non-binding* autoscaler (NBAS)."""

    name = "non-binding"

    def __init__(self, provider: NodeProvider,
                 provisioning_interval_s: float = 60.0,
                 scale_out_bypass_util: Optional[float] = None,
                 scale_in_util_ceiling: Optional[float] = None):
        super().__init__(provider, scale_in_util_ceiling=scale_in_util_ceiling)
        self.provisioning_interval_s = provisioning_interval_s
        # Policy-search knob (the "upper threshold"): when mean RAM
        # utilization reaches this level the Alg. 5 rate limit is bypassed
        # — a saturated cluster may launch every cycle instead of once per
        # provisioning interval.  None (default) keeps the paper's
        # unconditional rate limit.
        self.scale_out_bypass_util = scale_out_bypass_util
        self._last_launch: Optional[float] = None

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        rate_ok = (self._last_launch is None
                   or now - self._last_launch >= self.provisioning_interval_s)
        if not rate_ok and self.scale_out_bypass_util is not None:
            rate_ok = self._utilization(cluster) >= self.scale_out_bypass_util
        obs = self.obs
        if rate_ok:
            node = self.provider.launch_node(now)
            cluster.add_node(node)
            if obs is not None:
                since = (float("nan") if self._last_launch is None
                         else now - self._last_launch)
                obs.scale_out(now, pod.uid, node.node_id, SO_LAUNCH,
                              detail=since)
            self._last_launch = now
        elif obs is not None:
            # Rate limited: _last_launch is set (else rate_ok held).
            obs.scale_out(now, pod.uid, None, SO_LIMITED,
                          detail=now - self._last_launch)

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        return self._scale_in_impl(cluster, now)


@dataclasses.dataclass
class _ProvisioningTracker:
    node: Node
    assigned: Dict[int, Resources]    # pod uid -> its planned requests

    @property
    def planned_free(self) -> Resources:
        free = self.node.allocatable
        for req in self.assigned.values():
            free = free - req
        return free


class BindingAutoscaler(Autoscaler):
    """Paper Alg. 7 (+6) — the *binding* autoscaler (BAS).

    Keeps the pod↔booting-node association so that one unschedulable pod
    triggers at most one launch, and booting capacity is packed before any
    further launch (the mechanism behind the paper's lowest-cost results).
    """

    name = "binding"

    def __init__(self, provider: NodeProvider,
                 scale_in_util_ceiling: Optional[float] = None):
        super().__init__(provider, scale_in_util_ceiling=scale_in_util_ceiling)
        self._tracked: Dict[str, _ProvisioningTracker] = {}
        self._pod_to_node: Dict[int, str] = {}
        self._noticed: set = set()   # node ids already given a replacement

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        obs = self.obs
        if pod.uid in self._pod_to_node:
            if obs is not None:
                obs.scale_out(now, pod.uid, self._pod_to_node[pod.uid],
                              SO_ASSOCIATED)
            return  # already associated with a booting node — ignore
        # Is there still room in one of the nodes being provisioned?
        for tracker in sorted(self._tracked.values(),
                              key=lambda t: t.node.node_id):
            if pod.requests.fits_in(tracker.planned_free):
                tracker.assigned[pod.uid] = pod.requests
                self._pod_to_node[pod.uid] = tracker.node.node_id
                if obs is not None:
                    obs.scale_out(now, pod.uid, tracker.node.node_id,
                                  SO_ABSORBED,
                                  detail=float(len(tracker.assigned)))
                return
        # Launch a new node and assign the pod to it.
        node = self.provider.launch_node(now)
        cluster.add_node(node)
        self._tracked[node.node_id] = _ProvisioningTracker(
            node=node, assigned={pod.uid: pod.requests})
        self._pod_to_node[pod.uid] = node.node_id
        if obs is not None:
            obs.scale_out(now, pod.uid, node.node_id, SO_LAUNCH)

    def notify_node_ready(self, node: Node) -> None:
        tracker = self._tracked.pop(node.node_id, None)
        if tracker is None:
            return
        for uid in tracker.assigned:
            self._pod_to_node.pop(uid, None)
        # The scheduler (not the autoscaler) places pods on the new node.

    def notify_node_lost(self, node: Node) -> None:
        """Release the association state of a dead node.  Without this, a
        node failing while PROVISIONING leaks its tracker and every pod
        assigned to it stays permanently stranded (``scale_out``'s
        "already associated" early-return never launches a replacement)."""
        self._noticed.discard(node.node_id)
        tracker = self._tracked.pop(node.node_id, None)
        if tracker is None:
            return
        for uid in tracker.assigned:
            self._pod_to_node.pop(uid, None)

    def notify_node_removed(self, node: Node) -> None:
        """A noticed node that drains during its notice window is reaped
        by Alg. 6 step 1 before the kill fires; without this hook its id
        would sit in ``_noticed`` forever."""
        self._noticed.discard(node.node_id)

    def notify_preemption_notice(self, cluster: Cluster, node: Node,
                                 now: float) -> None:
        """Launch replacement capacity *during* the notice window instead
        of after the kill: the replacement boots while the doomed node
        drains, so evictees re-bind one provisioning delay sooner.  The
        evictees associate with the booting replacement through the
        normal ``scale_out`` path once the kill re-pends them; an empty
        replacement (the workload drained during the window) is reaped by
        scale-in."""
        if node.node_id in self._noticed:
            return   # one replacement per reclaimed node
        self._noticed.add(node.node_id)
        if not node.pods:
            return   # nothing to re-home; later arrivals scale out normally
        replacement = self._launch_replacement(node, now)
        cluster.add_node(replacement)
        self._tracked[replacement.node_id] = _ProvisioningTracker(
            node=replacement, assigned={})

    def _launch_replacement(self, node: Node, now: float) -> Node:
        """Like-for-like replacement; the heterogeneous subclass launches
        the reclaimed node's own instance type."""
        return self.provider.launch_node(now)

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        return self._scale_in_impl(cluster, now)


class PredictiveAutoscaler(SimpleAutoscaler):
    """Forecast-ahead extension of Alg. 5 (beyond-paper, ROADMAP item 2).

    The reactive algorithms pay one full provisioning delay per burst:
    capacity is requested only after pods are already unschedulable.  This
    autoscaler additionally feeds observed arrivals into a rate forecaster
    (``repro.forecast`` contract: ``observe_bin`` / ``predict``) and, each
    scheduling cycle, converts the predicted rate over the next
    ``lead_time_s`` into node demand via the provider template's capacity
    — launching *ahead* of the burst so nodes are READY when it lands.

    Fallback contract: with ``forecaster=None``, or whenever the
    forecaster's confidence is below ``conf_min``, behavior is exactly
    inherited Alg. 5 + Alg. 6 — the predictive path adds no launches, no
    RNG, and no event-order perturbation, so a disabled instance is
    bit-identical to `SimpleAutoscaler`.

    Freshly prelaunched nodes are protected from Alg. 6 step 1 for one
    provisioning-delay + lead window; without that grace period, scale-in
    would reap a speculative node the cycle after it boots empty and the
    deficit would relaunch it — a churn loop that burns cost without ever
    holding capacity through the predicted burst.

    Demand model: while the cluster is keeping up, speculation covers
    only the *unexpected* part of demand — the forecast rate in excess of
    a slow EWMA of the same bin stream (``trend_min`` scales the
    reference).  The reactive base algorithm already matches capacity to
    a steady rate, so holding ``rate * lead`` of free capacity through a
    plateau is pure idle cost, and launching into a falling rate (the
    forecaster's lag after a cliff) is worse.  But while pods are
    actually unschedulable (``scale_out`` fired within the last bin) the
    cluster is in sustained overload — Alg. 5's one-node-per-interval
    ramp is the bottleneck — and the full forecast rate drives the
    deficit so the fleet keeps building until the backlog clears.

    The overload ramp *escalates*: one node per cycle at onset, rising to
    ``max_prelaunch_per_cycle`` once the overload has persisted past
    ``escalate_s``.  A brief overload (a staircase climb the reactive
    path nearly keeps up with) gets a gentle nudge that does not
    overshoot the next cliff; a flash crowd that stays unschedulable for
    many minutes is provably beyond Alg. 5's one-node-per-interval ramp
    and gets the full-speed build-out.
    """

    name = "predictive"

    def __init__(self, provider: NodeProvider,
                 provisioning_interval_s: float = 60.0,
                 scale_out_bypass_util: Optional[float] = None,
                 scale_in_util_ceiling: Optional[float] = None,
                 forecaster=None,
                 bin_s: float = 30.0,
                 lead_time_s: float = 90.0,
                 headroom: float = 1.15,
                 conf_min: float = 0.35,
                 trend_min: float = 1.0,
                 slow_alpha: float = 0.08,
                 escalate_s: float = 900.0,
                 max_prelaunch_per_cycle: int = 2):
        super().__init__(provider,
                         provisioning_interval_s=provisioning_interval_s,
                         scale_out_bypass_util=scale_out_bypass_util,
                         scale_in_util_ceiling=scale_in_util_ceiling)
        self.forecaster = forecaster
        self.observes_arrivals = forecaster is not None
        self.bin_s = bin_s
        self.lead_time_s = lead_time_s
        self.headroom = headroom
        self.conf_min = conf_min
        self.trend_min = trend_min
        self.slow_alpha = slow_alpha
        self.escalate_s = escalate_s
        self.max_prelaunch_per_cycle = max_prelaunch_per_cycle
        template = getattr(provider, "template", None)
        boot_s = (template.provisioning_delay_s if template is not None
                  else provisioning_interval_s)
        self._protect_s = boot_s + lead_time_s
        self._cur_bin = 0          # index of the still-open arrival bin
        self._cur_count = 0        # arrivals observed in the open bin
        self._slow_rate: Optional[float] = None   # trend-gate reference
        self._last_bin_rate = 0.0  # most recent *closed* bin's rate
        self._arr_n = 0            # running per-arrival request means
        self._arr_cpu = 0.0
        self._arr_mem = 0.0
        self._prelaunched_at: Dict[str, float] = {}
        self._last_unsched = -np.inf   # last time Alg. 5 saw an unschedulable pod
        self._overload_since = -np.inf   # start of the current overload episode
        self._scale_in_now = 0.0
        self.prelaunched = 0       # diagnostic: speculative launches

    # -- arrival feed ---------------------------------------------------------
    def observe_arrivals(self, times, cpu_m=None, mem_mb=None) -> None:
        times = np.asarray(times, np.float64)
        if times.size == 0:
            return
        self._arr_n += times.size
        if cpu_m is not None:
            self._arr_cpu += float(np.sum(cpu_m))
        if mem_mb is not None:
            self._arr_mem += float(np.sum(mem_mb))
        for b in np.floor_divide(times, self.bin_s).astype(np.int64):
            self._roll_to(int(b))
            self._cur_count += 1

    def _roll_to(self, b: int) -> None:
        """Close (emit) every bin strictly before ``b``, including empty
        ones — a quiet stretch is signal, not missing data."""
        while self._cur_bin < b:
            r = self._cur_count / self.bin_s
            self.forecaster.observe_bin(r)
            self._last_bin_rate = r
            if self._slow_rate is None:
                self._slow_rate = r
            else:
                self._slow_rate += self.slow_alpha * (r - self._slow_rate)
            self._cur_count = 0
            self._cur_bin += 1

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        """Alg. 5 scale-out, plus an overload stamp: a call here means a
        pod was unschedulable this cycle, which switches the next
        ``on_cycle`` from rise-only speculation to full-rate ramping."""
        if now - self._last_unsched > self.bin_s:
            self._overload_since = now   # a fresh episode, not a continuation
        self._last_unsched = now
        super().scale_out(cluster, pod, now)

    # -- predictive prelaunch -------------------------------------------------
    def on_cycle(self, cluster: Cluster, now: float) -> None:
        if self.forecaster is None:
            return
        if self._prelaunched_at:
            cutoff = now - self._protect_s
            expired = [nid for nid, t0 in self._prelaunched_at.items()
                       if t0 <= cutoff]
            for nid in expired:
                del self._prelaunched_at[nid]
        self._roll_to(int(now // self.bin_s))
        rate, conf = self.forecaster.predict()
        obs = self.obs
        if obs is not None:
            obs.forecast(now, rate, conf,
                         now - self._last_unsched <= self.bin_s,
                         self._slow_rate if self._slow_rate is not None
                         else 0.0)
        if conf < self.conf_min or rate <= 0.0 or self._arr_n == 0:
            return   # fallback contract: stay purely reactive
        slow = self._slow_rate if self._slow_rate is not None else 0.0
        if rate < slow:
            # Forecast says demand fell: stop shielding speculative nodes
            # from Alg. 6 step 1 — let the cliff drain.
            self._prelaunched_at.clear()
        overloaded = now - self._last_unsched <= self.bin_s
        # Escalation needs the overload to be *fed*: persistent backlog
        # with arrivals still landing (a non-empty last bin) means Alg. 5's
        # ramp is losing the race; a backlog with arrivals gone is a fixed
        # drain the existing fleet retires without further build-out.
        escalated = (overloaded
                     and now - self._overload_since >= self.escalate_s
                     and self._last_bin_rate > 0.0)
        if not escalated:
            # Alg. 5's launch rate limit applies to speculative launches
            # too (the stamp below is shared): un-escalated prediction
            # *shifts* the reactive launch earlier — ahead of the pods
            # going unschedulable — it does not add fleet beyond what the
            # reactive ramp would build.  That keeps cost pinned to the
            # NBAS trajectory while capacity arrives one boot earlier.
            if (self._last_launch is not None
                    and now - self._last_launch < self.provisioning_interval_s):
                return
        if overloaded:
            target_rate = rate   # sustained overload: ramp at forecast rate
        else:
            # Keeping up: speculate only on the rise the reactive path
            # cannot see yet (forecast in excess of the slow trend).
            target_rate = rate - self.trend_min * slow
            if target_rate <= 0.0:
                return   # steady or falling: leave it to reactive Alg. 5
        allowed = self.max_prelaunch_per_cycle if escalated else 1
        jobs = target_rate * self.lead_time_s * self.headroom
        need_cpu = jobs * (self._arr_cpu / self._arr_n)
        need_mem = jobs * (self._arr_mem / self._arr_n)
        free_cpu, free_mem = self._free_capacity(cluster)
        alloc = self.provider.template.allocatable
        deficit = max((need_cpu - free_cpu) / max(alloc.cpu_m, 1),
                      (need_mem - free_mem) / max(alloc.mem_mb, 1e-9))
        if deficit <= 0.0:
            return
        for _ in range(min(allowed, int(np.ceil(deficit)))):
            node = self.provider.launch_node(now)
            cluster.add_node(node)
            self._prelaunched_at[node.node_id] = now
            self.prelaunched += 1
            self._last_launch = now   # shared with the Alg. 5 rate limiter
            if obs is not None:
                obs.scale_out(now, -1, node.node_id, SO_PRELAUNCH, rate=rate,
                              conf=conf, headroom=self.headroom,
                              detail=deficit)

    @staticmethod
    def _free_capacity(cluster: Cluster):
        """(cpu_m, mem_mb) the cluster can still absorb within the lead
        window: free room on READY nodes plus the full allocatable of
        nodes already PROVISIONING (they will be up by then)."""
        arr = cluster.arrays
        if arr is not None:
            active = arr.live("active")
            state = arr.live("state")
            ready = active & (state == _engine.STATE_READY)
            prov = active & (state == _engine.STATE_PROVISIONING)
            free_cpu, free_mem = arr.free_views()
            cpu = (float(np.sum(free_cpu[ready]))
                   + float(np.sum(arr.live("alloc_cpu")[prov])))
            mem = (float(np.sum(free_mem[ready]))
                   + float(np.sum(arr.live("alloc_mem")[prov])))
            return cpu, mem
        cpu = mem = 0.0
        for node in cluster.nodes.values():
            if node.state == NodeState.READY:
                free = node.free
                cpu += free.cpu_m
                mem += free.mem_mb
            elif node.state == NodeState.PROVISIONING:
                cpu += node.allocatable.cpu_m
                mem += node.allocatable.mem_mb
        return cpu, mem

    # -- scale-in protection --------------------------------------------------
    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        self._scale_in_now = now
        return self._scale_in_impl(cluster, now)

    def _step1_candidates(self, cluster: Cluster) -> List[Node]:
        cands = Autoscaler._step1_candidates(cluster)
        if not self._prelaunched_at:
            return cands
        cutoff = self._scale_in_now - self._protect_s
        return [node for node in cands
                if self._prelaunched_at.get(node.node_id, -np.inf) <= cutoff]

    def notify_node_removed(self, node: Node) -> None:
        self._prelaunched_at.pop(node.node_id, None)

    def notify_node_lost(self, node: Node) -> None:
        self._prelaunched_at.pop(node.node_id, None)


AUTOSCALERS = {
    cls.name: cls
    for cls in (VoidAutoscaler, SimpleAutoscaler, BindingAutoscaler,
                PredictiveAutoscaler)
}
