"""Autoscalers (paper §6.3, Algorithms 5, 6, 7).

Scale-out policies:

* **Void** — ignore scale requests (static cluster).
* **Simple / non-binding (NBAS, Alg. 5)** — launch at most one instance per
  ``provisioning_interval`` (set to the provisioning delay + contingency).
* **Binding (BAS, Alg. 7)** — track pod↔provisioning-node associations: a pod
  already assigned to a booting node never triggers another launch, and a
  booting node with spare planned room absorbs further unschedulable pods.

Scale-in (Alg. 6) is shared by both active autoscalers and runs only after a
fully successful scheduling cycle:

1. terminate empty dynamically-created nodes;
2. drain nodes whose pods are all moveable *and* all placeable elsewhere;
3. for mixed moveable+batch nodes whose moveables are placeable elsewhere,
   evict the moveables and **taint** the node so it drains as batch completes.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine as _engine
from repro.core.cluster import Cluster, Node, NodeState
from repro.core.pods import Pod
from repro.core.rescheduler import _ShadowBase, _ShadowCapacity
from repro.core.resources import Resources


class NodeProvider(abc.ABC):
    """What the autoscaler needs from the cloud adapter (repro.cloud)."""

    @abc.abstractmethod
    def launch_node(self, now: float) -> Node:
        """Request a new worker; returns it in PROVISIONING state."""

    @abc.abstractmethod
    def terminate_node(self, node: Node, now: float) -> None:
        """Deprovision (stops billing)."""


class Autoscaler(abc.ABC):
    name = "autoscaler"

    def __init__(self, provider: NodeProvider,
                 scale_in_util_ceiling: Optional[float] = None):
        self.provider = provider
        # Policy-search knob (the "lower threshold" of threshold-based
        # cluster autoscalers): run Alg. 6 consolidation only while mean
        # RAM utilization is at or below this ceiling — a busy cluster
        # skips the drain/taint pass entirely.  None (default) preserves
        # the paper's unconditional scale-in.
        self.scale_in_util_ceiling = scale_in_util_ceiling
        # Version-invalidated shadow snapshot shared by the Alg. 6
        # placeability checks (same cache the reschedulers use): step 2/3
        # candidates that don't consolidate reuse one base instead of
        # re-snapshotting the free vectors per candidate.
        self._shadow_base = _ShadowBase()

    @abc.abstractmethod
    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        """Called per unschedulable pod after rescheduling failed."""

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        """Alg. 6; returns ids of nodes terminated or tainted (for logs)."""
        return []

    def notify_node_ready(self, node: Node) -> None:
        """Provider callback once a node joins the cluster."""

    def notify_node_lost(self, node: Node) -> None:
        """``node`` died (failure/reclaim), possibly while still
        PROVISIONING: drop any provisioning association so its pods can
        trigger replacement capacity instead of staying stranded.
        Default: stateless autoscalers have nothing to clean up."""

    def notify_preemption_notice(self, cluster: Cluster, node: Node,
                                 now: float) -> None:
        """``node`` received a spot reclaim notice and will be killed when
        the notice window closes (``Simulation._on_node_notice``).
        Default: do nothing — react after the kill like any failure."""

    # -- shared Alg. 6 body ----------------------------------------------------
    @staticmethod
    def _step1_candidates(cluster: Cluster) -> List[Node]:
        """Empty dynamically-created nodes (READY or TAINTED), in cluster
        insertion order (slots are append-only, so ascending slot order is
        insertion order — termination order is behaviour)."""
        arr = cluster.arrays
        if arr is not None:
            state = arr.live("state")
            mask = (arr.live("active") & arr.live("autoscaled")
                    & (arr.live("pod_count") == 0)
                    & ((state == _engine.STATE_READY)
                       | (state == _engine.STATE_TAINTED)))
            return [cluster.node_by_slot(int(s)) for s in np.nonzero(mask)[0]]
        return [node for node in list(cluster.nodes.values())
                if (node.autoscaled and not node.pods
                    and node.state in (NodeState.READY, NodeState.TAINTED))]

    @staticmethod
    def _step23_candidates(cluster: Cluster) -> List[Node]:
        """Non-empty autoscaled READY nodes, in cluster insertion order."""
        arr = cluster.arrays
        if arr is not None:
            mask = (arr.live("active") & arr.live("autoscaled")
                    & (arr.live("pod_count") > 0)
                    & (arr.live("state") == _engine.STATE_READY))
            return [cluster.node_by_slot(int(s)) for s in np.nonzero(mask)[0]]
        return [node for node in list(cluster.nodes.values())
                if node.autoscaled and node.state == NodeState.READY
                and node.pods]

    def _utilization(self, cluster: Cluster) -> float:
        """Mean RAM req/cap ratio over READY|TAINTED nodes — the Table-5
        quantity the threshold knobs gate on (0.0 on an empty cluster).
        ``utilization_totals`` is incremental on the array engine and its
        fsum reduction is flush-order independent, so reading it here does
        not disturb the 20 s sampler."""
        n_nodes, ram_sum, _cpu, _ppn = cluster.utilization_totals()
        return ram_sum / n_nodes if n_nodes else 0.0

    def _scale_in_impl(self, cluster: Cluster, now: float) -> List[str]:
        if (self.scale_in_util_ceiling is not None
                and self._utilization(cluster) > self.scale_in_util_ceiling):
            return []
        touched: List[str] = []

        # 1. Shut down empty dynamically-created nodes (READY or TAINTED).
        for node in self._step1_candidates(cluster):
            self.provider.terminate_node(node, now)
            cluster.remove_node(node, now)
            touched.append(node.node_id)

        # 2./3. Consolidate moveable pods off candidate nodes.
        for node in self._step23_candidates(cluster):
            if node.has_only_moveable():
                if self._all_placeable(cluster, node, node.moveable_pods()):
                    for pod in list(node.pods.values()):
                        cluster.unbind(pod, now)   # recreated -> next cycle
                    self.provider.terminate_node(node, now)
                    cluster.remove_node(node, now)
                    touched.append(node.node_id)
            elif node.has_moveable_and_batch():
                movers = node.moveable_pods()
                if movers and self._all_placeable(cluster, node, movers):
                    for pod in movers:
                        cluster.unbind(pod, now)
                    node.taint()                    # drains as batch completes
                    touched.append(node.node_id)
        return touched

    def _all_placeable(self, cluster: Cluster, exclude: Node,
                       pods: List[Pod]) -> bool:
        """True iff *all* of `pods` fit on other nodes (shadow accounting)."""
        base = self._shadow_base if cluster.arrays is not None else None
        shadow = _ShadowCapacity(cluster, exclude=exclude, base=base)
        try:
            ordered = sorted(pods, key=lambda p: (p.requests.mem_mb, p.uid),
                             reverse=True)
            return all(shadow.place_best_fit(p.requests) is not None
                       for p in ordered)
        finally:
            shadow.rollback()


class VoidAutoscaler(Autoscaler):
    """Paper: ignores scale-out and scale-in — a fixed-size cluster."""

    name = "void"

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        return

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        return []


class SimpleAutoscaler(Autoscaler):
    """Paper Alg. 5 (+6) — the *non-binding* autoscaler (NBAS)."""

    name = "non-binding"

    def __init__(self, provider: NodeProvider,
                 provisioning_interval_s: float = 60.0,
                 scale_out_bypass_util: Optional[float] = None,
                 scale_in_util_ceiling: Optional[float] = None):
        super().__init__(provider, scale_in_util_ceiling=scale_in_util_ceiling)
        self.provisioning_interval_s = provisioning_interval_s
        # Policy-search knob (the "upper threshold"): when mean RAM
        # utilization reaches this level the Alg. 5 rate limit is bypassed
        # — a saturated cluster may launch every cycle instead of once per
        # provisioning interval.  None (default) keeps the paper's
        # unconditional rate limit.
        self.scale_out_bypass_util = scale_out_bypass_util
        self._last_launch: Optional[float] = None

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        rate_ok = (self._last_launch is None
                   or now - self._last_launch >= self.provisioning_interval_s)
        if not rate_ok and self.scale_out_bypass_util is not None:
            rate_ok = self._utilization(cluster) >= self.scale_out_bypass_util
        if rate_ok:
            node = self.provider.launch_node(now)
            cluster.add_node(node)
            self._last_launch = now
        # else: ignore the scale-out request (rate limited)

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        return self._scale_in_impl(cluster, now)


@dataclasses.dataclass
class _ProvisioningTracker:
    node: Node
    assigned: Dict[int, Resources]    # pod uid -> its planned requests

    @property
    def planned_free(self) -> Resources:
        free = self.node.allocatable
        for req in self.assigned.values():
            free = free - req
        return free


class BindingAutoscaler(Autoscaler):
    """Paper Alg. 7 (+6) — the *binding* autoscaler (BAS).

    Keeps the pod↔booting-node association so that one unschedulable pod
    triggers at most one launch, and booting capacity is packed before any
    further launch (the mechanism behind the paper's lowest-cost results).
    """

    name = "binding"

    def __init__(self, provider: NodeProvider,
                 scale_in_util_ceiling: Optional[float] = None):
        super().__init__(provider, scale_in_util_ceiling=scale_in_util_ceiling)
        self._tracked: Dict[str, _ProvisioningTracker] = {}
        self._pod_to_node: Dict[int, str] = {}
        self._noticed: set = set()   # node ids already given a replacement

    def scale_out(self, cluster: Cluster, pod: Pod, now: float) -> None:
        if pod.uid in self._pod_to_node:
            return  # already associated with a booting node — ignore
        # Is there still room in one of the nodes being provisioned?
        for tracker in sorted(self._tracked.values(),
                              key=lambda t: t.node.node_id):
            if pod.requests.fits_in(tracker.planned_free):
                tracker.assigned[pod.uid] = pod.requests
                self._pod_to_node[pod.uid] = tracker.node.node_id
                return
        # Launch a new node and assign the pod to it.
        node = self.provider.launch_node(now)
        cluster.add_node(node)
        self._tracked[node.node_id] = _ProvisioningTracker(
            node=node, assigned={pod.uid: pod.requests})
        self._pod_to_node[pod.uid] = node.node_id

    def notify_node_ready(self, node: Node) -> None:
        tracker = self._tracked.pop(node.node_id, None)
        if tracker is None:
            return
        for uid in tracker.assigned:
            self._pod_to_node.pop(uid, None)
        # The scheduler (not the autoscaler) places pods on the new node.

    def notify_node_lost(self, node: Node) -> None:
        """Release the association state of a dead node.  Without this, a
        node failing while PROVISIONING leaks its tracker and every pod
        assigned to it stays permanently stranded (``scale_out``'s
        "already associated" early-return never launches a replacement)."""
        self._noticed.discard(node.node_id)
        tracker = self._tracked.pop(node.node_id, None)
        if tracker is None:
            return
        for uid in tracker.assigned:
            self._pod_to_node.pop(uid, None)

    def notify_preemption_notice(self, cluster: Cluster, node: Node,
                                 now: float) -> None:
        """Launch replacement capacity *during* the notice window instead
        of after the kill: the replacement boots while the doomed node
        drains, so evictees re-bind one provisioning delay sooner.  The
        evictees associate with the booting replacement through the
        normal ``scale_out`` path once the kill re-pends them; an empty
        replacement (the workload drained during the window) is reaped by
        scale-in."""
        if node.node_id in self._noticed:
            return   # one replacement per reclaimed node
        self._noticed.add(node.node_id)
        if not node.pods:
            return   # nothing to re-home; later arrivals scale out normally
        replacement = self._launch_replacement(node, now)
        cluster.add_node(replacement)
        self._tracked[replacement.node_id] = _ProvisioningTracker(
            node=replacement, assigned={})

    def _launch_replacement(self, node: Node, now: float) -> Node:
        """Like-for-like replacement; the heterogeneous subclass launches
        the reclaimed node's own instance type."""
        return self.provider.launch_node(now)

    def scale_in(self, cluster: Cluster, now: float) -> List[str]:
        return self._scale_in_impl(cluster, now)


AUTOSCALERS = {
    cls.name: cls
    for cls in (VoidAutoscaler, SimpleAutoscaler, BindingAutoscaler)
}
