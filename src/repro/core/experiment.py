"""Experiment wiring: one call = one cell of Fig. 3 / Fig. 4 / Table 5.

`run_experiment` reproduces a rescheduler×autoscaler combination on one of the
paper's workloads; `run_k8s_baseline` reproduces the Fig.-4 baseline (default
kube-scheduler on the *minimum* static cluster that completes the workload).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.autoscaler import (AUTOSCALERS, BindingAutoscaler,
                                   PredictiveAutoscaler, SimpleAutoscaler,
                                   VoidAutoscaler)
from repro.core.cluster import Cluster
from repro.core.cost import CostModel
from repro.core.metrics import ExperimentResult
from repro.core.orchestrator import Orchestrator
from repro.core.rescheduler import RESCHEDULERS
from repro.core.scheduler import SCHEDULERS
from repro.core.simulation import SimConfig, Simulation
from repro.core.workload import Arrival, generate_workload

MAX_POD_AGE_S = 60.0            # Table 4
PROVISIONING_INTERVAL_S = 60.0  # Table 4
PRICE_PER_S = 0.011             # Table 4


@dataclasses.dataclass
class ExperimentSpec:
    workload: str = "mixed"
    scheduler: str = "best-fit"
    rescheduler: str = "void"
    autoscaler: str = "binding"
    seed: int = 0
    initial_workers: int = 1
    static_workers: Optional[int] = None   # forces a fixed-size cluster
    template: object = None                # NodeTemplate; None -> M2_SMALL
    # Picklable twin of `template`: a `repro.cloud.adapter.NODE_TEMPLATES`
    # name — the policy search's node-template axis crosses process
    # boundaries as a string.  Mutually exclusive with `template`.
    template_name: Optional[str] = None
    max_pod_age_s: float = MAX_POD_AGE_S
    provisioning_interval_s: float = PROVISIONING_INTERVAL_S
    cycle_period_s: float = 10.0
    # Policy-search knobs (repro.search).  All default to the paper's
    # hard-coded behavior:
    # * scheduler_weights — (w_pack, w_lr, w_bal) for scheduler="weighted"
    #   (raises with any other scheduler: silently inert weights would make
    #   searched configs unreproducible);
    # * scale_out_bypass_util — NBAS Alg. 5 rate-limit bypass above this
    #   mean RAM utilization (non-binding autoscaler only, None = never);
    # * scale_in_util_ceiling — run Alg. 6 consolidation only at or below
    #   this mean RAM utilization (None = always).
    scheduler_weights: Optional[tuple] = None
    scale_out_bypass_util: Optional[float] = None
    scale_in_util_ceiling: Optional[float] = None
    # Predictive-autoscaler knobs (autoscaler="predictive" only — see
    # repro.core.autoscaler.PredictiveAutoscaler + repro.forecast).
    # `forecaster` names the built-in online forecaster ("ewma"); None
    # disables prediction entirely (bit-identical to autoscaler
    # "non-binding").  `forecaster_obj` injects a programmatic forecaster
    # (e.g. a trained repro.forecast.model.LearnedForecaster restored
    # from a checkpoint) and takes precedence over the name.
    forecaster: Optional[str] = "ewma"
    forecaster_obj: object = None
    forecast_bin_s: float = 30.0
    forecast_lead_s: float = 90.0
    forecast_headroom: float = 1.15
    forecast_conf_min: float = 0.35
    failure_injector: object = None
    straggler_threshold: float = 0.0
    # repro.core.failures.StragglerInjector — wired into the provider's
    # launch path so a deterministic fraction of autoscaled nodes boots
    # slow; pair with straggler_threshold > 0 to exercise the eviction
    # policy that moves checkpointable batch work off them.
    straggler_injector: object = None
    arrivals: Optional[List[Arrival]] = None   # override the workload trace
    # Columnar workload sources (repro.scenarios): a TraceStore replayed
    # natively through the array engine's bulk ingest, or a registry
    # scenario name built with this spec's seed.  `arrivals`, `trace` and
    # `scenario` are mutually exclusive — see `workload_source`.
    trace: object = None                       # scenarios.TraceStore
    scenario: Optional[str] = None             # scenarios.registry name
    scenario_jobs: Optional[int] = None        # override the family's length
    # "array" (vectorized SoA engine, default) or "object" (seed object-scan
    # engine); None defers to the REPRO_SCHED_ENGINE env var.
    engine: Optional[str] = None
    # Wave selection kernel: "argmin" (flat reduction), "segtree" (O(log n)
    # index), or "auto" (tree above engine.SEGTREE_AUTO_MIN_NODES active
    # nodes — the kernels are decision-identical, so this is purely a
    # performance choice); None defers to the REPRO_WAVE_SELECT env var.
    wave_select: Optional[str] = None
    # Observability (repro.obs): an ObsConfig (or True for defaults)
    # attaches a flight recorder + cycle-phase profiler to the built
    # simulation.  None (default) compiles observability out — the hot
    # paths pay one is-None test and results are untouched; with it set,
    # recording is passive and ExperimentResult stays bit-identical.
    obs: object = None

    def workload_source(self):
        """Resolve this spec's workload to ``(arrivals, trace)`` — exactly
        one is non-None.

        ``arrivals`` (explicit list), ``trace`` (columnar TraceStore) and
        ``scenario`` (registry name, built with this spec's seed and
        ``scenario_jobs``) are mutually exclusive; naming more than one is
        ambiguous and raises immediately rather than silently preferring
        one.  With none set, the paper workload named by ``workload`` is
        generated as the classic arrival list."""
        sources = [name for name, v in (("arrivals", self.arrivals),
                                        ("trace", self.trace),
                                        ("scenario", self.scenario))
                   if v is not None]
        if len(sources) > 1:
            raise ValueError(
                f"ExperimentSpec got multiple workload sources "
                f"({' + '.join(sources)}); set at most one of "
                f"arrivals / trace / scenario")
        if self.scenario_jobs is not None and self.scenario is None:
            raise ValueError("scenario_jobs is only meaningful together "
                             "with scenario=<registry name>")
        if self.arrivals is not None:
            return self.arrivals, None
        if self.trace is not None:
            return None, self.trace
        if self.scenario is not None:
            from repro.scenarios import build_scenario
            return None, build_scenario(self.scenario, seed=self.seed,
                                        n_jobs=self.scenario_jobs)
        return generate_workload(self.workload, seed=self.seed), None

    def workload_label(self) -> str:
        """The name recorded on the ExperimentResult row."""
        if self.scenario is not None:
            return self.scenario
        if self.trace is not None:
            return getattr(self.trace, "name", "trace")
        return self.workload


def build_simulation(spec: ExperimentSpec) -> Simulation:
    # Imported here (not at module level) to avoid a package import cycle:
    # repro.cloud.adapter needs repro.core.autoscaler's NodeProvider.
    from repro.cloud.adapter import M2_SMALL, NODE_TEMPLATES, SimCloudProvider

    if spec.template is not None and spec.template_name is not None:
        raise ValueError("ExperimentSpec got both template and template_name;"
                         " set at most one")
    if spec.template_name is not None:
        try:
            template = NODE_TEMPLATES[spec.template_name]
        except KeyError:
            raise KeyError(
                f"unknown template_name {spec.template_name!r}; known: "
                f"{sorted(NODE_TEMPLATES)}") from None
    else:
        template = spec.template or M2_SMALL

    cost = CostModel(price_per_s=PRICE_PER_S)
    # Non-default templates bill at their own catalog price; M2_SMALL's
    # entry equals PRICE_PER_S, so this is value-neutral for the default.
    cost.price_table.setdefault(template.name, template.price_per_s)
    provider = SimCloudProvider(template, cost,
                                straggler_injector=spec.straggler_injector)
    use_arrays = None if spec.engine is None else (spec.engine != "object")
    cluster = Cluster(use_arrays=use_arrays, wave_select=spec.wave_select)

    n_static = (spec.static_workers if spec.static_workers is not None
                else spec.initial_workers)
    for _ in range(n_static):
        cluster.add_node(provider.make_static_node(0.0))

    if spec.scheduler_weights is not None and spec.scheduler != "weighted":
        raise ValueError(
            f"scheduler_weights is only meaningful with scheduler='weighted'"
            f" (got scheduler={spec.scheduler!r})")
    if spec.scheduler == "weighted" and spec.scheduler_weights is not None:
        scheduler = SCHEDULERS["weighted"](*spec.scheduler_weights)
    else:
        scheduler = SCHEDULERS[spec.scheduler]()
    rescheduler = RESCHEDULERS[spec.rescheduler](
        max_pod_age_s=spec.max_pod_age_s)
    if spec.autoscaler == "void":
        autoscaler = VoidAutoscaler(provider)
    elif spec.autoscaler == "non-binding":
        autoscaler = SimpleAutoscaler(
            provider, provisioning_interval_s=spec.provisioning_interval_s,
            scale_out_bypass_util=spec.scale_out_bypass_util,
            scale_in_util_ceiling=spec.scale_in_util_ceiling)
    elif spec.autoscaler == "binding":
        autoscaler = BindingAutoscaler(
            provider, scale_in_util_ceiling=spec.scale_in_util_ceiling)
    elif spec.autoscaler == "predictive":
        if spec.forecaster_obj is not None:
            forecaster = spec.forecaster_obj
        elif spec.forecaster is None:
            forecaster = None
        elif spec.forecaster == "ewma":
            from repro.forecast import EwmaForecaster
            forecaster = EwmaForecaster()
        else:
            raise KeyError(f"unknown forecaster {spec.forecaster!r}; "
                           f"known: 'ewma', None, or set forecaster_obj")
        autoscaler = PredictiveAutoscaler(
            provider, provisioning_interval_s=spec.provisioning_interval_s,
            scale_out_bypass_util=spec.scale_out_bypass_util,
            scale_in_util_ceiling=spec.scale_in_util_ceiling,
            forecaster=forecaster, bin_s=spec.forecast_bin_s,
            lead_time_s=spec.forecast_lead_s,
            headroom=spec.forecast_headroom,
            conf_min=spec.forecast_conf_min)
    else:
        raise KeyError(spec.autoscaler)

    orch = Orchestrator(cluster, scheduler, rescheduler, autoscaler,
                        straggler_threshold=spec.straggler_threshold)
    arrivals, trace = spec.workload_source()
    sim = Simulation(orch, cost, arrivals, trace=trace,
                     config=SimConfig(cycle_period_s=spec.cycle_period_s),
                     failure_injector=spec.failure_injector)
    provider.attach(sim)
    if spec.obs is not None and spec.obs is not False:
        from repro.obs import ObsConfig, ObsRecorder
        config = spec.obs if isinstance(spec.obs, ObsConfig) else None
        recorder = ObsRecorder(config).attach(sim)
        recorder.meta = {
            "workload": spec.workload_label(), "scheduler": spec.scheduler,
            "rescheduler": spec.rescheduler, "autoscaler": spec.autoscaler,
            "seed": spec.seed,
            "engine": "array" if cluster.arrays is not None else "object"}
    return sim


def run_experiment(spec: ExperimentSpec) -> ExperimentResult:
    sim = build_simulation(spec)
    result = sim.run()
    result.workload = spec.workload_label()
    return result


def run_k8s_baseline(workload: str, seed: int = 0, max_nodes: int = 60,
                     cycle_period_s: float = 10.0,
                     engine: Optional[str] = None,
                     search: str = "bisect") -> ExperimentResult:
    """Fig. 4 baseline: default K8s scheduler on the minimum static cluster
    able to *successfully place* and execute all jobs.

    "Successfully place" is read as placement without queuing (every pod is
    bound in the scheduling cycle it arrives in): with queuing allowed, any
    cluster big enough for the services alone eventually "completes", which
    contradicts the paper's reported K8s scheduling durations being slightly
    *better* than the autoscaled ones (§7.2/Fig. 4B — zero pending time).

    The acceptability predicate is monotone in the cluster size (more
    spread-scheduled identical nodes never create queuing), so the minimum
    is found by **bisection** over ``[1, max_nodes]`` — O(log max_nodes)
    simulations instead of one per candidate size (``search="linear"``
    restores the scan order; ``tests/test_engine_parity.py`` asserts both
    searches pick the same cluster).  Each candidate run restarts the global
    id counters so its outcome depends only on ``n`` — not on how many sims
    ran before it — which is what makes the two search orders comparable.
    Note this hermeticity is a deliberate change from the seed linear scan,
    whose candidates inherited whatever counter state earlier candidates
    left behind (node ids order lexicographically, so counter offsets could
    shift tie-breaks): baseline rows are now reproducible in isolation, but
    may differ from the seed's exact numbers.
    """
    def attempt(n: int) -> ExperimentResult:
        # Deferred import: reset_id_counters lives in the package root,
        # which imports this module (same cycle-avoidance as build_simulation).
        from repro.core import reset_id_counters
        reset_id_counters()
        spec = ExperimentSpec(workload=workload, scheduler="k8s-default",
                              rescheduler="void", autoscaler="void",
                              static_workers=n, seed=seed,
                              cycle_period_s=cycle_period_s, engine=engine)
        return run_experiment(spec)

    def acceptable(r: ExperimentResult) -> bool:
        return r.completed and r.max_pending_s <= cycle_period_s + 1e-9

    if search == "linear":
        for n in range(1, max_nodes + 1):
            result = attempt(n)
            if acceptable(result):
                return result
    elif search == "bisect":
        best = attempt(max_nodes)
        if acceptable(best):
            lo, hi = 1, max_nodes
            while lo < hi:
                mid = (lo + hi) // 2
                result = attempt(mid)
                if acceptable(result):
                    hi, best = mid, result
                else:
                    lo = mid + 1
            return best
    else:
        raise ValueError(f"search must be 'bisect' or 'linear', got {search!r}")
    raise RuntimeError(f"k8s baseline did not complete with <= {max_nodes}"
                       f" nodes on workload {workload!r}")


def run_all_combos(workload: str, seed: int = 0,
                   engine: Optional[str] = None) -> List[ExperimentResult]:
    """The six rescheduler × autoscaler combinations of Fig. 3."""
    out = []
    for rescheduler in ("void", "binding", "non-binding"):
        for autoscaler in ("non-binding", "binding"):
            spec = ExperimentSpec(workload=workload, rescheduler=rescheduler,
                                  autoscaler=autoscaler, seed=seed,
                                  engine=engine)
            out.append(run_experiment(spec))
    return out
