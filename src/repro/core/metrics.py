"""Experiment metrics (paper §7.2, Table 5 + Fig. 3/4 quantities).

* **cost** — from `CostModel` (per-second billing).
* **scheduling duration** — first job submitted → last batch job completed.
* **median scheduling time** — median of per-pod pending intervals.
* **RAM / CPU req/cap ratios** — sampled every 20 s over cluster nodes, then
  time-averaged (paper's Table 5 definition).
* **pods per node** — same sampling.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional, Tuple

from repro.core.cluster import Cluster, NodeState

SAMPLE_PERIOD_S = 20.0


@dataclasses.dataclass
class Sample:
    time: float
    n_nodes: int
    ram_ratio: float
    cpu_ratio: float
    pods_per_node: float


class MetricsCollector:
    def __init__(self):
        self.samples: List[Sample] = []
        self.pending_intervals: List[float] = []
        # (sample time, live node count) at every 20 s tick — exported
        # through the obs bundle (repro.obs.ObsRecorder.bundle) alongside
        # pending_intervals for fleet-size-over-time plots.
        self.node_count_series: List[Tuple[float, int]] = []

    def sample(self, cluster: Cluster, now: float) -> None:
        # cluster.utilization_totals() reads the SoA mirror's incrementally
        # maintained sampling aggregates (O(dirty nodes) per tick) when the
        # mirror is on; the sums are exact (fsum rounding), so sum/n is
        # bit-identical to the seed per-node fmean scan on both engines.
        n_nodes, ram_sum, cpu_sum, ppn_sum = cluster.utilization_totals()
        # node_count_series records the n_nodes actually sampled — including
        # the (now, 0) point on an empty cluster, which the seed dropped.
        self.node_count_series.append((now, n_nodes))
        if n_nodes == 0:
            self.samples.append(Sample(now, 0, 0.0, 0.0, 0.0))
            return
        self.samples.append(Sample(now, n_nodes, ram_sum / n_nodes,
                                   cpu_sum / n_nodes,
                                   float(ppn_sum) / n_nodes))

    def record_pending_interval(self, seconds: float) -> None:
        self.pending_intervals.append(seconds)

    def record_pending_intervals(self, seconds) -> None:
        """Bulk append (one call per pod at end-of-run, not per interval)."""
        self.pending_intervals.extend(seconds)

    # -- aggregates -------------------------------------------------------------
    def median_pending_s(self) -> float:
        return statistics.median(self.pending_intervals) if self.pending_intervals else 0.0

    def mean_pending_s(self) -> float:
        """Mean per-pod pending interval — the policy-search objective
        (repro.search): unlike the median it is sensitive to the long tail
        a bad autoscaling policy produces."""
        return (statistics.fmean(self.pending_intervals)
                if self.pending_intervals else 0.0)

    def max_pending_s(self) -> float:
        return max(self.pending_intervals) if self.pending_intervals else 0.0

    def avg_ram_ratio(self) -> float:
        xs = [s.ram_ratio for s in self.samples if s.n_nodes > 0]
        return statistics.fmean(xs) if xs else 0.0

    def avg_cpu_ratio(self) -> float:
        xs = [s.cpu_ratio for s in self.samples if s.n_nodes > 0]
        return statistics.fmean(xs) if xs else 0.0

    def avg_pods_per_node(self) -> float:
        xs = [s.pods_per_node for s in self.samples if s.n_nodes > 0]
        return statistics.fmean(xs) if xs else 0.0

    def max_nodes(self) -> int:
        return max((s.n_nodes for s in self.samples), default=0)


@dataclasses.dataclass
class ExperimentResult:
    """One row of Fig. 3 / Table 5."""

    workload: str
    scheduler: str
    rescheduler: str
    autoscaler: str
    completed: bool
    cost: float
    duration_s: float
    median_pending_s: float
    mean_pending_s: float
    max_pending_s: float
    avg_ram_ratio: float
    avg_cpu_ratio: float
    avg_pods_per_node: float
    max_nodes: int
    node_seconds: int
    evictions: int = 0
    scale_outs: int = 0
    scale_ins: int = 0
    failures_injected: int = 0
    # Disruption telemetry (repro.core.disruption): spot reclaim notices
    # delivered, and Σ executed-but-not-durable seconds across evictions.
    preemption_notices: int = 0
    lost_work_s: float = 0.0

    def combo(self) -> str:
        abbrev = {"void": "VR", "non-binding": "NBR", "binding": "BR"}
        as_abbrev = {"void": "VAS", "non-binding": "NBAS", "binding": "BAS",
                     "predictive": "PAS"}
        return f"{abbrev.get(self.rescheduler, self.rescheduler)}-" \
               f"{as_abbrev.get(self.autoscaler, self.autoscaler)}"

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)
