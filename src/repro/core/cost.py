"""Billing model (paper §7.1).

Per-second billing at $0.011 per worker (Azure B2S-derived), partial seconds
rounded **up**.  A dynamically-created node is billed from the moment the
provisioning request is placed until the deprovisioning request; static nodes
are billed for the whole scheduling duration of the workload.

The fleet adaptation uses the identical model with a per-node-type price table
(heterogeneous node types are a paper-§8 extension, off by default).

Closed records are mirrored into SoA columns (start / end / node_type) as
they retire, so the end-of-run queries (`total_cost`, `total_node_seconds`)
are one vectorized ceil/multiply reduction over the billing history instead
of a per-record method-call walk — at 2k autoscaled nodes that walk was ~5%
of full-run wall time.  The float contract is unchanged: per-record seconds
are ``ceil(max(0, end-start))`` (bit-identical to ``math.ceil`` below 2^53)
and the cost accumulates left-to-right in record-retirement order, so the
totals match the scalar loop bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.cluster import Node

DEFAULT_PRICE_PER_S = 0.011


@dataclasses.dataclass
class BillingRecord:
    node_id: str
    node_type: str
    start: float
    end: Optional[float] = None   # None -> still running

    def seconds(self, now: float) -> int:
        end = self.end if self.end is not None else now
        return int(math.ceil(max(0.0, end - self.start)))


class CostModel:
    """Tracks provision/deprovision events and prices node-seconds."""

    def __init__(self, price_per_s: float = DEFAULT_PRICE_PER_S,
                 price_table: Optional[Dict[str, float]] = None):
        self.price_per_s = price_per_s
        self.price_table = price_table or {}
        self.records: Dict[str, BillingRecord] = {}
        self.closed: List[BillingRecord] = []
        # SoA mirror of `closed` (same order): the query path reduces over
        # these columns instead of walking record objects.
        self._closed_start: List[float] = []
        self._closed_end: List[float] = []
        self._closed_type: List[str] = []

    def price_of(self, node_type: str) -> float:
        return self.price_table.get(node_type, self.price_per_s)

    # -- events ---------------------------------------------------------------
    def on_provision(self, node: Node, now: float) -> None:
        open_rec = self.records.get(node.node_id)
        if open_rec is not None:
            raise ValueError(
                f"node {node.node_id} is already billing (open record since "
                f"t={open_rec.start}): double provision — deprovision it "
                f"before provisioning again")
        self.records[node.node_id] = BillingRecord(
            node_id=node.node_id, node_type=node.node_type, start=now)

    def on_deprovision(self, node: Node, now: float) -> None:
        rec = self.records.pop(node.node_id, None)
        if rec is None:
            raise ValueError(
                f"node {node.node_id} has no open billing record: double "
                f"deprovision (a failed/reclaimed node is already retired "
                f"by the NODE_FAIL handler — don't also terminate it) or "
                f"a node this CostModel never provisioned")
        rec.end = now
        self.closed.append(rec)
        self._closed_start.append(rec.start)
        self._closed_end.append(now)
        self._closed_type.append(rec.node_type)

    def close_all(self, now: float) -> None:
        """End of experiment: static/running nodes stop billing now.

        One bulk column append over the open set (insertion order, same as
        the retired-record order the scalar walk produced) instead of a
        per-node close loop."""
        if not self.records:
            return
        recs = list(self.records.values())
        self.records.clear()
        for rec in recs:
            rec.end = now
        self.closed.extend(recs)
        self._closed_start.extend(rec.start for rec in recs)
        self._closed_end.extend(now for _ in recs)
        self._closed_type.extend(rec.node_type for rec in recs)

    # -- queries ---------------------------------------------------------------
    def _resolve_now(self, now: Optional[float]) -> float:
        """``now`` may be omitted only once every record is closed.

        Open records bill ``start → now``; pricing them against a default
        of 0.0 silently yields `max(0, -start)` = 0 node-seconds for every
        running node — a cost of $0 that *looks* like an answer.  Closed
        records never read ``now``, so the query is unambiguous without it
        only after ``close_all``/``on_deprovision`` retired everything."""
        if now is not None:
            return now
        if self.records:
            raise ValueError(
                f"now= is required while {len(self.records)} node(s) are "
                "still billing (open records would price as 0 seconds); "
                "pass the current simulation time or call close_all first")
        return 0.0   # unused: only closed records remain

    def _seconds_column(self, now: float) -> "tuple":
        """``(seconds, node_types)`` over closed-then-open records.

        ``seconds`` is one vectorized ``ceil(max(0, end-start))`` reduction
        — bit-identical to ``BillingRecord.seconds`` (float64 ``np.ceil``
        equals ``math.ceil`` for any billing span below 2^53 seconds)."""
        if len(self._closed_start) != len(self.closed):   # external mutation
            self._closed_start = [r.start for r in self.closed]
            self._closed_end = [now if r.end is None else r.end
                                for r in self.closed]
            self._closed_type = [r.node_type for r in self.closed]
        open_recs = list(self.records.values())
        starts = np.fromiter(
            (s for s in self._closed_start), dtype=np.float64,
            count=len(self._closed_start))
        ends = np.fromiter(
            (e for e in self._closed_end), dtype=np.float64,
            count=len(self._closed_end))
        if open_recs:
            starts = np.concatenate(
                [starts, np.fromiter((r.start for r in open_recs),
                                     dtype=np.float64, count=len(open_recs))])
            ends = np.concatenate(
                [ends, np.full(len(open_recs), now, dtype=np.float64)])
        seconds = np.ceil(np.maximum(0.0, ends - starts))
        types = self._closed_type + [r.node_type for r in open_recs]
        return seconds, types

    def total_cost(self, now: Optional[float] = None) -> float:
        now = self._resolve_now(now)
        seconds, types = self._seconds_column(now)
        if not types:
            return 0.0
        prices = np.fromiter((self.price_of(t) for t in types),
                             dtype=np.float64, count=len(types))
        # Left-to-right accumulation in record order: the per-term products
        # are IEEE-identical to the scalar loop's `seconds * price`, and the
        # running float sum must visit them in the same order to keep the
        # golden-fixture cost bits.
        total = 0.0
        for term in (seconds * prices).tolist():
            total += term
        return total

    def total_node_seconds(self, now: Optional[float] = None) -> int:
        now = self._resolve_now(now)
        seconds, _ = self._seconds_column(now)
        # Exact: every element is a small non-negative integer-valued float,
        # so the float64 sum is exact far beyond any plausible fleet size.
        return int(seconds.sum())
