"""Billing model (paper §7.1).

Per-second billing at $0.011 per worker (Azure B2S-derived), partial seconds
rounded **up**.  A dynamically-created node is billed from the moment the
provisioning request is placed until the deprovisioning request; static nodes
are billed for the whole scheduling duration of the workload.

The fleet adaptation uses the identical model with a per-node-type price table
(heterogeneous node types are a paper-§8 extension, off by default).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.cluster import Node

DEFAULT_PRICE_PER_S = 0.011


@dataclasses.dataclass
class BillingRecord:
    node_id: str
    node_type: str
    start: float
    end: Optional[float] = None   # None -> still running

    def seconds(self, now: float) -> int:
        end = self.end if self.end is not None else now
        return int(math.ceil(max(0.0, end - self.start)))


class CostModel:
    """Tracks provision/deprovision events and prices node-seconds."""

    def __init__(self, price_per_s: float = DEFAULT_PRICE_PER_S,
                 price_table: Optional[Dict[str, float]] = None):
        self.price_per_s = price_per_s
        self.price_table = price_table or {}
        self.records: Dict[str, BillingRecord] = {}
        self.closed: List[BillingRecord] = []

    def price_of(self, node_type: str) -> float:
        return self.price_table.get(node_type, self.price_per_s)

    # -- events ---------------------------------------------------------------
    def on_provision(self, node: Node, now: float) -> None:
        open_rec = self.records.get(node.node_id)
        if open_rec is not None:
            raise ValueError(
                f"node {node.node_id} is already billing (open record since "
                f"t={open_rec.start}): double provision — deprovision it "
                f"before provisioning again")
        self.records[node.node_id] = BillingRecord(
            node_id=node.node_id, node_type=node.node_type, start=now)

    def on_deprovision(self, node: Node, now: float) -> None:
        rec = self.records.pop(node.node_id, None)
        if rec is None:
            raise ValueError(
                f"node {node.node_id} has no open billing record: double "
                f"deprovision (a failed/reclaimed node is already retired "
                f"by the NODE_FAIL handler — don't also terminate it) or "
                f"a node this CostModel never provisioned")
        rec.end = now
        self.closed.append(rec)

    def close_all(self, now: float) -> None:
        """End of experiment: static/running nodes stop billing now."""
        for rec in list(self.records.values()):
            rec.end = now
            self.closed.append(rec)
        self.records.clear()

    # -- queries ---------------------------------------------------------------
    def _resolve_now(self, now: Optional[float]) -> float:
        """``now`` may be omitted only once every record is closed.

        Open records bill ``start → now``; pricing them against a default
        of 0.0 silently yields `max(0, -start)` = 0 node-seconds for every
        running node — a cost of $0 that *looks* like an answer.  Closed
        records never read ``now``, so the query is unambiguous without it
        only after ``close_all``/``on_deprovision`` retired everything."""
        if now is not None:
            return now
        if self.records:
            raise ValueError(
                f"now= is required while {len(self.records)} node(s) are "
                "still billing (open records would price as 0 seconds); "
                "pass the current simulation time or call close_all first")
        return 0.0   # unused: only closed records remain

    def total_cost(self, now: Optional[float] = None) -> float:
        now = self._resolve_now(now)
        total = 0.0
        for rec in self.closed:
            total += rec.seconds(now) * self.price_of(rec.node_type)
        for rec in self.records.values():
            total += rec.seconds(now) * self.price_of(rec.node_type)
        return total

    def total_node_seconds(self, now: Optional[float] = None) -> int:
        now = self._resolve_now(now)
        return (sum(r.seconds(now) for r in self.closed)
                + sum(r.seconds(now) for r in self.records.values()))
