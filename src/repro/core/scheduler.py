"""Schedulers (paper §6.1 + the default-K8s baseline used in Fig. 4).

All schedulers implement the same two-stage shape Kubernetes uses:
*filter* (feasibility) then *select* (scoring).  The paper's contribution is
the selection rule; filtering is request-based feasibility on both axes.

Tainted nodes (Alg. 6 step 3) are used **only as a last resort**: the filter
first considers READY nodes and falls back to TAINTED nodes only when no
untainted node fits.

Two execution engines share each policy:

* the **object path** (seed engine) — list comprehensions over ``Node``
  objects, kept for parity testing and as the fallback when the cluster has
  no SoA mirror;
* the **array path** — filter+select as masked NumPy reductions over the
  cluster's :class:`repro.core.engine.ClusterArrays` mirror.  Identical
  floats, identical IEEE ops, identical tie-breaks => identical bindings.

On the array path the orchestrator schedules in **waves**
(:meth:`Scheduler.select_wave_store`): the whole pending snapshot — rows of
the SoA :class:`repro.core.engine.PodStore` — is placed against a
:class:`repro.core.engine.WavePlacer` in one call, and the chosen bindings
are committed once per wave (``Cluster.bind_wave_store``, or the
object-path ``Cluster.bind_wave`` when an external observer needs ``Pod``
shells) instead of once per pod.  :meth:`Scheduler.select_wave` is the
``Pod``-based twin, kept as the documented reference implementation and for
direct callers.  Each policy contributes its vectorized selection rule
through two hooks:

* :attr:`Scheduler.wave_mode` — ``'min'``/``'max'``: which extremum of the
  policy's score vector wins (``None`` = no score, first feasible node in
  node_id order);
* :meth:`Scheduler.wave_scores` — the score vector itself, computed over the
  placer's working free columns (falls back to ``None`` for score-free
  policies).

``select_slot`` (the iterated single-pod array kernel) remains as the
non-wave array path used by :meth:`Scheduler.schedule`; a policy that
defines ``select_slot`` but keeps the default wave hooks is still wave-
compatible because the base ``select_wave`` loop and ``select_slot`` read
the same masks and tie-breaks.

Wave-placement parity contract (property-tested by
``tests/test_engine_parity.py``): a wave must produce the **bit-identical
bind sequence** the seed per-pod loop produces — same pods on the same
nodes in the same order, lowest-node_id tie-breaks — because the placer
advances its working frees with the same float ops the object accounting
applies (see ``repro.core.engine``).

Tie-breaks are uniform across all four policies: among equally-scored
feasible nodes the **lexicographically lowest node_id wins**.
"""
from __future__ import annotations

import abc
from typing import List, Optional, Tuple

import numpy as np

from repro.core import engine as _engine
from repro.core.cluster import Cluster, Node
from repro.core.pods import Pod, PodPhase
from repro.core.resources import Resources


def _lowest_id(nodes: List[Node]) -> Node:
    return min(nodes, key=lambda n: n.node_id)


class Scheduler(abc.ABC):
    """Base scheduler: filter feasible nodes, pick one, create the binding."""

    name = "scheduler"

    # Concrete policies override with a vectorized (arrays, mask, free_cpu,
    # free_mem, pod) -> slot implementation; None disables the array path.
    select_slot = None

    # Wave placement: which extremum of `wave_scores` wins ('min' | 'max');
    # None = score-free policy (first feasible node in node_id order).
    wave_mode: Optional[str] = None

    # Run-length fast path (select_wave_store): amortize one extremum query
    # over a run of same-size pods.  Sound only for 'min' policies whose
    # score at the bound rank can only move further into the minimum or go
    # infeasible (best-fit: free_mem decreases per bind) — every other rank's
    # cached score is frozen during the run, so the runner-up comparison is
    # exact.  Decision-identical to querying per pod (parity-tested).
    wave_run_length = False

    def suitable_nodes(self, cluster: Cluster, pod: Pod) -> List[Node]:
        """getAllSuitableNodes(p): feasible READY nodes, else TAINTED ones."""
        ready = [n for n in cluster.ready_nodes() if n.fits(pod.requests)]
        if ready:
            return ready
        # Last resort: tainted nodes (paper: "unless strictly necessary").
        return [n for n in cluster.tainted_nodes() if n.fits(pod.requests)]

    @abc.abstractmethod
    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        """Pick the target node among feasible candidates."""

    def schedule(self, cluster: Cluster, pod: Pod, now: float) -> bool:
        """Paper Alg. 2 skeleton. Returns True iff a binding was created."""
        if cluster.arrays is not None and self.select_slot is not None:
            return self._schedule_arrays(cluster, pod, now)
        nodes = self.suitable_nodes(cluster, pod)
        node = self.select(nodes, pod) if nodes else None
        if node is None:
            return False
        cluster.bind(pod, node, now)
        return True

    # -- array engine ---------------------------------------------------------
    def _schedule_arrays(self, cluster: Cluster, pod: Pod, now: float) -> bool:
        arr = cluster.arrays
        if arr.n_slots == 0:
            return False
        req = pod.requests
        free_cpu, free_mem = arr.free_views()
        # Same feasibility ops as Resources.fits_in, elementwise.
        fits = (free_cpu >= req.cpu_m) & ((free_mem + 1e-9) >= req.mem_mb)
        state = arr.live("state")
        mask = fits & arr.live("active") & (state == _engine.STATE_READY)
        if not mask.any():
            mask = fits & arr.live("active") & (state == _engine.STATE_TAINTED)
            if not mask.any():
                return False
        slot = self.select_slot(arr, mask, free_cpu, free_mem, pod)
        if slot < 0:
            return False
        cluster.bind(pod, cluster.node_by_slot(slot), now)
        return True

    # -- wave placement (vectorized multi-pod array engine) --------------------
    def wave_scores(self, placer, req, sl=slice(None)) -> Optional[np.ndarray]:
        """Policy score vector over ``placer``'s working frees, or None.

        ``sl`` restricts the computation to a slice of ranks: ``select_wave``
        passes a single-rank slice to refresh a cached score buffer after a
        placement (NumPy ops on a length-1 view are the same IEEE-754 ops as
        the full-vector elementwise computation, so the refreshed entry is
        bit-identical to a recompute).  May return a *view* of a placer
        column (e.g. ``free_mem``).
        """
        return None

    def wave_score_at(self, placer, req, r: int):
        """Scalar policy score at rank ``r`` — the per-bind cache refresh.

        Default falls back to a length-1 ``wave_scores`` slice; policies
        whose score is a direct column read (best-fit / worst-fit) or a
        scalar formula (k8s-default) override it to skip the vector-slice
        machinery.  Must apply the same IEEE-754 double ops as the
        elementwise vector computation so the refreshed entry stays
        bit-identical to a full recompute."""
        return self.wave_scores(placer, req, slice(r, r + 1))[0]

    def select_wave(self, placer, pods: List[Pod],
                    start: int = 0) -> Tuple[list, Optional[int]]:
        """Place ``pods[start:]`` in order against the placer's working state.

        The wave engine of ``Orchestrator.cycle``: pods are considered in
        snapshot (FIFO) order; each placement is recorded in the placer's
        working arrays so later pods of the wave observe it, but **no object
        state is touched** — the caller commits the returned prefix with
        ``Cluster.bind_wave``.

        Returns ``(bindings, blocked)``: ``bindings`` is the placed prefix as
        ``(pod, slot)`` pairs, and ``blocked`` is the index (into ``pods``)
        of the first pod with no feasible node — or ``None`` when the whole
        remainder was placed.  The orchestrator then runs the paper's
        rescheduling/scale-out path for the blocked pod and resumes the wave
        after it.

        Selection per pod is one extremum query over a per-request-size
        score buffer: the buffer holds the policy score where the node is
        READY and feasible and ±inf elsewhere, lives in node-id rank order
        (so the first extremum *is* the lowest-node_id tie-break), is
        memoized in ``placer.cache``, and is refreshed only at the just-bound
        rank after each placement — O(1) amortized filter+score work per pod
        for repeated request sizes.  The extremum itself runs on one of two
        kernels (``engine.wave_select_default`` / ``ExperimentSpec``):

        * **flat** — one C-speed O(nodes) ``argmin``/``argmax`` per pod;
        * **segment tree** — an :class:`repro.core.engine.SegExtTree` per
          cached buffer answers the first-extremum query in O(log nodes)
          and absorbs the per-bind refresh as an O(log nodes) point update.

        Both kernels return the identical rank (same extremum, same
        first-index tie-break), so decisions are bit-identical to each other
        and to iterating ``select_slot`` pod by pod (see the module
        docstring).
        """
        bindings: List[Tuple[Pod, int]] = []
        cache = placer.cache
        cache_list = placer.cache_list
        mode = self.wave_mode
        mode_min = mode == "min"
        fill = np.inf if mode_min else -np.inf
        slot_of_rank = placer.slot_of_rank_list
        use_tree = placer.use_tree
        ready = placer.ready
        free_cpu, free_mem = placer.free_cpu, placer.free_mem
        used_cpu, used_mem = placer.used_cpu, placer.used_mem
        alloc_cpu, alloc_mem = placer.alloc_cpu, placer.alloc_mem
        pending = PodPhase.PENDING
        score_at = self.wave_score_at
        for i in range(start, len(pods)):
            pod = pods[i]
            if pod.phase is not pending:
                continue   # a binding rescheduler may have placed it already
            if placer.n == 0:
                return bindings, i
            req = pod.requests
            key = (req.cpu_m, req.mem_mb)
            ent = cache.get(key)
            if ent is None:
                # Same feasibility ops as Resources.fits_in, elementwise.
                fits = (free_cpu >= req.cpu_m) & (
                    (free_mem + 1e-9) >= req.mem_mb)
                mask = fits & ready
                if mode is None:
                    buf = mask          # argmax(bool) == first feasible rank
                else:
                    buf = np.where(mask, self.wave_scores(placer, req), fill)
                if not use_tree:
                    tree = None
                elif mode is None:
                    # Boolean mask as a 'max' tree with -inf infeasible
                    # entries: first rank attaining 1.0 == first feasible,
                    # all-(-inf) root == no feasible rank.
                    tree = _engine.SegExtTree(
                        np.where(mask, 1.0, -np.inf), False)
                else:
                    tree = _engine.SegExtTree(buf, mode_min)
                ent = (fits, mask, buf, req, tree, key[0], key[1])
                cache[key] = ent
                cache_list.append(ent)
            fits, mask, buf, _, tree, _, _ = ent
            if tree is None:
                r = int(buf.argmin() if mode_min else buf.argmax())
                feasible = mask[r] if mode is None else buf[r] != fill
            else:
                r = tree.argext()
                feasible = r >= 0
            if not feasible:
                # No READY node fits.  Last resort: tainted nodes (paper:
                # "unless strictly necessary") — same fallback as per-pod.
                r = self._select_wave_tainted(placer, fits, req)
                if r < 0:
                    return bindings, i
            bindings.append((pod, slot_of_rank[r]))
            # Inlined placer.bind(r, req): same `+=` / `alloc - used` float
            # ops as the object accounting, so the rest of the wave sees
            # bit-identical frees.
            used_cpu[r] += req.cpu_m
            used_mem[r] += req.mem_mb
            free_cpu[r] = alloc_cpu[r] - used_cpu[r]
            free_mem[r] = alloc_mem[r] - used_mem[r]
            # Only the bound rank's feasibility/score changed: refresh that
            # one entry in every cached buffer.  Scalar extraction is exact
            # (int64/float64 round-trip verbatim), and Python int/float
            # comparisons and the `+ 1e-9` are the identical IEEE doubles
            # the elementwise vector ops compute.
            fc = int(free_cpu[r])
            fm_eps = float(free_mem[r]) + 1e-9
            ready_r = bool(ready[r])
            for f2, m2, b2, r2, t2, cpu_m, mem_mb in cache_list:
                ok = fc >= cpu_m and fm_eps >= mem_mb
                f2[r] = ok
                ok = ok and ready_r
                m2[r] = ok
                if mode is not None:
                    v = score_at(placer, r2, r) if ok else fill
                    b2[r] = v
                    if t2 is not None:
                        t2.update(r, v)
                elif t2 is not None:   # buf is the mask itself (1/-inf tree)
                    t2.update(r, 1.0 if ok else -np.inf)
        return bindings, None

    def _select_wave_tainted(self, placer, fits, req) -> int:
        """Tainted-node fallback of the wave filter: rank of the policy's
        pick among feasible TAINTED nodes, or -1.  Cold path — only reached
        when no READY node fits — so nothing is cached."""
        mask = fits & placer.tainted
        if not mask.any():
            return -1
        if self.wave_mode is None:
            return int(mask.argmax())
        fill = np.inf if self.wave_mode == "min" else -np.inf
        buf = np.where(mask, self.wave_scores(placer, req), fill)
        return int(buf.argmin() if self.wave_mode == "min" else buf.argmax())

    def select_wave_store(self, placer, store, rows,
                          start: int = 0) -> Tuple[list, Optional[int]]:
        """Row-native :meth:`select_wave`: place ``rows[start:]`` of a
        :class:`repro.core.engine.PodStore` against the placer.

        The store-path wave engine of ``Orchestrator.cycle``.  Identical
        decision procedure to :meth:`select_wave` — same cached ±inf-masked
        score buffers, same per-bind float ops, same refresh, same
        tie-breaks — except pod phase and request sizes are read from the
        SoA columns instead of ``Pod`` attributes, and no object is ever
        touched.  Returns ``(bindings, blocked)`` where ``bindings`` is the
        placed prefix as ``(row, slot)`` pairs.

        **Run-length fast path** (``wave_run_length`` policies, best-fit):
        one extremum query is amortized over a run of consecutive same-size
        pods.  After placing a pod at rank ``r``, the runner-up ``(v2, r2)``
        — the first extremum with ``r`` masked out — is computed once; while
        successive pods carry the same request key, the next extremum is
        decidable from two scalars, because only ``buf[r]`` has changed:
        the per-pod query collapses to *stay at r iff
        ``(buf[r], r) < (v2, r2)`` lexicographically and r still fits*.  The
        moment ``r`` goes infeasible or loses to the runner-up the loop
        falls back to a full query.  Accounting floats still advance one pod
        at a time in bind order (``+=`` / ``alloc − used``), so the working
        frees — and therefore every subsequent decision — are bit-identical
        to the per-pod query path; cache refreshes for the run's rank are
        flushed before the next query reads any buffer (refreshes are pure
        functions of the current working frees, so one flush equals the
        per-bind refreshes it replaces).  ``REPRO_WAVE_RUNLEN=0`` forces the
        per-pod query path for A/B parity testing.
        """
        bindings: List[Tuple[int, int]] = []
        cache = placer.cache
        cache_list = placer.cache_list
        mode = self.wave_mode
        mode_min = mode == "min"
        fill = np.inf if mode_min else -np.inf
        slot_of_rank = placer.slot_of_rank_list
        use_tree = placer.use_tree
        ready = placer.ready
        free_cpu, free_mem = placer.free_cpu, placer.free_mem
        used_cpu, used_mem = placer.used_cpu, placer.used_mem
        alloc_cpu, alloc_mem = placer.alloc_cpu, placer.alloc_mem
        phase_col = store.phase
        cpu_col = store.cpu_m
        mem_col = store.mem_mb
        pending = _engine.POD_PENDING
        score_at = self.wave_score_at
        run_len = (self.wave_run_length and mode_min
                   and _engine.wave_runlen_enabled())

        def refresh(r):
            # Only rank r's feasibility/score changed: refresh that one
            # entry in every cached buffer.  Scalar extraction is exact
            # (int64/float64 round-trip verbatim), and Python int/float
            # comparisons and the `+ 1e-9` are the identical IEEE doubles
            # the elementwise vector ops compute.
            fc = int(free_cpu[r])
            fm_eps = float(free_mem[r]) + 1e-9
            ready_r = bool(ready[r])
            for f2, m2, b2, r2, t2, c2, m_mb2 in cache_list:
                ok = fc >= c2 and fm_eps >= m_mb2
                f2[r] = ok
                ok = ok and ready_r
                m2[r] = ok
                if mode is not None:
                    v = score_at(placer, r2, r) if ok else fill
                    b2[r] = v
                    if t2 is not None:
                        t2.update(r, v)
                elif t2 is not None:   # buf is the mask itself (1/-inf tree)
                    t2.update(r, 1.0 if ok else -np.inf)

        blocked_keys = placer.blocked_keys
        i = start
        n = len(rows)
        while i < n:
            row = rows[i]
            if phase_col[row] != pending:
                i += 1
                continue   # a binding rescheduler may have placed it already
            if placer.n == 0:
                return bindings, i
            cpu_m = cpu_col[row]
            mem_mb = mem_col[row]
            key = (cpu_m, mem_mb)
            if key in blocked_keys:
                return bindings, i   # latched infeasible (frees only shrink)
            ent = cache.get(key)
            if ent is None:
                req = Resources(cpu_m, mem_mb)
                # Same feasibility ops as Resources.fits_in, elementwise.
                fits = (free_cpu >= cpu_m) & ((free_mem + 1e-9) >= mem_mb)
                mask = fits & ready
                if mode is None:
                    buf = mask          # argmax(bool) == first feasible rank
                else:
                    buf = np.where(mask, self.wave_scores(placer, req), fill)
                if not use_tree:
                    tree = None
                elif mode is None:
                    tree = _engine.SegExtTree(
                        np.where(mask, 1.0, -np.inf), False)
                else:
                    tree = _engine.SegExtTree(buf, mode_min)
                ent = (fits, mask, buf, req, tree, cpu_m, mem_mb)
                cache[key] = ent
                cache_list.append(ent)
            fits, mask, buf, req, tree, _, _ = ent
            if tree is None:
                r = int(buf.argmin() if mode_min else buf.argmax())
                feasible = mask[r] if mode is None else buf[r] != fill
            else:
                r = tree.argext()
                feasible = r >= 0
            if not feasible:
                # No READY node fits.  Last resort: tainted nodes (paper:
                # "unless strictly necessary") — same fallback as per-pod.
                r = self._select_wave_tainted(placer, fits, req)
                if r < 0:
                    blocked_keys.add(key)
                    return bindings, i
            bindings.append((row, slot_of_rank[r]))
            # Same `+=` / `alloc - used` float ops as the object accounting,
            # so the rest of the wave sees bit-identical frees.
            used_cpu[r] += cpu_m
            used_mem[r] += mem_mb
            free_cpu[r] = alloc_cpu[r] - used_cpu[r]
            free_mem[r] = alloc_mem[r] - used_mem[r]
            # Inlined refresh(r) — the per-bind hot path skips the call.
            fc = int(free_cpu[r])
            fm_eps = float(free_mem[r]) + 1e-9
            rdy = bool(ready[r])
            for f2, m2, b2, r2_, t2, c2, m_mb2 in cache_list:
                ok = fc >= c2 and fm_eps >= m_mb2
                f2[r] = ok
                ok = ok and rdy
                m2[r] = ok
                if mode is not None:
                    v = score_at(placer, r2_, r) if ok else fill
                    b2[r] = v
                    if t2 is not None:
                        t2.update(r, v)
                elif t2 is not None:
                    t2.update(r, 1.0 if ok else -np.inf)
            i += 1
            # Run-length continuation must pay for itself: the runner-up
            # query is one extra extremum pass, and a run of exactly two
            # breaks even (one saved query, one paid) — so peek *two* rows
            # ahead and only arm the fast path for runs of three or more.
            if (not run_len or not feasible or i + 1 >= n
                    or cpu_col[rows[i]] != cpu_m
                    or mem_col[rows[i]] != mem_mb
                    or cpu_col[rows[i + 1]] != cpu_m
                    or mem_col[rows[i + 1]] != mem_mb):
                continue   # (feasible False => tainted fallback bind: no run)
            # -- run-length continuation at rank r -----------------------------
            if tree is None:
                old = buf[r]
                buf[r] = fill
                r2 = int(buf.argmin())
                v2 = buf[r2]
                buf[r] = old
            else:
                old = buf[r]
                tree.update(r, fill)
                r2 = tree.argext()
                tree.update(r, old)
                v2 = buf[r2] if r2 >= 0 else fill
                if r2 < 0:
                    r2 = placer.n   # sentinel: no competitor, v2 == fill
            ready_r = bool(ready[r])
            dirty = False
            while i < n:
                row2 = rows[i]
                if phase_col[row2] != pending:
                    i += 1
                    continue
                if cpu_col[row2] != cpu_m or mem_col[row2] != mem_mb:
                    break   # run over: next pod has a different request key
                # Identical scalar feasibility ops as refresh()/fits_in.
                if not (ready_r and int(free_cpu[r]) >= cpu_m
                        and float(free_mem[r]) + 1e-9 >= mem_mb):
                    break   # r no longer fits: full re-query needed
                v = score_at(placer, req, r)
                if v > v2 or (v == v2 and r2 < r):
                    break   # the frozen runner-up now wins the extremum
                bindings.append((row2, slot_of_rank[r]))
                used_cpu[r] += cpu_m
                used_mem[r] += mem_mb
                free_cpu[r] = alloc_cpu[r] - used_cpu[r]
                free_mem[r] = alloc_mem[r] - used_mem[r]
                dirty = True
                i += 1
            if dirty:
                refresh(r)   # flush the run's deferred per-bind refreshes
        return bindings, None


class BestFitBinPackingScheduler(Scheduler):
    """Paper Alg. 2 — online best-fit bin packing.

    Filter nodes with enough free CPU (compressible), then among those that
    also fit the memory request pick the one with the **least** free memory:
    the fullest bin that still accommodates the item.  Memory is the best-fit
    key because it is the non-compressible axis (§6.1).
    """

    name = "best-fit"
    wave_mode = "min"
    # Binding at rank r strictly decreases free_mem[r] while all other ranks
    # are frozen, so a run of same-size pods piles onto r until it fills or
    # ties against the runner-up — the premise of the run-length fast path.
    wave_run_length = True

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None
        # Deterministic tie-break on node_id.
        return min(nodes, key=lambda n: (n.free.mem_mb, n.node_id))

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        best = free_mem[mask].min()
        return arr.first_by_id(mask & (free_mem == best))

    def wave_scores(self, placer, req, sl=slice(None)):
        # A view into the working frees: the cached score buffer is still a
        # masked *copy* (np.where) that select_wave must refresh per bind —
        # the view only makes that single-element refresh read for free.
        return placer.free_mem[sl]

    def wave_score_at(self, placer, req, r: int):
        return placer.free_mem[r]


def _k8s_scores(free_cpu, free_mem, alloc_cpu, alloc_mem, req):
    """LeastRequestedPriority + BalancedResourceAllocation, equally weighted.

    Shared by both engines: the object path feeds scalars, the array path
    feeds vectors; NumPy elementwise ops are the same IEEE-754 double ops, so
    the scores are bit-identical.
    """
    cpu_frac = (free_cpu - req.cpu_m) / np.maximum(alloc_cpu, 1)
    mem_frac = (free_mem - req.mem_mb) / np.maximum(alloc_mem, 1e-9)
    least_requested = 10.0 * (cpu_frac + mem_frac) / 2.0
    balanced = 10.0 * (1.0 - np.abs(cpu_frac - mem_frac))
    return (least_requested + balanced) / 2.0


class KubernetesDefaultScheduler(Scheduler):
    """The Fig. 4 baseline: default kube-scheduler scoring (v1.10 era).

    LeastRequestedPriority + BalancedResourceAllocation, equally weighted —
    a *spread* strategy that favours the least-loaded node, the opposite of
    bin packing.  Run on a fixed-size static cluster in the baseline.
    """

    name = "k8s-default"
    wave_mode = "max"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None

        def score(n: Node) -> float:
            free = n.free
            cap = n.allocatable
            return float(_k8s_scores(free.cpu_m, free.mem_mb,
                                     cap.cpu_m, cap.mem_mb, pod.requests))

        scored = [(score(n), n) for n in nodes]
        best = max(s for s, _ in scored)
        return _lowest_id([n for s, n in scored if s == best])

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        scores = _k8s_scores(free_cpu, free_mem, arr.live("alloc_cpu"),
                             arr.live("alloc_mem"), pod.requests)
        best = scores[mask].max()
        return arr.first_by_id(mask & (scores == best))

    def wave_scores(self, placer, req, sl=slice(None)):
        return _k8s_scores(placer.free_cpu[sl], placer.free_mem[sl],
                           placer.alloc_cpu[sl], placer.alloc_mem[sl], req)

    def wave_score_at(self, placer, req, r: int):
        # NumPy scalar ops are the same IEEE-754 doubles as the elementwise
        # vector computation — bit-identical to a length-1 slice.
        return _k8s_scores(placer.free_cpu[r], placer.free_mem[r],
                           placer.alloc_cpu[r], placer.alloc_mem[r], req)


def _weighted_scores(free_cpu, free_mem, alloc_cpu, alloc_mem, req,
                     w_pack, w_lr, w_bal):
    """Parameterized scoring: packing + LeastRequested + Balanced, weighted.

    The policy-search scoring surface (repro.search): ``w_pack`` pulls
    toward bin packing (fullest-after-placement node wins — best-fit's
    regime), ``w_lr`` toward spreading (least-requested — k8s-default's
    regime) and ``w_bal`` toward cpu/mem balance.  Shared by both engines
    exactly like ``_k8s_scores``: scalars on the object path, vectors on
    the array path, same IEEE-754 double ops either way, so scores are
    bit-identical across engines.
    """
    cpu_frac = (free_cpu - req.cpu_m) / np.maximum(alloc_cpu, 1)
    mem_frac = (free_mem - req.mem_mb) / np.maximum(alloc_mem, 1e-9)
    # Packing keys on memory alone — best-fit's non-compressible axis
    # (§6.1) — so it is not an affine shadow of LeastRequested (which
    # averages both axes): the three weights span genuinely different
    # orderings.
    pack = 10.0 * (1.0 - mem_frac)
    least_requested = 10.0 * (cpu_frac + mem_frac) / 2.0
    balanced = 10.0 * (1.0 - np.abs(cpu_frac - mem_frac))
    return w_pack * pack + w_lr * least_requested + w_bal * balanced


class WeightedScoringScheduler(Scheduler):
    """Tunable-weight scheduler — the policy-search scoring knob.

    A continuous family that contains both ends of the paper's Fig.-4
    comparison: ``(1, 0, 0)`` is ordering-equivalent to best-fit bin
    packing on a homogeneous fleet (max packing == min free memory after
    placement) and ``(0, 1, 1)`` is ordering-equivalent to the k8s-default
    LeastRequested+Balanced blend (same sum, scaled by 2).
    ``repro.search`` optimizes the three weights against the
    cost/pending/utilization front.
    """

    name = "weighted"
    wave_mode = "max"

    def __init__(self, w_pack: float = 1.0, w_lr: float = 0.0,
                 w_bal: float = 0.0):
        total = w_pack + w_lr + w_bal
        if not (total > 0.0):     # also rejects NaN
            raise ValueError(
                f"weighted scheduler needs w_pack + w_lr + w_bal > 0, got "
                f"({w_pack}, {w_lr}, {w_bal})")
        if min(w_pack, w_lr, w_bal) < 0.0:
            raise ValueError(f"weights must be non-negative, got "
                             f"({w_pack}, {w_lr}, {w_bal})")
        self.weights = (float(w_pack), float(w_lr), float(w_bal))

    def _scores(self, free_cpu, free_mem, alloc_cpu, alloc_mem, req):
        w_pack, w_lr, w_bal = self.weights
        return _weighted_scores(free_cpu, free_mem, alloc_cpu, alloc_mem,
                                req, w_pack, w_lr, w_bal)

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None

        def score(n: Node) -> float:
            free = n.free
            cap = n.allocatable
            return float(self._scores(free.cpu_m, free.mem_mb,
                                      cap.cpu_m, cap.mem_mb, pod.requests))

        scored = [(score(n), n) for n in nodes]
        best = max(s for s, _ in scored)
        return _lowest_id([n for s, n in scored if s == best])

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        scores = self._scores(free_cpu, free_mem, arr.live("alloc_cpu"),
                              arr.live("alloc_mem"), pod.requests)
        best = scores[mask].max()
        return arr.first_by_id(mask & (scores == best))

    def wave_scores(self, placer, req, sl=slice(None)):
        return self._scores(placer.free_cpu[sl], placer.free_mem[sl],
                            placer.alloc_cpu[sl], placer.alloc_mem[sl], req)

    def wave_score_at(self, placer, req, r: int):
        # NumPy scalar ops are the same IEEE-754 doubles as the elementwise
        # vector computation — bit-identical to a length-1 slice.
        return self._scores(placer.free_cpu[r], placer.free_mem[r],
                            placer.alloc_cpu[r], placer.alloc_mem[r], req)


class FirstFitScheduler(Scheduler):
    """Ablation baseline: first feasible node in id order (classic FF)."""

    name = "first-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        return _lowest_id(nodes) if nodes else None

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        return arr.first_by_id(mask)


class WorstFitScheduler(Scheduler):
    """Ablation baseline: emptiest feasible node (Docker Swarm 'spread')."""

    name = "worst-fit"
    wave_mode = "max"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None
        best = max(n.free.mem_mb for n in nodes)
        return _lowest_id([n for n in nodes if n.free.mem_mb == best])

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        best = free_mem[mask].max()
        return arr.first_by_id(mask & (free_mem == best))

    def wave_scores(self, placer, req, sl=slice(None)):
        return placer.free_mem[sl]

    def wave_score_at(self, placer, req, r: int):
        return placer.free_mem[r]


SCHEDULERS = {
    cls.name: cls
    for cls in (BestFitBinPackingScheduler, KubernetesDefaultScheduler,
                FirstFitScheduler, WorstFitScheduler,
                WeightedScoringScheduler)
}
