"""Schedulers (paper §6.1 + the default-K8s baseline used in Fig. 4).

All schedulers implement the same two-stage shape Kubernetes uses:
*filter* (feasibility) then *select* (scoring).  The paper's contribution is
the selection rule; filtering is request-based feasibility on both axes.

Tainted nodes (Alg. 6 step 3) are used **only as a last resort**: the filter
first considers READY nodes and falls back to TAINTED nodes only when no
untainted node fits.
"""
from __future__ import annotations

import abc
from typing import List, Optional

from repro.core.cluster import Cluster, Node
from repro.core.pods import Pod


class Scheduler(abc.ABC):
    """Base scheduler: filter feasible nodes, pick one, create the binding."""

    name = "scheduler"

    def suitable_nodes(self, cluster: Cluster, pod: Pod) -> List[Node]:
        """getAllSuitableNodes(p): feasible READY nodes, else TAINTED ones."""
        ready = [n for n in cluster.ready_nodes() if n.fits(pod.requests)]
        if ready:
            return ready
        # Last resort: tainted nodes (paper: "unless strictly necessary").
        return [n for n in cluster.tainted_nodes() if n.fits(pod.requests)]

    @abc.abstractmethod
    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        """Pick the target node among feasible candidates."""

    def schedule(self, cluster: Cluster, pod: Pod, now: float) -> bool:
        """Paper Alg. 2 skeleton. Returns True iff a binding was created."""
        nodes = self.suitable_nodes(cluster, pod)
        node = self.select(nodes, pod) if nodes else None
        if node is None:
            return False
        cluster.bind(pod, node, now)
        return True


class BestFitBinPackingScheduler(Scheduler):
    """Paper Alg. 2 — online best-fit bin packing.

    Filter nodes with enough free CPU (compressible), then among those that
    also fit the memory request pick the one with the **least** free memory:
    the fullest bin that still accommodates the item.  Memory is the best-fit
    key because it is the non-compressible axis (§6.1).
    """

    name = "best-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None
        # Deterministic tie-break on node_id.
        return min(nodes, key=lambda n: (n.free.mem_mb, n.node_id))


class KubernetesDefaultScheduler(Scheduler):
    """The Fig. 4 baseline: default kube-scheduler scoring (v1.10 era).

    LeastRequestedPriority + BalancedResourceAllocation, equally weighted —
    a *spread* strategy that favours the least-loaded node, the opposite of
    bin packing.  Run on a fixed-size static cluster in the baseline.
    """

    name = "k8s-default"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None

        def score(n: Node) -> float:
            free = n.free - pod.requests
            cap = n.allocatable
            cpu_frac = free.cpu_m / max(cap.cpu_m, 1)
            mem_frac = free.mem_mb / max(cap.mem_mb, 1e-9)
            least_requested = 10.0 * (cpu_frac + mem_frac) / 2.0
            balanced = 10.0 * (1.0 - abs(cpu_frac - mem_frac))
            return (least_requested + balanced) / 2.0

        return max(nodes, key=lambda n: (score(n), n.node_id))


class FirstFitScheduler(Scheduler):
    """Ablation baseline: first feasible node in id order (classic FF)."""

    name = "first-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        return min(nodes, key=lambda n: n.node_id) if nodes else None


class WorstFitScheduler(Scheduler):
    """Ablation baseline: emptiest feasible node (Docker Swarm 'spread')."""

    name = "worst-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None
        return max(nodes, key=lambda n: (n.free.mem_mb, n.node_id))


SCHEDULERS = {
    cls.name: cls
    for cls in (BestFitBinPackingScheduler, KubernetesDefaultScheduler,
                FirstFitScheduler, WorstFitScheduler)
}
