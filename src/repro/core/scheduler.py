"""Schedulers (paper §6.1 + the default-K8s baseline used in Fig. 4).

All schedulers implement the same two-stage shape Kubernetes uses:
*filter* (feasibility) then *select* (scoring).  The paper's contribution is
the selection rule; filtering is request-based feasibility on both axes.

Tainted nodes (Alg. 6 step 3) are used **only as a last resort**: the filter
first considers READY nodes and falls back to TAINTED nodes only when no
untainted node fits.

Two execution engines share each policy:

* the **object path** (seed engine) — list comprehensions over ``Node``
  objects, kept for parity testing and as the fallback when the cluster has
  no SoA mirror;
* the **array path** — filter+select as masked NumPy reductions over the
  cluster's :class:`repro.core.engine.ClusterArrays` mirror.  Identical
  floats, identical IEEE ops, identical tie-breaks => identical bindings.

Tie-breaks are uniform across all four policies: among equally-scored
feasible nodes the **lexicographically lowest node_id wins**.
"""
from __future__ import annotations

import abc
from typing import List, Optional

import numpy as np

from repro.core import engine as _engine
from repro.core.cluster import Cluster, Node
from repro.core.pods import Pod


def _lowest_id(nodes: List[Node]) -> Node:
    return min(nodes, key=lambda n: n.node_id)


class Scheduler(abc.ABC):
    """Base scheduler: filter feasible nodes, pick one, create the binding."""

    name = "scheduler"

    # Concrete policies override with a vectorized (arrays, mask, free_cpu,
    # free_mem, pod) -> slot implementation; None disables the array path.
    select_slot = None

    def suitable_nodes(self, cluster: Cluster, pod: Pod) -> List[Node]:
        """getAllSuitableNodes(p): feasible READY nodes, else TAINTED ones."""
        ready = [n for n in cluster.ready_nodes() if n.fits(pod.requests)]
        if ready:
            return ready
        # Last resort: tainted nodes (paper: "unless strictly necessary").
        return [n for n in cluster.tainted_nodes() if n.fits(pod.requests)]

    @abc.abstractmethod
    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        """Pick the target node among feasible candidates."""

    def schedule(self, cluster: Cluster, pod: Pod, now: float) -> bool:
        """Paper Alg. 2 skeleton. Returns True iff a binding was created."""
        if cluster.arrays is not None and self.select_slot is not None:
            return self._schedule_arrays(cluster, pod, now)
        nodes = self.suitable_nodes(cluster, pod)
        node = self.select(nodes, pod) if nodes else None
        if node is None:
            return False
        cluster.bind(pod, node, now)
        return True

    # -- array engine ---------------------------------------------------------
    def _schedule_arrays(self, cluster: Cluster, pod: Pod, now: float) -> bool:
        arr = cluster.arrays
        if arr.n_slots == 0:
            return False
        req = pod.requests
        free_cpu, free_mem = arr.free_views()
        # Same feasibility ops as Resources.fits_in, elementwise.
        fits = (free_cpu >= req.cpu_m) & ((free_mem + 1e-9) >= req.mem_mb)
        state = arr.live("state")
        mask = fits & arr.live("active") & (state == _engine.STATE_READY)
        if not mask.any():
            mask = fits & arr.live("active") & (state == _engine.STATE_TAINTED)
            if not mask.any():
                return False
        slot = self.select_slot(arr, mask, free_cpu, free_mem, pod)
        if slot < 0:
            return False
        cluster.bind(pod, cluster.node_by_slot(slot), now)
        return True


class BestFitBinPackingScheduler(Scheduler):
    """Paper Alg. 2 — online best-fit bin packing.

    Filter nodes with enough free CPU (compressible), then among those that
    also fit the memory request pick the one with the **least** free memory:
    the fullest bin that still accommodates the item.  Memory is the best-fit
    key because it is the non-compressible axis (§6.1).
    """

    name = "best-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None
        # Deterministic tie-break on node_id.
        return min(nodes, key=lambda n: (n.free.mem_mb, n.node_id))

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        best = free_mem[mask].min()
        return arr.first_by_id(mask & (free_mem == best))


def _k8s_scores(free_cpu, free_mem, alloc_cpu, alloc_mem, req):
    """LeastRequestedPriority + BalancedResourceAllocation, equally weighted.

    Shared by both engines: the object path feeds scalars, the array path
    feeds vectors; NumPy elementwise ops are the same IEEE-754 double ops, so
    the scores are bit-identical.
    """
    cpu_frac = (free_cpu - req.cpu_m) / np.maximum(alloc_cpu, 1)
    mem_frac = (free_mem - req.mem_mb) / np.maximum(alloc_mem, 1e-9)
    least_requested = 10.0 * (cpu_frac + mem_frac) / 2.0
    balanced = 10.0 * (1.0 - np.abs(cpu_frac - mem_frac))
    return (least_requested + balanced) / 2.0


class KubernetesDefaultScheduler(Scheduler):
    """The Fig. 4 baseline: default kube-scheduler scoring (v1.10 era).

    LeastRequestedPriority + BalancedResourceAllocation, equally weighted —
    a *spread* strategy that favours the least-loaded node, the opposite of
    bin packing.  Run on a fixed-size static cluster in the baseline.
    """

    name = "k8s-default"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None

        def score(n: Node) -> float:
            free = n.free
            cap = n.allocatable
            return float(_k8s_scores(free.cpu_m, free.mem_mb,
                                     cap.cpu_m, cap.mem_mb, pod.requests))

        scored = [(score(n), n) for n in nodes]
        best = max(s for s, _ in scored)
        return _lowest_id([n for s, n in scored if s == best])

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        scores = _k8s_scores(free_cpu, free_mem, arr.live("alloc_cpu"),
                             arr.live("alloc_mem"), pod.requests)
        best = scores[mask].max()
        return arr.first_by_id(mask & (scores == best))


class FirstFitScheduler(Scheduler):
    """Ablation baseline: first feasible node in id order (classic FF)."""

    name = "first-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        return _lowest_id(nodes) if nodes else None

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        return arr.first_by_id(mask)


class WorstFitScheduler(Scheduler):
    """Ablation baseline: emptiest feasible node (Docker Swarm 'spread')."""

    name = "worst-fit"

    def select(self, nodes: List[Node], pod: Pod) -> Optional[Node]:
        if not nodes:
            return None
        best = max(n.free.mem_mb for n in nodes)
        return _lowest_id([n for n in nodes if n.free.mem_mb == best])

    def select_slot(self, arr, mask, free_cpu, free_mem, pod) -> int:
        best = free_mem[mask].max()
        return arr.first_by_id(mask & (free_mem == best))


SCHEDULERS = {
    cls.name: cls
    for cls in (BestFitBinPackingScheduler, KubernetesDefaultScheduler,
                FirstFitScheduler, WorstFitScheduler)
}
