"""Pod / job object model (paper §3, §5.1).

A *pod* is the schedulable unit.  The paper distinguishes:

* **services** — long-running, latency-sensitive (K8s ``Deployment``), may be
  labelled ``rescheduling: moveable``;
* **batch jobs** — run-to-completion (K8s ``Job``), labelled ``type: batch``,
  never moveable.

In the TPU-fleet adaptation a service pod is a serving deployment and a batch
pod is a training job; *moveable* means *checkpointable* (the eviction →
recreate cycle becomes checkpoint → restore, see ``repro.train.checkpoint``).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

from repro.core.resources import Resources


class PodKind(enum.Enum):
    SERVICE = "service"   # long-running (K8s Deployment / serving job)
    BATCH = "batch"       # run-to-completion (K8s Job / training job)


class PodPhase(enum.Enum):
    PENDING = "pending"       # in the scheduling queue
    BOUND = "bound"           # binding created; starts running at bind time
    SUCCEEDED = "succeeded"   # batch only: ran to completion
    EVICTED = "evicted"       # shut down for rescheduling; will be recreated
    FAILED = "failed"         # node failure killed it; will be recreated


_uid = itertools.count()


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """Immutable template for a pod (the YAML of Fig. 3/4 in the paper)."""

    type_name: str                 # e.g. "batch_small", "service_med"
    kind: PodKind
    requests: Resources            # requests == limits (guaranteed QoS class)
    duration_s: float = 0.0        # batch only: nominal runtime
    moveable: bool = False         # services only (label rescheduling:moveable)
    # Fleet extension: moveable batch jobs are checkpointable training jobs.
    checkpointable: bool = False
    checkpoint_interval_s: float = 0.0
    scheduler_name: str = "customScheduler"

    def __post_init__(self):
        if self.kind == PodKind.BATCH and self.moveable:
            raise ValueError("paper §5.1: batch jobs cannot be moveable")


@dataclasses.dataclass
class Pod:
    """A live pod instance.

    A pod evicted by the rescheduler/autoscaler is *recreated*: in Kubernetes
    the deployment controller spawns a fresh pod for the same template.  We
    model that by resetting the instance back to PENDING with a fresh
    ``pending_since`` and an incremented ``incarnation`` — identity (``uid``)
    is stable across incarnations so metrics can track the logical task.
    """

    spec: PodSpec
    submit_time: float
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))
    phase: PodPhase = PodPhase.PENDING
    node_id: Optional[str] = None
    pending_since: float = 0.0       # start of the *current* pending interval
    bound_time: Optional[float] = None
    finish_time: Optional[float] = None
    incarnation: int = 0
    progress_s: float = 0.0          # batch: completed work (checkpoint restore)
    checkpointed_s: float = 0.0      # batch: durable progress at last checkpoint
    pending_intervals: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.pending_since = self.submit_time

    # -- convenience ---------------------------------------------------------
    @property
    def name(self) -> str:
        return f"{self.spec.type_name}-{self.uid}"

    @property
    def requests(self) -> Resources:
        return self.spec.requests

    @property
    def is_batch(self) -> bool:
        return self.spec.kind == PodKind.BATCH

    @property
    def is_service(self) -> bool:
        return self.spec.kind == PodKind.SERVICE

    @property
    def moveable(self) -> bool:
        return self.spec.moveable

    def age(self, now: float) -> float:
        """Time spent in the current pending interval (rescheduler gate)."""
        return now - self.pending_since

    def remaining_s(self, now: float) -> float:
        """Batch only: work left, given progress at the current binding."""
        assert self.is_batch and self.bound_time is not None
        done_before = self.progress_s
        return max(0.0, self.spec.duration_s - done_before - (now - self.bound_time))

    # -- lifecycle -----------------------------------------------------------
    def bind(self, node_id: str, now: float) -> None:
        assert self.phase == PodPhase.PENDING, self
        self.pending_intervals.append(now - self.pending_since)
        self.phase = PodPhase.BOUND
        self.node_id = node_id
        self.bound_time = now

    def evict(self, now: float, *, failed: bool = False) -> None:
        """Shut down and immediately recreate as a fresh PENDING incarnation."""
        assert self.phase == PodPhase.BOUND, self
        if self.is_batch:
            ran = now - (self.bound_time or now)
            if self.spec.checkpointable:
                # Durable progress = last checkpoint boundary (fleet semantics).
                iv = self.spec.checkpoint_interval_s or 1.0
                total = self.progress_s + ran
                self.checkpointed_s = (total // iv) * iv
                self.progress_s = self.checkpointed_s
            elif failed:
                self.progress_s = 0.0     # restart from scratch
            # moveable batch pods do not exist (guarded in PodSpec)
        self.phase = PodPhase.FAILED if failed else PodPhase.EVICTED
        self.node_id = None
        self.bound_time = None
        # recreate
        self.phase = PodPhase.PENDING
        self.pending_since = now
        self.incarnation += 1

    def complete(self, now: float) -> None:
        assert self.is_batch and self.phase == PodPhase.BOUND
        self.phase = PodPhase.SUCCEEDED
        self.finish_time = now

    def __hash__(self):
        return hash(self.uid)

    def __eq__(self, other):
        return isinstance(other, Pod) and other.uid == self.uid

    def __repr__(self):
        return (f"Pod({self.name}, {self.phase.value}, node={self.node_id}, "
                f"inc={self.incarnation})")
