"""Pod / job object model (paper §3, §5.1).

A *pod* is the schedulable unit.  The paper distinguishes:

* **services** — long-running, latency-sensitive (K8s ``Deployment``), may be
  labelled ``rescheduling: moveable``;
* **batch jobs** — run-to-completion (K8s ``Job``), labelled ``type: batch``,
  never moveable.

In the TPU-fleet adaptation a service pod is a serving deployment and a batch
pod is a training job; *moveable* means *checkpointable* (the eviction →
recreate cycle becomes checkpoint → restore, see ``repro.train.checkpoint``).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

from repro.core.resources import Resources


class PodKind(enum.Enum):
    SERVICE = "service"   # long-running (K8s Deployment / serving job)
    BATCH = "batch"       # run-to-completion (K8s Job / training job)


class PodPhase(enum.Enum):
    PENDING = "pending"       # in the scheduling queue
    BOUND = "bound"           # binding created; starts running at bind time
    SUCCEEDED = "succeeded"   # batch only: ran to completion
    EVICTED = "evicted"       # shut down for rescheduling; will be recreated
    FAILED = "failed"         # node failure killed it; will be recreated


_uid = itertools.count()


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """Immutable template for a pod (the YAML of Fig. 3/4 in the paper)."""

    type_name: str                 # e.g. "batch_small", "service_med"
    kind: PodKind
    requests: Resources            # requests == limits (guaranteed QoS class)
    duration_s: float = 0.0        # batch only: nominal runtime
    moveable: bool = False         # services only (label rescheduling:moveable)
    # Fleet extension: moveable batch jobs are checkpointable training jobs.
    checkpointable: bool = False
    checkpoint_interval_s: float = 0.0
    scheduler_name: str = "customScheduler"

    def __post_init__(self):
        if self.kind == PodKind.BATCH and self.moveable:
            raise ValueError("paper §5.1: batch jobs cannot be moveable")


class Pod:
    """A live pod instance.

    A pod evicted by the rescheduler/autoscaler is *recreated*: in Kubernetes
    the deployment controller spawns a fresh pod for the same template.  We
    model that by resetting the instance back to PENDING with a fresh
    ``pending_since`` and an incremented ``incarnation`` — identity (``uid``)
    is stable across incarnations so metrics can track the logical task.

    A plain slotted class, not a dataclass: large traces create one instance
    per arrival (50 k+ per benchmark run), so construction and attribute
    access are hot.  ``requests`` / ``is_batch`` / ``is_service`` /
    ``moveable`` are materialized once from the immutable spec instead of
    going through property descriptors on every read.
    """

    __slots__ = ("spec", "submit_time", "uid", "phase", "node_id",
                 "pending_since", "bound_time", "finish_time", "incarnation",
                 "progress_s", "checkpointed_s", "lost_work_s",
                 "pending_intervals",
                 "requests", "is_batch", "is_service", "moveable")

    def __init__(self, spec: PodSpec, submit_time: float):
        self.spec = spec
        self.submit_time = submit_time
        self.uid: int = next(_uid)
        self.phase = PodPhase.PENDING
        self.node_id: Optional[str] = None
        self.pending_since = submit_time  # start of current pending interval
        self.bound_time: Optional[float] = None
        self.finish_time: Optional[float] = None
        self.incarnation = 0
        self.progress_s = 0.0       # batch: completed work (checkpoint restore)
        self.checkpointed_s = 0.0   # batch: durable progress at last checkpoint
        self.lost_work_s = 0.0      # batch: Σ executed-but-not-durable work
        self.pending_intervals: list = []
        self.requests: Resources = spec.requests
        self.is_batch: bool = spec.kind == PodKind.BATCH
        self.is_service: bool = spec.kind == PodKind.SERVICE
        self.moveable: bool = spec.moveable

    @classmethod
    def _restore(cls, spec: PodSpec, submit_time: float, uid: int,
                 phase: "PodPhase", node_id: Optional[str],
                 pending_since: float, bound_time: Optional[float],
                 finish_time: Optional[float], incarnation: int,
                 pending_intervals: list, lost_work_s: float = 0.0) -> "Pod":
        """Materialize a pod *shell* from SoA column state (PodStore).

        Unlike ``__init__`` this does **not** draw from the global uid
        counter: the store already allocated the uid at ingest time.  The
        attribute values are handed in verbatim from the columns, so the
        shell is indistinguishable from the object the seed path would have
        produced (property-tested by ``tests/test_engine_parity.py``).
        A store-resident pod is only evicted column-natively when it banks
        no durable progress (``Cluster.fail_node_store`` materializes it
        otherwise), so ``progress_s`` / ``checkpointed_s`` are always zero
        here — but ``lost_work_s`` may carry prior bulk-eviction losses.
        """
        pod = object.__new__(cls)
        pod.spec = spec
        pod.submit_time = submit_time
        pod.uid = uid
        pod.phase = phase
        pod.node_id = node_id
        pod.pending_since = pending_since
        pod.bound_time = bound_time
        pod.finish_time = finish_time
        pod.incarnation = incarnation
        pod.progress_s = 0.0
        pod.checkpointed_s = 0.0
        pod.lost_work_s = lost_work_s
        pod.pending_intervals = pending_intervals
        pod.requests = spec.requests
        pod.is_batch = spec.kind == PodKind.BATCH
        pod.is_service = spec.kind == PodKind.SERVICE
        pod.moveable = spec.moveable
        return pod

    # -- convenience ---------------------------------------------------------
    @property
    def name(self) -> str:
        return f"{self.spec.type_name}-{self.uid}"

    def age(self, now: float) -> float:
        """Time spent in the current pending interval (rescheduler gate)."""
        return now - self.pending_since

    def remaining_s(self, now: float) -> float:
        """Batch only: work left, given progress at the current binding."""
        assert self.is_batch and self.bound_time is not None
        done_before = self.progress_s
        return max(0.0, self.spec.duration_s - done_before - (now - self.bound_time))

    # -- lifecycle -----------------------------------------------------------
    def bind(self, node_id: str, now: float) -> None:
        assert self.phase == PodPhase.PENDING, self
        self.pending_intervals.append(now - self.pending_since)
        self.phase = PodPhase.BOUND
        self.node_id = node_id
        self.bound_time = now

    def evict(self, now: float, *, failed: bool = False) -> None:
        """Shut down and immediately recreate as a fresh PENDING incarnation."""
        assert self.phase == PodPhase.BOUND, self
        if self.is_batch:
            ran = now - (self.bound_time or now)
            if self.spec.checkpointable:
                # Durable progress = last checkpoint boundary (fleet semantics).
                iv = self.spec.checkpoint_interval_s or 1.0
                total = self.progress_s + ran
                self.checkpointed_s = (total // iv) * iv
                # Work past the last durable checkpoint is redone on restore.
                self.lost_work_s += total - self.checkpointed_s
                self.progress_s = self.checkpointed_s
            elif failed:
                self.lost_work_s += self.progress_s + ran
                self.progress_s = 0.0     # restart from scratch
            # moveable batch pods do not exist (guarded in PodSpec)
        self.phase = PodPhase.FAILED if failed else PodPhase.EVICTED
        self.node_id = None
        self.bound_time = None
        # recreate
        self.phase = PodPhase.PENDING
        self.pending_since = now
        self.incarnation += 1

    def complete(self, now: float) -> None:
        assert self.is_batch and self.phase == PodPhase.BOUND
        self.phase = PodPhase.SUCCEEDED
        self.finish_time = now

    def __hash__(self):
        return hash(self.uid)

    def __eq__(self, other):
        return isinstance(other, Pod) and other.uid == self.uid

    def __repr__(self):
        return (f"Pod({self.name}, {self.phase.value}, node={self.node_id}, "
                f"inc={self.incarnation})")
