"""Two-dimensional resource vectors, paper §3/§6.1.

The paper schedules on (CPU, memory) with an explicit asymmetry:

* **CPU is compressible** — exceeding it gets throttled, never killed.
* **Memory is non-compressible** — exceeding it gets the pod killed; the only
  relief for pressure is eviction.

The TPU-fleet adaptation keeps the same algebra with reinterpreted units
(see DESIGN.md §2): ``cpu_m`` = compressible compute grain (millicores on a
VM worker; chip-milliseconds of schedulable compute share on a TPU host) and
``mem_mb`` = the non-compressible byte resource (RAM MB; HBM MB).  Best-fit
is keyed on the non-compressible axis in both worlds.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Resources:
    """An amount of (compressible, non-compressible) resource.

    Attributes:
      cpu_m:  compressible resource in milli-units (paper: CPU millicores).
      mem_mb: non-compressible resource in MB (paper: RAM; fleet: HBM).
    """

    cpu_m: int = 0
    mem_mb: float = 0.0

    # -- algebra ------------------------------------------------------------
    def __add__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu_m + other.cpu_m, self.mem_mb + other.mem_mb)

    def __sub__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu_m - other.cpu_m, self.mem_mb - other.mem_mb)

    def __mul__(self, k: float) -> "Resources":
        return Resources(int(self.cpu_m * k), self.mem_mb * k)

    # -- predicates ----------------------------------------------------------
    def fits_in(self, free: "Resources") -> bool:
        """True iff a request of `self` fits inside `free` on both axes."""
        return self.cpu_m <= free.cpu_m and self.mem_mb <= free.mem_mb + 1e-9

    def cpu_fits_in(self, free: "Resources") -> bool:
        """Paper Alg. 3/4 first-stage filter: compressible axis only."""
        return self.cpu_m <= free.cpu_m

    def nonneg(self) -> bool:
        return self.cpu_m >= 0 and self.mem_mb >= -1e-9

    @staticmethod
    def zero() -> "Resources":
        return Resources(0, 0.0)


def gi(x: float) -> float:
    """Gibibytes -> MB (paper requests are written in Gi)."""
    return x * 1024.0


def sum_resources(items) -> Resources:
    total = Resources.zero()
    for r in items:
        total = total + r
    return total
