"""Discrete-event simulator for the paper's evaluation (§7).

Deterministic (seeded, no wall clock).  Events live on a typed
:class:`Timeline` — a two-stream structure replacing the seed's flat
one-entry-per-event heap:

* the **arrival stream** — the workload trace is already sorted by
  submission time, so ARRIVAL events are never heap-managed at all: the
  timeline merges the presorted stream against the heap head and drains
  every arrival due before the next non-arrival event as **one batch**
  (50 k trace entries collapse into a few hundred batch events);
* the **event heap** — everything else, with POD_DONE *bucketed*: each
  cycle sorts the pods it bound by completion timestamp into the
  PodStore's append-only completion log and pushes one event per distinct
  timestamp carrying a ``(lo, hi)`` range into that log (stale entries are
  filtered at fire time via the phase/incarnation columns — there is no
  per-pod scheduling dict).

Event kinds:

* ``ARRIVAL``     — a run of trace jobs is submitted (batch payload);
* ``CYCLE``       — periodic scheduler cycle (paper Alg. 1);
* ``POD_DONE``    — batch pods ran to completion (bucketed, see above);
* ``NODE_READY``  — a provisioning VM joined the cluster (boot delay model);
* ``SAMPLE``      — 20 s Table-5 utilization sampling;
* ``NODE_FAIL``   — fleet extension: a node dies (failure injection);
* ``NODE_NOTICE`` — disruption: spot reclaim notice — the node is drained
  and killed after the notice window (``repro.core.disruption``);
* ``ZONE_OUTAGE`` — disruption: a correlated zone failure event (the
  payload injector picks the zone and kills its nodes);
* ``POD_CRASH``   — disruption: a crash-loop event (the payload injector
  picks a running batch pod within its restart budget).

Disruption events (kind >= ``NODE_FAIL``) append to ``disruption_log`` and,
when ``on_disruption`` is set, invoke it after the handler — the chaos
harness hooks ``PodStore.audit_columns`` there.

Ordering is identical to the seed heap: the seed pushed every arrival
before any other event, so at equal timestamps arrivals always won the
sequence-number tie-break — exactly the ``arrival_time <= heap_head`` rule
the timeline applies; all other simultaneous events retain push order via
the heap's sequence counter.

Exit condition: all arrivals submitted and every batch pod SUCCEEDED;
services are then torn down and billing closed (paper's *scheduling
duration* = first submission → last batch completion).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from bisect import bisect_right
from typing import List, Optional, Tuple

from repro.core import engine as _engine
from repro.core.autoscaler import Autoscaler
from repro.core.cluster import Cluster, Node, NodeState
from repro.core.cost import CostModel
from repro.core.metrics import SAMPLE_PERIOD_S, ExperimentResult, MetricsCollector
from repro.core.orchestrator import Orchestrator
from repro.core.pods import Pod, PodPhase
from repro.core.workload import Arrival

(ARRIVAL, CYCLE, POD_DONE, NODE_READY, SAMPLE,
 NODE_FAIL, NODE_NOTICE, ZONE_OUTAGE, POD_CRASH) = range(9)

_INF = float("inf")


class Timeline:
    """Typed event timeline: presorted arrival stream + bucketed heap.

    ``pop()`` yields ``(t, kind, payload)`` in global time order.  ARRIVAL
    events carry a **batch payload** (a list of :class:`Arrival`): one pop
    drains every arrival due at or before the next heap event, bounded by
    ``horizon`` so a batch never crosses the simulation's time limit (the
    consumer must still see the first out-of-limit event to stop on it,
    exactly like popping it off the seed heap).

    Tie-break contract (bit-parity with the seed heap): arrivals were
    pushed first in the seed, so they carried the lowest sequence numbers —
    at equal timestamps an arrival always preceded any other event.  Here
    that is the ``t_arrival <= t_heap`` comparison.  Heap events pushed
    later keep their relative push order via ``seq``.
    """

    def __init__(self, arrivals: Optional[List[Arrival]] = None,
                 horizon: float = _INF, trace=None):
        if trace is not None:
            # Trace-native mode: the key column is the TraceStore's
            # arrival_time column itself (bisect works on the ndarray) and
            # ARRIVAL payloads are ``(lo, hi)`` row ranges — no Arrival
            # objects exist at any point.
            self._arrivals = None
            self._trace = trace
            self._times = trace.arrival_time
            self._n = trace.n
        else:
            self._arrivals = arrivals or []
            self._trace = None
            self._times = [a.time for a in self._arrivals]   # bisect keys
            self._n = len(self._arrivals)
        self._ai = 0
        self._horizon = horizon
        self._heap: List[Tuple[float, int, int, object]] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: int, payload=None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def __bool__(self) -> bool:
        return bool(self._heap) or self._ai < self._n

    def pop(self) -> Tuple[float, int, object]:
        """Earliest event; ARRIVAL runs come out as one batch.  Batch
        payloads are ``Arrival`` slices (list mode) or ``(lo, hi)`` row
        ranges (trace mode)."""
        ai = self._ai
        t_arr = float(self._times[ai]) if ai < self._n else _INF
        heap = self._heap
        if heap:
            head = heap[0]
            if head[0] < t_arr:
                heapq.heappop(heap)
                return head[0], head[2], head[3]
            limit = head[0]
        else:
            if t_arr is _INF:
                raise IndexError("pop from empty Timeline")
            limit = _INF
        if t_arr > self._horizon:
            # Out-of-horizon arrival: surface it alone, like the seed heap
            # popping the first over-limit event (the consumer stops on it).
            self._ai = ai + 1
            if self._arrivals is None:
                return t_arr, ARRIVAL, (ai, ai + 1)
            return t_arr, ARRIVAL, self._arrivals[ai:ai + 1]
        j = bisect_right(self._times, min(limit, self._horizon), ai)
        self._ai = j
        if self._arrivals is None:
            return t_arr, ARRIVAL, (ai, j)
        return t_arr, ARRIVAL, self._arrivals[ai:j]


@dataclasses.dataclass
class SimConfig:
    cycle_period_s: float = 10.0
    max_sim_time_s: float = 48 * 3600.0
    sample_period_s: float = SAMPLE_PERIOD_S
    # Benchmark instrumentation: stop issuing CYCLE events after this many
    # (None = unlimited) and record per-cycle wall-clock latency.
    max_cycles: Optional[int] = None
    record_cycle_times: bool = False


class Simulation:
    """Drives one experiment: workload trace × policy combo × cluster."""

    def __init__(self, orchestrator: Orchestrator, cost: CostModel,
                 arrivals: Optional[List[Arrival]] = None,
                 config: Optional[SimConfig] = None,
                 failure_injector=None, trace=None):
        self.orch = orchestrator
        self.cluster = orchestrator.cluster
        self.cost = cost
        if trace is not None and arrivals:
            raise ValueError("pass either arrivals or trace, not both")
        if trace is not None and orchestrator.store is None:
            # The object engine has no columnar ingest: materialize the
            # classic arrival list once (an API boundary; the seed engine
            # is object-speed anyway).
            arrivals, trace = trace.to_arrivals(), None
        self.trace = trace   # columnar workload (scenarios.TraceStore)
        self.arrivals = sorted(arrivals or [], key=lambda a: a.time)
        # Total jobs in the workload, whichever form it arrived in (the
        # exit condition and stuck detection compare against it).
        self.n_arrivals = trace.n if trace is not None else len(self.arrivals)
        self.config = config or SimConfig()
        self.metrics = MetricsCollector()
        self.failure_injector = failure_injector
        self.now = 0.0
        self.timeline: Optional[Timeline] = None
        self.cycle_wall_s: List[float] = []    # per-cycle latency (bench)
        self.cycle_placed: List[int] = []      # per-cycle placements (bench)
        self.n_cycles = 0
        self.failures_injected = 0
        self.preemption_notices = 0
        # Chronological ledger of disruption events:
        # (time, kind-str, subject-id, payload-list) — "node_fail" carries
        # the evicted pod uids, "reclaim_notice" the resident count,
        # "zone_outage" the victim node ids, "pod_crash" the crashed uid.
        self.disruption_log: List[tuple] = []
        # Optional observer called as on_disruption(sim, kind) after every
        # disruption event (kind >= NODE_FAIL); the chaos harness audits
        # the pod columns here.
        self.on_disruption = None
        # Observability recorder (repro.obs.ObsRecorder.attach sets it);
        # None = compiled out — the run loop pays one is-None test per event.
        self.obs = None
        self._stuck = False
        self.first_submit: Optional[float] = None
        self.last_batch_done: Optional[float] = None

    # -- event plumbing -----------------------------------------------------------
    def push(self, t: float, kind: int, payload=None) -> None:
        if self.timeline is None:   # pre-run priming (failure injectors)
            self.timeline = Timeline(self.arrivals, trace=self.trace)
        self.timeline.push(t, kind, payload)

    # -- public: used by SimProvider ----------------------------------------------
    def schedule_node_ready(self, node: Node, t: float) -> None:
        self.push(t, NODE_READY, node)

    # -- main loop ------------------------------------------------------------------
    def run(self) -> ExperimentResult:
        if self.timeline is None:
            self.timeline = Timeline(self.arrivals, trace=self.trace)
        tl = self.timeline
        tl._horizon = self.config.max_sim_time_s   # config may change pre-run
        tl.push(0.0, CYCLE)
        tl.push(0.0, SAMPLE)
        if self.failure_injector is not None:
            self.failure_injector.prime(self)

        max_t = self.config.max_sim_time_s
        completed = False
        obs = self.obs
        prof = obs.prof if obs is not None else None
        while tl:
            if prof is None:
                t, kind, payload = tl.pop()
            else:
                t0 = prof.start()
                t, kind, payload = tl.pop()
                prof.stop("timeline_drain", t0, self.now)
            if t > max_t:
                break
            self.now = t
            if kind == ARRIVAL:
                if prof is None:
                    self._on_arrivals(payload)
                else:
                    t0 = prof.start()
                    self._on_arrivals(payload)
                    prof.stop("arrival_ingest", t0, t)
            elif kind == CYCLE:
                self._on_cycle()
            elif kind == POD_DONE:
                if prof is None:
                    self._on_pod_done(payload)
                else:
                    t0 = prof.start()
                    self._on_pod_done(payload)
                    prof.stop("completion_commit", t0, t)
            elif kind == NODE_READY:
                self._on_node_ready(payload)
            elif kind == SAMPLE:
                if prof is None:
                    self._on_sample()
                else:
                    t0 = prof.start()
                    self._on_sample()
                    prof.stop("metrics_sample", t0, t)
            elif kind == NODE_FAIL:
                self._on_node_fail(payload)
            elif kind == NODE_NOTICE:
                self._on_node_notice(payload)
            elif kind == ZONE_OUTAGE:
                payload.on_outage(self)
            elif kind == POD_CRASH:
                payload.on_crash_event(self)
            if kind >= NODE_FAIL and self.on_disruption is not None:
                self.on_disruption(self, kind)
            if self._done():
                completed = True
                break

        end = self.last_batch_done if completed and self.last_batch_done else self.now
        self.cost.close_all(end)
        return self._result(completed, end)

    # -- handlers --------------------------------------------------------------------
    def _on_arrivals(self, batch) -> None:
        """Submit one ARRIVAL batch.  Each pod's submit_time/pending_since
        is its own arrival instant, exactly as under per-event handling;
        ``now`` jumps straight to the batch's last arrival because nothing
        can observe the intermediate instants — no other event is due
        before then (Timeline contract) and submission never reads the
        clock.  Trace mode: the batch is a ``(lo, hi)`` row range and
        submission is the columnar bulk ingest (zero Arrival objects)."""
        if type(batch) is tuple:
            lo, hi = batch
            times = self.trace.arrival_time
            if self.first_submit is None:
                self.first_submit = float(times[lo])
            self.now = float(times[hi - 1])
            if self.orch.autoscaler.observes_arrivals:
                self.orch.autoscaler.observe_arrivals(
                    times[lo:hi], self.trace.cpu_m[lo:hi],
                    self.trace.mem_mb[lo:hi])
            self.orch.submit_trace(self.trace, lo, hi)
            return
        if self.first_submit is None:
            self.first_submit = batch[0].time
        self.now = batch[-1].time
        if self.orch.autoscaler.observes_arrivals:
            reqs = [a.spec.requests for a in batch]
            self.orch.autoscaler.observe_arrivals(
                [a.time for a in batch],
                [r.cpu_m for r in reqs], [r.mem_mb for r in reqs])
        self.orch.submit_wave(batch)

    def _on_cycle(self) -> None:
        t0 = time.perf_counter() if self.config.record_cycle_times else 0.0
        stats = self.orch.cycle(self.now)
        obs = self.obs
        prof = obs.prof if obs is not None else None
        if prof is None:
            self._schedule_completions()
        else:
            ts = prof.start()
            self._schedule_completions()
            prof.stop("completion_schedule", ts, self.now)
        if self.config.record_cycle_times:
            self.cycle_wall_s.append(time.perf_counter() - t0)
            self.cycle_placed.append(stats.placed)
        self.n_cycles += 1
        if (self.config.max_cycles is not None
                and self.n_cycles >= self.config.max_cycles):
            return   # benchmark cap: stop perpetuating cycles
        if self._permanently_stuck(stats):
            self._stuck = True
            return   # stop perpetuating cycles; timeline drains, run() returns
        self.push(self.now + self.config.cycle_period_s, CYCLE)

    def _permanently_stuck(self, stats) -> bool:
        """A static (void-autoscaled) cluster with pending pods, nothing
        running that could free space, and no provisioning in flight can
        never make progress — bail instead of simulating to max_sim_time."""
        if len(self.orch.pods) != self.n_arrivals:
            return False
        if stats.placed or stats.rescheduled or stats.scale_out_requests == 0:
            return False
        if self.cluster.provisioning_nodes():
            return False
        if self.orch.has_running_batch():
            return False   # a completion may free space later
        return self.orch.n_pending > 0

    def _schedule_completions(self) -> None:
        """Any batch pod bound (or re-bound) since the last cycle gets a
        completion for its current incarnation.  The orchestrator hands us
        exactly the pods bound since the last drain — no per-cycle scan of
        every running pod — and completions sharing a timestamp (pods of the
        same spec bound in the same cycle) are bucketed into a single heap
        event, so the event heap sees one push per distinct completion time
        per cycle instead of one per pod.

        The cycle's entries are stable-sorted by completion time (bind
        order preserved within a timestamp — the per-pod event order the
        seed engine produced for equal timestamps).  On the shell-less fast
        path they append to the PodStore's columnar completion log
        (:meth:`PodStore.log_completions`) and each bucket's POD_DONE
        payload is a ``(lo, hi)`` range into it; a cycle that drained any
        ``Pod`` object (object engine, or a shell materialized since the
        bind) falls back to list payloads of ``(pod | row, incarnation)``.
        Both shapes compute ``t_done`` with the identical float ops (a
        shell-less row has ``progress_s == 0`` by construction).

        There is no cross-cycle scheduling dict: a ``(uid, incarnation)``
        pair can only be drained twice within *one* cycle (bind → evict →
        re-bind bumps the incarnation, and the drain list resets every
        cycle), so a per-call ``seen`` set is the whole dedup story; fire-
        time staleness is the phase/incarnation check in `_on_pod_done`."""
        node_of = self.cluster.nodes.get
        now = self.now
        store = self.orch.store
        slot_nodes = self.cluster._slot_nodes
        entries: list = []                 # (t_done, row | pod, incarnation)
        all_rows = True
        seen = set()
        for item in self.orch.drain_newly_bound_batch():
            if type(item) is int:
                row = item
                pod = store.shells.get(row)
                if pod is None:
                    if store.phase[row] != _engine.POD_BOUND:
                        continue   # bound then evicted before the drain
                    uid = store.uid[row]
                    if uid in seen:
                        continue
                    seen.add(uid)
                    node = slot_nodes[store.node_slot[row]]
                    speed = node.speed_factor if node else 1.0
                    # progress_s is 0 for a never-evicted, shell-less pod.
                    remaining = store.duration_s[row] - 0.0
                    entries.append((now + remaining / max(speed, 1e-6),
                                    row, store.incarnation[row]))
                    continue
            else:
                pod = item
            if pod.phase is not PodPhase.BOUND:
                continue   # bound then evicted again before the drain
            if pod.uid in seen:
                continue
            seen.add(pod.uid)
            all_rows = False
            node = node_of(pod.node_id)
            speed = node.speed_factor if node else 1.0
            remaining = pod.spec.duration_s - pod.progress_s
            entries.append((now + remaining / max(speed, 1e-6),
                            pod, pod.incarnation))
        if not entries:
            return
        entries.sort(key=lambda e: e[0])   # stable: bind order within a time
        i, n = 0, len(entries)
        while i < n:
            t_done = entries[i][0]
            j = i
            while j < n and entries[j][0] == t_done:
                j += 1
            if all_rows and store is not None:
                payload = store.log_completions(
                    [e[1] for e in entries[i:j]],
                    [e[2] for e in entries[i:j]])
            else:
                payload = [(e[1], e[2]) for e in entries[i:j]]
            self.push(t_done, POD_DONE, payload)
            i = j

    def _on_pod_done(self, payload) -> None:
        # One POD_DONE event carries every completion bucketed at this
        # timestamp, in bind order (matching the per-pod event order the
        # seed engine produced for equal timestamps).  The payload is a
        # ``(lo, hi)`` range into the PodStore completion log (fast path)
        # or a list of ``(pod | store-row, incarnation)`` (object engine /
        # mixed-shell cycles); live-vs-stale is decided here, per entry, by
        # the phase + incarnation columns — this event was that
        # incarnation's one shot either way.
        #
        # Rows stay column-only through the commit
        # (``Cluster.complete_wave_store``) unless an external
        # ``on_complete`` observer is attached — an API boundary, which
        # materializes shells and routes through the object-path
        # ``complete_wave`` so the observer sees real pods, in order.
        store = self.orch.store
        if type(payload) is tuple:
            lo, hi = payload
            pairs = zip(store.done_rows[lo:hi], store.done_incs[lo:hi])
            store.consume_completions(lo, hi)
        else:
            pairs = payload
        live: list = []
        rows_present = False
        for first, incarnation in pairs:
            if type(first) is int:
                row = first
                pod = store.shells.get(row)
                if pod is None:
                    if (store.phase[row] != _engine.POD_BOUND
                            or store.incarnation[row] != incarnation):
                        continue   # stale: pod was evicted/failed since
                    live.append(row)
                    rows_present = True
                    continue
            else:
                pod = first
            if pod.phase is not PodPhase.BOUND or pod.incarnation != incarnation:
                continue   # stale entry: pod was evicted/failed since
            live.append(pod)
        if live:
            if rows_present:
                orch = self.orch
                if self.cluster.on_complete == orch._on_pod_completed:
                    self.cluster.complete_wave_store(
                        live, self.now, on_row=orch._on_row_completed)
                else:
                    # External observer: materialize rows, keep bind order.
                    self.cluster.complete_wave(
                        [store.pod_at(e) if type(e) is int else e
                         for e in live], self.now)
            else:
                self.cluster.complete_wave(live, self.now)
            self.last_batch_done = self.now

    def _on_node_ready(self, node: Node) -> None:
        if node.state != NodeState.PROVISIONING:
            return
        node.mark_ready(self.now)
        self.orch.autoscaler.notify_node_ready(node)
        if self.failure_injector is not None:
            self.failure_injector.arm_node(self, node)

    def _on_sample(self) -> None:
        self.metrics.sample(self.cluster, self.now)
        self.push(self.now + self.config.sample_period_s, SAMPLE)

    def _on_node_fail(self, node: Node) -> None:
        if node.node_id not in self.cluster.nodes:
            return
        if node.state == NodeState.TERMINATED:
            return
        self.failures_injected += 1
        cluster = self.cluster
        if (cluster.pod_store is not None
                and cluster.on_unbind == self.orch._on_pod_unbound):
            # Shell-less fast path: the whole node evicts as bulk column
            # writes (no per-pod materialization).  An external on_unbind
            # observer is an API boundary — the object loop below
            # materializes shells so the observer sees real pods, in order.
            victims = cluster.fail_node_store(
                node, self.now, on_row=self.orch._on_row_unbound)
        else:
            victims = []
            for pod in list(node.pods.values()):
                victims.append(pod.uid)
                cluster.unbind(pod, self.now, failed=True)
        # Drop any provisioning association so evictees can trigger
        # replacement capacity (the BindingAutoscaler leak fix).
        self.orch.autoscaler.notify_node_lost(node)
        if node.state == NodeState.PROVISIONING:
            node.state = NodeState.READY   # force through the state machine
            node.ready_time = self.now
        self.cost.on_deprovision(node, self.now)
        cluster.remove_node(node, self.now)
        self.disruption_log.append(
            (self.now, "node_fail", node.node_id, victims))

    def fail_node(self, node: Node) -> None:
        """Public entry point for disruption injectors: kill ``node`` at the
        current instant through the normal NODE_FAIL plumbing."""
        self._on_node_fail(node)

    def _on_node_notice(self, payload) -> None:
        """Spot reclaim notice (``disruption.SpotReclaimInjector``): the
        node will be killed ``kill_delay_s`` from now.  Drain it (taint —
        no new pods land during the window), tell the autoscaler so
        replacement capacity can launch *before* the kill, and schedule
        the kill itself through the normal NODE_FAIL plumbing."""
        node, kill_delay_s = payload
        if node.node_id not in self.cluster.nodes:
            return
        if node.state == NodeState.TERMINATED:
            return
        self.preemption_notices += 1
        self.disruption_log.append(
            (self.now, "reclaim_notice", node.node_id, [len(node.pods)]))
        obs = self.obs
        if obs is not None:
            obs.preempt_notice(self.now, node.node_id, len(node.pods),
                               kill_delay_s)
        node.taint()
        self.orch.autoscaler.notify_preemption_notice(
            self.cluster, node, self.now)
        self.push(self.now + kill_delay_s, NODE_FAIL, node)

    # -- termination / results ----------------------------------------------------
    def _done(self) -> bool:
        """All jobs placed & executed: every batch SUCCEEDED and every
        service BOUND (a cluster that never fits its services never
        completed the workload — this matters for the Fig. 4 baseline)."""
        if len(self.orch.pods) != self.n_arrivals or not self.orch.pods:
            return False
        if not self.orch.batch_all_done():
            return False
        return self.orch.services_all_bound()

    def _result(self, completed: bool, end: float) -> ExperimentResult:
        store = self.orch.store
        if store is not None:
            # Column-native end-of-run walk: shells contribute their
            # recorded interval lists, shell-less rows derive theirs from
            # the columns — same multiset, no 50k-shell materialization.
            self.metrics.record_pending_intervals(
                store.pending_intervals_all())
            evictions = store.total_incarnations()
            lost_work = store.total_lost_work_s()
        else:
            for pod in self.orch.pods:
                self.metrics.record_pending_intervals(pod.pending_intervals)
            evictions = sum(p.incarnation for p in self.orch.pods)
            lost_work = sum((p.lost_work_s for p in self.orch.pods), 0.0)
        start = self.first_submit or 0.0
        return ExperimentResult(
            workload="", scheduler=self.orch.scheduler.name,
            rescheduler=self.orch.rescheduler.name,
            autoscaler=self.orch.autoscaler.name,
            completed=completed,
            cost=self.cost.total_cost(end),
            duration_s=end - start,
            median_pending_s=self.metrics.median_pending_s(),
            mean_pending_s=self.metrics.mean_pending_s(),
            max_pending_s=self.metrics.max_pending_s(),
            avg_ram_ratio=self.metrics.avg_ram_ratio(),
            avg_cpu_ratio=self.metrics.avg_cpu_ratio(),
            avg_pods_per_node=self.metrics.avg_pods_per_node(),
            max_nodes=self.metrics.max_nodes(),
            node_seconds=self.cost.total_node_seconds(end),
            evictions=evictions,
            scale_outs=self.orch.total_scale_outs,
            scale_ins=self.orch.total_scale_ins,
            failures_injected=self.failures_injected,
            preemption_notices=self.preemption_notices,
            lost_work_s=lost_work,
        )
