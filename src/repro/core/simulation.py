"""Discrete-event simulator for the paper's evaluation (§7).

Deterministic (seeded, no wall clock): events are (time, seq, kind, payload)
on a heap.  Event kinds:

* ``ARRIVAL``     — a job from the workload trace is submitted;
* ``CYCLE``       — periodic scheduler cycle (paper Alg. 1);
* ``POD_DONE``    — batch pods ran to completion.  Completions are
  *bucketed*: each cycle groups the pods it bound by completion timestamp
  and pushes **one** heap event per distinct timestamp carrying the whole
  batch, instead of one heap push per pod (stale entries are invalidated
  per pod via the incarnation counter);
* ``NODE_READY``  — a provisioning VM joined the cluster (boot delay model);
* ``SAMPLE``      — 20 s Table-5 utilization sampling;
* ``NODE_FAIL``   — fleet extension: a node dies (failure injection).

Exit condition: all arrivals submitted and every batch pod SUCCEEDED; services
are then torn down and billing closed (paper's *scheduling duration* =
first submission → last batch completion).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional, Tuple

from repro.core.autoscaler import Autoscaler
from repro.core.cluster import Cluster, Node, NodeState
from repro.core.cost import CostModel
from repro.core.metrics import SAMPLE_PERIOD_S, ExperimentResult, MetricsCollector
from repro.core.orchestrator import Orchestrator
from repro.core.pods import Pod, PodPhase
from repro.core.workload import Arrival

ARRIVAL, CYCLE, POD_DONE, NODE_READY, SAMPLE, NODE_FAIL = range(6)


@dataclasses.dataclass
class SimConfig:
    cycle_period_s: float = 10.0
    max_sim_time_s: float = 48 * 3600.0
    sample_period_s: float = SAMPLE_PERIOD_S
    # Benchmark instrumentation: stop issuing CYCLE events after this many
    # (None = unlimited) and record per-cycle wall-clock latency.
    max_cycles: Optional[int] = None
    record_cycle_times: bool = False


class Simulation:
    """Drives one experiment: workload trace × policy combo × cluster."""

    def __init__(self, orchestrator: Orchestrator, cost: CostModel,
                 arrivals: List[Arrival], config: Optional[SimConfig] = None,
                 failure_injector=None):
        self.orch = orchestrator
        self.cluster = orchestrator.cluster
        self.cost = cost
        self.arrivals = sorted(arrivals, key=lambda a: a.time)
        self.config = config or SimConfig()
        self.metrics = MetricsCollector()
        self.failure_injector = failure_injector
        self.now = 0.0
        self._heap: List[Tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._completion_scheduled: Dict[Tuple[int, int], bool] = {}
        self.cycle_wall_s: List[float] = []    # per-cycle latency (bench)
        self.cycle_placed: List[int] = []      # per-cycle placements (bench)
        self.n_cycles = 0
        self.failures_injected = 0
        self._stuck = False
        self.first_submit: Optional[float] = None
        self.last_batch_done: Optional[float] = None

    # -- event plumbing -----------------------------------------------------------
    def push(self, t: float, kind: int, payload=None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    # -- public: used by SimProvider ----------------------------------------------
    def schedule_node_ready(self, node: Node, t: float) -> None:
        self.push(t, NODE_READY, node)

    # -- main loop ------------------------------------------------------------------
    def run(self) -> ExperimentResult:
        for a in self.arrivals:
            self.push(a.time, ARRIVAL, a)
        self.push(0.0, CYCLE)
        self.push(0.0, SAMPLE)
        if self.failure_injector is not None:
            self.failure_injector.prime(self)

        completed = False
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > self.config.max_sim_time_s:
                break
            self.now = t
            if kind == ARRIVAL:
                self._on_arrival(payload)
            elif kind == CYCLE:
                self._on_cycle()
            elif kind == POD_DONE:
                self._on_pod_done(payload)
            elif kind == NODE_READY:
                self._on_node_ready(payload)
            elif kind == SAMPLE:
                self._on_sample()
            elif kind == NODE_FAIL:
                self._on_node_fail(payload)
            if self._done():
                completed = True
                break

        end = self.last_batch_done if completed and self.last_batch_done else self.now
        self.cost.close_all(end)
        return self._result(completed, end)

    # -- handlers --------------------------------------------------------------------
    def _on_arrival(self, arrival: Arrival) -> None:
        pod = Pod(spec=arrival.spec, submit_time=self.now)
        if self.first_submit is None:
            self.first_submit = self.now
        self.orch.submit(pod)

    def _on_cycle(self) -> None:
        t0 = time.perf_counter() if self.config.record_cycle_times else 0.0
        stats = self.orch.cycle(self.now)
        self._schedule_completions()
        if self.config.record_cycle_times:
            self.cycle_wall_s.append(time.perf_counter() - t0)
            self.cycle_placed.append(stats.placed)
        self.n_cycles += 1
        if (self.config.max_cycles is not None
                and self.n_cycles >= self.config.max_cycles):
            return   # benchmark cap: stop perpetuating cycles
        if self._permanently_stuck(stats):
            self._stuck = True
            return   # stop perpetuating cycles; heap drains, run() returns
        self.push(self.now + self.config.cycle_period_s, CYCLE)

    def _permanently_stuck(self, stats) -> bool:
        """A static (void-autoscaled) cluster with pending pods, nothing
        running that could free space, and no provisioning in flight can
        never make progress — bail instead of simulating to max_sim_time."""
        if len(self.orch.pods) != len(self.arrivals):
            return False
        if stats.placed or stats.rescheduled or stats.scale_out_requests == 0:
            return False
        if self.cluster.provisioning_nodes():
            return False
        if self.orch.has_running_batch():
            return False   # a completion may free space later
        return self.orch.n_pending > 0

    def _schedule_completions(self) -> None:
        """Any batch pod bound (or re-bound) since the last cycle gets a
        completion for its current incarnation.  The orchestrator hands us
        exactly the pods bound since the last drain — no per-cycle scan of
        every running pod — and completions sharing a timestamp (pods of the
        same spec bound in the same cycle) are bucketed into a single heap
        event, so the event heap sees one push per distinct completion time
        per cycle instead of one per pod."""
        buckets: Dict[float, List[Tuple[Pod, int]]] = {}
        for pod in self.orch.drain_newly_bound_batch():
            if pod.phase != PodPhase.BOUND:
                continue   # bound then evicted again before the drain
            key = (pod.uid, pod.incarnation)
            if key in self._completion_scheduled:
                continue
            node = self.cluster.node_of(pod)
            speed = node.speed_factor if node else 1.0
            remaining = pod.spec.duration_s - pod.progress_s
            t_done = self.now + remaining / max(speed, 1e-6)
            buckets.setdefault(t_done, []).append((pod, pod.incarnation))
            self._completion_scheduled[key] = True
        for t_done, batch in buckets.items():
            self.push(t_done, POD_DONE, batch)

    def _on_pod_done(self, payload) -> None:
        # One POD_DONE event carries every completion bucketed at this
        # timestamp, in bind order (matching the per-pod event order the
        # seed engine produced for equal timestamps).
        for pod, incarnation in payload:
            if pod.phase != PodPhase.BOUND or pod.incarnation != incarnation:
                continue   # stale entry: pod was evicted/failed since
            self.cluster.complete(pod, self.now)
            self.last_batch_done = self.now

    def _on_node_ready(self, node: Node) -> None:
        if node.state != NodeState.PROVISIONING:
            return
        node.mark_ready(self.now)
        self.orch.autoscaler.notify_node_ready(node)
        if self.failure_injector is not None:
            self.failure_injector.arm_node(self, node)

    def _on_sample(self) -> None:
        self.metrics.sample(self.cluster, self.now)
        self.push(self.now + self.config.sample_period_s, SAMPLE)

    def _on_node_fail(self, node: Node) -> None:
        if node.node_id not in self.cluster.nodes:
            return
        if node.state == NodeState.TERMINATED:
            return
        self.failures_injected += 1
        for pod in list(node.pods.values()):
            self.cluster.unbind(pod, self.now, failed=True)
        if node.state == NodeState.PROVISIONING:
            node.state = NodeState.READY   # force through the state machine
            node.ready_time = self.now
        self.cost.on_deprovision(node, self.now)
        self.cluster.remove_node(node, self.now)

    # -- termination / results ----------------------------------------------------
    def _done(self) -> bool:
        """All jobs placed & executed: every batch SUCCEEDED and every
        service BOUND (a cluster that never fits its services never
        completed the workload — this matters for the Fig. 4 baseline)."""
        if len(self.orch.pods) != len(self.arrivals) or not self.orch.pods:
            return False
        if not self.orch.batch_all_done():
            return False
        return self.orch.services_all_bound()

    def _result(self, completed: bool, end: float) -> ExperimentResult:
        for pod in self.orch.pods:
            for iv in pod.pending_intervals:
                self.metrics.record_pending_interval(iv)
        start = self.first_submit or 0.0
        evictions = sum(p.incarnation for p in self.orch.pods)
        return ExperimentResult(
            workload="", scheduler=self.orch.scheduler.name,
            rescheduler=self.orch.rescheduler.name,
            autoscaler=self.orch.autoscaler.name,
            completed=completed,
            cost=self.cost.total_cost(end),
            duration_s=end - start,
            median_pending_s=self.metrics.median_pending_s(),
            max_pending_s=self.metrics.max_pending_s(),
            avg_ram_ratio=self.metrics.avg_ram_ratio(),
            avg_cpu_ratio=self.metrics.avg_cpu_ratio(),
            avg_pods_per_node=self.metrics.avg_pods_per_node(),
            max_nodes=self.metrics.max_nodes(),
            node_seconds=self.cost.total_node_seconds(end),
            evictions=evictions,
            scale_outs=self.orch.total_scale_outs,
            scale_ins=self.orch.total_scale_ins,
            failures_injected=self.failures_injected,
        )
