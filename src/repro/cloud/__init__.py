from repro.cloud.adapter import (CloudAdapter, NodeTemplate, SimCloudProvider,
                                 M2_SMALL, TPU_V5E_HOST)

__all__ = ["CloudAdapter", "NodeTemplate", "SimCloudProvider", "M2_SMALL",
           "TPU_V5E_HOST"]
