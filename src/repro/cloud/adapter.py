"""Cloud adapters (paper §4.2 "Cloud Adapter" + Fig. 1 red components).

The paper implements an OpenStack adapter; we provide:

* `SimCloudProvider` — the provisioning-delay model used by the discrete-event
  evaluation (boot + join ≈ 50 s, the paper's own justification for
  ``provisioning_interval = 60 s``);
* `LocalCloudProvider` (repro.cloud.local_provider) — "nodes" are in-process
  worker slots executing *real JAX jobs*, used by the live examples.

Node templates cover the paper's Nectar m2.small worker and the fleet's
TPU v5e host.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Optional

from repro.core.autoscaler import NodeProvider
from repro.core.cluster import Node
from repro.core.cost import CostModel
from repro.core.resources import Resources, gi


@dataclasses.dataclass(frozen=True)
class NodeTemplate:
    """What one worker looks like when the autoscaler asks for one."""

    name: str
    allocatable: Resources
    provisioning_delay_s: float
    price_per_s: float = 0.011


# Paper testbed: Nectar m2.small (1 vCPU / 4 GB).  Allocatable is capacity
# minus kubelet/system reservations — calibrated so that, like on the paper's
# testbed, a service_large (2.359 Gi) + service_small (1 Gi) fill a node.
M2_SMALL = NodeTemplate(
    name="m2.small",
    allocatable=Resources(cpu_m=940, mem_mb=gi(3.5)),
    provisioning_delay_s=50.0,
)

# Nectar siblings (same family as repro.core.heterogeneous.NECTAR_CATALOG):
# the policy search's node-template axis — half-size and double-size workers
# at their catalog prices, so the cost objective responds to the mix choice.
M2_TINY = NodeTemplate(
    name="m2.tiny",
    allocatable=Resources(cpu_m=460, mem_mb=gi(1.5)),
    provisioning_delay_s=50.0,
    price_per_s=0.0055,
)

M2_MEDIUM = NodeTemplate(
    name="m2.medium",
    allocatable=Resources(cpu_m=1900, mem_mb=gi(5.5)),
    provisioning_delay_s=50.0,
    price_per_s=0.022,
)

# Fleet adaptation: one TPU v5e host = 4 chips x 16 GB HBM; chip milli-shares
# are the compressible axis, HBM the non-compressible one (DESIGN.md §2).
TPU_V5E_HOST = NodeTemplate(
    name="tpu-v5e-host",
    allocatable=Resources(cpu_m=4000, mem_mb=4 * 16 * 1024),
    provisioning_delay_s=120.0,
)

# Name -> template registry: `ExperimentSpec.template_name` (a picklable
# string — sweep/search cells cross process boundaries) resolves here.
NODE_TEMPLATES = {
    t.name: t for t in (M2_TINY, M2_SMALL, M2_MEDIUM, TPU_V5E_HOST)
}


class CloudAdapter(NodeProvider):
    """NodeProvider + billing wiring, shared by all adapters."""

    def __init__(self, template: NodeTemplate, cost: CostModel,
                 straggler_injector: Optional[object] = None):
        self.template = template
        self.cost = cost
        self.launched = 0
        # repro.core.failures.StragglerInjector (or None): applied to every
        # launched node so a deterministic fraction boots slow.
        self.straggler_injector = straggler_injector

    @abc.abstractmethod
    def _schedule_ready(self, node: Node, ready_at: float) -> None:
        """Backend-specific: deliver the node at `ready_at`."""

    def make_static_node(self, now: float = 0.0) -> Node:
        """A pre-existing (non-autoscaled) worker, READY immediately."""
        node = Node(allocatable=self.template.allocatable,
                    node_type=self.template.name, autoscaled=False,
                    provision_time=now)
        node.mark_ready(now)
        self.cost.on_provision(node, now)
        return node

    def launch_node(self, now: float) -> Node:
        node = Node(allocatable=self.template.allocatable,
                    node_type=self.template.name, autoscaled=True,
                    provision_time=now)
        if self.straggler_injector is not None:
            self.straggler_injector.maybe_slow(node)
        self.cost.on_provision(node, now)
        self.launched += 1
        self._schedule_ready(node, now + self.template.provisioning_delay_s)
        return node

    def terminate_node(self, node: Node, now: float) -> None:
        self.cost.on_deprovision(node, now)


class SimCloudProvider(CloudAdapter):
    """Provisioning-delay model for the discrete-event simulation."""

    def __init__(self, template: NodeTemplate, cost: CostModel,
                 straggler_injector: Optional[object] = None):
        super().__init__(template, cost, straggler_injector)
        self._sim = None

    def attach(self, sim) -> None:
        """Late-bound: the Simulation is constructed after the provider."""
        self._sim = sim

    def _schedule_ready(self, node: Node, ready_at: float) -> None:
        assert self._sim is not None, "SimCloudProvider.attach(sim) first"
        self._sim.schedule_node_ready(node, ready_at)
