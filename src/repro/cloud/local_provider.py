"""Live mode: the orchestrator drives *real JAX jobs* on in-process nodes.

This closes the loop the paper leaves at the platform boundary: a *node* is
a worker slot (capacity-accounted exactly like a sim node), a *batch pod*
is a real `repro.train.Trainer` running in a thread, and a *moveable
service* is a `ServeEngine`.  Eviction sends the cooperative stop signal;
the trainer checkpoints; the next binding resumes from the durable step on
whichever node the scheduler picks — the paper's recreate-by-controller
semantics, executed for real.

`LiveCluster.run()` is a wall-clock analogue of the discrete-event
simulator: a scheduler cycle every `cycle_period_s`, arrivals from a trace,
completion detection from the job threads.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core.autoscaler import Autoscaler, NodeProvider, VoidAutoscaler
from repro.core.cluster import Cluster, Node
from repro.core.cost import CostModel
from repro.core.orchestrator import Orchestrator
from repro.core.pods import Pod, PodKind, PodPhase, PodSpec
from repro.core.rescheduler import Rescheduler, VoidRescheduler
from repro.core.resources import Resources
from repro.core.scheduler import BestFitBinPackingScheduler, Scheduler


@dataclasses.dataclass
class LiveJob:
    """A real workload bound to a pod: factory builds a fresh runner each
    incarnation (the runner must resume from its own durable state)."""

    pod: Pod
    factory: Callable[[], object]     # -> object with run() and request_stop()
    runner: Optional[object] = None
    thread: Optional[threading.Thread] = None
    result: Optional[Dict] = None

    def start(self) -> None:
        self.runner = self.factory()

        def _run():
            self.result = self.runner.run()

        self.thread = threading.Thread(target=_run, daemon=True)
        self.thread.start()

    def stop(self, timeout: float = 30.0) -> None:
        if self.runner is not None and self.thread is not None:
            self.runner.request_stop()
            self.thread.join(timeout)

    @property
    def finished(self) -> bool:
        return (self.thread is not None and not self.thread.is_alive()
                and self.result is not None
                and self.result.get("completed") == 1.0)


class LocalCloudProvider(NodeProvider):
    """Nodes are process-local worker slots (instant provisioning by
    default; a delay can be configured to exercise the binding autoscaler)."""

    def __init__(self, template_resources: Resources, cost: CostModel,
                 provisioning_delay_s: float = 0.0):
        self.template_resources = template_resources
        self.cost = cost
        self.delay = provisioning_delay_s
        self.pending_ready: List[tuple] = []   # (node, ready_at)

    def make_static_node(self) -> Node:
        node = Node(allocatable=self.template_resources, autoscaled=False,
                    node_type="local")
        node.mark_ready(time.time())
        self.cost.on_provision(node, time.time())
        return node

    def launch_node(self, now: float) -> Node:
        node = Node(allocatable=self.template_resources, autoscaled=True,
                    node_type="local")
        self.cost.on_provision(node, time.time())
        self.pending_ready.append((node, time.time() + self.delay))
        return node

    def terminate_node(self, node: Node, now: float) -> None:
        self.cost.on_deprovision(node, time.time())

    def poll_ready(self, notify) -> None:
        now = time.time()
        still = []
        for node, ready_at in self.pending_ready:
            if now >= ready_at:
                node.mark_ready(now)
                notify(node)
            else:
                still.append((node, ready_at))
        self.pending_ready = still


class LiveCluster:
    """Wall-clock orchestration of real jobs (the paper's Algorithm 1)."""

    def __init__(self, provider: LocalCloudProvider,
                 scheduler: Optional[Scheduler] = None,
                 rescheduler: Optional[Rescheduler] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 cycle_period_s: float = 0.5,
                 log: Callable[[str], None] = print):
        self.provider = provider
        self.cluster = Cluster()
        self.orch = Orchestrator(
            self.cluster,
            scheduler or BestFitBinPackingScheduler(),
            rescheduler or VoidRescheduler(max_pod_age_s=1.0),
            autoscaler or VoidAutoscaler(provider))
        self.cycle_period_s = cycle_period_s
        self.jobs: Dict[int, LiveJob] = {}
        self.log = log

    def add_static_nodes(self, n: int) -> None:
        for _ in range(n):
            self.cluster.add_node(self.provider.make_static_node())

    def submit(self, spec: PodSpec, factory: Callable[[], object]) -> Pod:
        pod = Pod(spec=spec, submit_time=time.time())
        self.orch.submit(pod)
        self.jobs[pod.uid] = LiveJob(pod=pod, factory=factory)
        return pod

    # -- lifecycle wiring -------------------------------------------------------
    def _sync_jobs(self) -> None:
        """Start newly-bound jobs; stop evicted ones; reap completions."""
        for job in self.jobs.values():
            pod = job.pod
            if pod.phase == PodPhase.BOUND and job.thread is None:
                job.start()
                self.log(f"[live] {pod.name} started on {pod.node_id}")
            elif pod.phase == PodPhase.PENDING and job.thread is not None:
                # evicted (rescheduler/scale-in/failure): stop + checkpoint,
                # a fresh incarnation starts at the next binding
                job.stop()
                job.thread = None
                job.runner = None
                self.log(f"[live] {pod.name} evicted; checkpointed")
            elif (pod.phase == PodPhase.BOUND and pod.is_batch
                  and job.finished):
                self.cluster.complete(pod, time.time())
                self.log(f"[live] {pod.name} completed")

    def evict(self, pod: Pod) -> None:
        """External preemption (e.g. a failure drill)."""
        job = self.jobs[pod.uid]
        job.stop()
        job.thread = None
        job.runner = None
        self.cluster.unbind(pod, time.time())

    def run(self, until: Callable[[], bool], timeout_s: float = 600.0) -> bool:
        t0 = time.time()
        while time.time() - t0 < timeout_s:
            self.provider.poll_ready(self.orch.autoscaler.notify_node_ready)
            self.orch.cycle(time.time())
            self._sync_jobs()
            if until():
                return True
            time.sleep(self.cycle_period_s)
        return False

    def batch_done(self) -> bool:
        return all(j.pod.phase == PodPhase.SUCCEEDED
                   for j in self.jobs.values() if j.pod.is_batch)
