"""Beyond-paper ablation: the paper fixes best-fit bin packing (§6.1) — how
much of the saving is the *scheduler* vs the autoscaling machinery?
Swap in first-fit, worst-fit (Docker Swarm 'spread') and the default-K8s
scorer under the same NBR-BAS policies."""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from repro.core import ExperimentSpec, run_experiment


def run(seeds=(0, 1, 2), workload: str = "slow") -> List[Dict]:
    rows = []
    for sched in ("best-fit", "first-fit", "worst-fit", "k8s-default"):
        costs, rams = [], []
        t0 = time.time()
        for seed in seeds:
            r = run_experiment(ExperimentSpec(
                workload=workload, scheduler=sched,
                rescheduler="non-binding", autoscaler="binding", seed=seed))
            costs.append(r.cost)
            rams.append(r.avg_ram_ratio)
        rows.append({
            "scheduler": sched, "workload": workload,
            "cost_mean": statistics.fmean(costs),
            "ram_ratio": statistics.fmean(rams),
            "us_per_call": (time.time() - t0) / len(seeds) * 1e6,
        })
    return rows


def main() -> None:
    rows = run()
    base = next(r for r in rows if r["scheduler"] == "best-fit")
    for r in rows:
        delta = 100 * (r["cost_mean"] / base["cost_mean"] - 1)
        print(f"ablation/{r['workload']}/{r['scheduler']},"
              f"{r['us_per_call']:.0f},"
              f"cost=${r['cost_mean']:.2f}({delta:+.1f}%);"
              f"ram={r['ram_ratio']:.2f}")


if __name__ == "__main__":
    main()
