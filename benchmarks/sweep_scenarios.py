"""Scenario sweep harness: scheduler × autoscaler × scenario grid.

Runs every cell of a policy×workload grid through the `repro.search`
cell runner with columnar trace replay (``repro.scenarios``) and emits a
Fig-3-style, machine-readable table: per-cell cost, scheduling duration,
pending-time stats and Table-5 utilization ratios.  This is how the
paper's cost-efficiency claims are checked *beyond* its three 50-job
workloads — the default grid covers six scenario families (diurnal,
flash-crowd MMPP, heavy-tailed durations, batch→service mix ramp,
autoscaler stress, multi-tenant composition) at thousands of jobs per
trace.

Cells are hermetic (`repro.search.runner`), so ``--pool N`` fans the
grid over N worker processes with **bit-identical** results to the
serial run — same floats, same row order.

Usage::

    python benchmarks/sweep_scenarios.py                  # full default grid
    python benchmarks/sweep_scenarios.py --smoke          # CI smoke (seconds)
    python benchmarks/sweep_scenarios.py --pool 8         # 8 worker processes
    python benchmarks/sweep_scenarios.py \
        --scenarios diurnal,heavy-tail --schedulers best-fit \
        --autoscalers binding --jobs 5000

Writes ``SWEEP_scenarios.json`` (override with ``--out``); prints
``name,us_per_call,derived`` CSV lines like the other benches (one line
per cell: wall-clock µs, cost).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.search.runner import CellSpec, run_cells

DEFAULT_SCENARIOS = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp",
                     "scale-stress", "multi-tenant")
DEFAULT_SCHEDULERS = ("best-fit", "k8s-default", "first-fit", "worst-fit")
DEFAULT_AUTOSCALERS = ("binding", "non-binding", "predictive")

SMOKE_SCENARIOS = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp")
SMOKE_SCHEDULERS = ("best-fit", "k8s-default")
SMOKE_JOBS = 300
DEFAULT_JOBS = 1500


def format_row(row: dict) -> dict:
    """One report row, rounded for the committed artifact (the raw
    runner row keeps full precision for bit-parity tests)."""
    cell = row["cell"]
    return {
        "scenario": cell["scenario"], "scheduler": cell["scheduler"],
        "autoscaler": cell["autoscaler"], "rescheduler": cell["rescheduler"],
        "n_jobs": row["n_jobs"], "completed": row["completed"],
        "cost": round(row["cost"], 3),
        "duration_s": round(row["duration_s"], 1),
        "mean_pending_s": round(row["mean_pending_s"], 3),
        "median_pending_s": round(row["median_pending_s"], 3),
        "max_pending_s": round(row["max_pending_s"], 3),
        "avg_ram_ratio": round(row["avg_ram_ratio"], 4),
        "avg_cpu_ratio": round(row["avg_cpu_ratio"], 4),
        "avg_pods_per_node": round(row["avg_pods_per_node"], 3),
        "max_nodes": row["max_nodes"],
        "node_seconds": row["node_seconds"],
        "evictions": row["evictions"],
        "scale_outs": row["scale_outs"], "scale_ins": row["scale_ins"],
        "failures_injected": row["failures_injected"],
        "preemption_notices": row["preemption_notices"],
        "lost_work_s": round(row["lost_work_s"], 3),
        "wall_s": round(row["wall_s"], 3),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    # Defaults resolve after parsing so --smoke can shrink whichever axes
    # the caller did NOT set explicitly (an explicit axis always wins).
    ap.add_argument("--scenarios",
                    help=f"default {','.join(DEFAULT_SCENARIOS)}")
    ap.add_argument("--schedulers",
                    help=f"default {','.join(DEFAULT_SCHEDULERS)}")
    ap.add_argument("--autoscalers",
                    help=f"default {','.join(DEFAULT_AUTOSCALERS)}")
    # "non-binding" reproduces the paper's full Alg. 3/4 chain by default;
    # the shadow-capacity cache (repro.core.rescheduler) keeps backlog-heavy
    # cells (flash-crowd, scale-stress) tractable.  Pass --rescheduler void
    # to sweep scheduling/autoscaling alone.
    ap.add_argument("--rescheduler", default="non-binding")
    ap.add_argument("--jobs", type=int, default=None,
                    help=f"trace length per scenario (default {DEFAULT_JOBS})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pool", type=int, default=0, metavar="N",
                    help="run cells on N worker processes (bit-identical "
                         "to serial; 0/1 = in-process)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI grid: "
                         f"{len(SMOKE_SCENARIOS)}x{len(SMOKE_SCHEDULERS)}x2 "
                         f"at {SMOKE_JOBS} jobs, runs in seconds")
    ap.add_argument("--out", default="SWEEP_scenarios.json")
    args = ap.parse_args(argv)

    def axis(value, default):
        return tuple(s for s in value.split(",") if s) if value else default

    scenarios = axis(args.scenarios,
                     SMOKE_SCENARIOS if args.smoke else DEFAULT_SCENARIOS)
    schedulers = axis(args.schedulers,
                      SMOKE_SCHEDULERS if args.smoke else DEFAULT_SCHEDULERS)
    autoscalers = axis(args.autoscalers, DEFAULT_AUTOSCALERS)
    n_jobs = args.jobs or (SMOKE_JOBS if args.smoke else DEFAULT_JOBS)

    # One trace per (scenario, seed, n_jobs) key, memoized per process by
    # the runner — same jobs, same floats, cells differ only by policy.
    specs = [CellSpec(scenario=scenario, scheduler=scheduler,
                      autoscaler=autoscaler, rescheduler=args.rescheduler,
                      seed=args.seed, n_jobs=n_jobs)
             for scenario in scenarios
             for scheduler in schedulers
             for autoscaler in autoscalers]
    rows = run_cells(specs, workers=args.pool)
    cells = []
    for spec, row in zip(specs, rows):
        cell = format_row(row)
        cells.append(cell)
        print(f"sweep.{spec.scenario}.{spec.scheduler}.{spec.autoscaler},"
              f"{1e6 * cell['wall_s']:.0f},{cell['cost']}")

    report = {
        "bench": "sweep_scenarios",
        "generated_unix_s": int(time.time()),
        "grid": {"scenarios": list(scenarios),
                 "schedulers": list(schedulers),
                 "autoscalers": list(autoscalers),
                 "rescheduler": args.rescheduler,
                 "n_jobs": n_jobs, "seed": args.seed,
                 "pool": args.pool},
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    n_done = sum(c["completed"] for c in cells)
    print(f"# wrote {args.out} ({n_done}/{len(cells)} cells completed)")
    return report


if __name__ == "__main__":
    main()
