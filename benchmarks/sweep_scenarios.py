"""Scenario sweep harness: scheduler × autoscaler × scenario grid.

Runs every cell of a policy×workload grid through ``run_experiment`` with
columnar trace replay (``repro.scenarios``) and emits a Fig-3-style,
machine-readable table: per-cell cost, scheduling duration, pending-time
stats and Table-5 utilization ratios.  This is how the paper's
cost-efficiency claims are checked *beyond* its three 50-job workloads —
the default grid covers six scenario families (diurnal, flash-crowd MMPP,
heavy-tailed durations, batch→service mix ramp, autoscaler stress,
multi-tenant composition) at thousands of jobs per trace.

Usage::

    python benchmarks/sweep_scenarios.py                  # full default grid
    python benchmarks/sweep_scenarios.py --smoke          # CI smoke (seconds)
    python benchmarks/sweep_scenarios.py \
        --scenarios diurnal,heavy-tail --schedulers best-fit \
        --autoscalers binding --jobs 5000

Writes ``SWEEP_scenarios.json`` (override with ``--out``); prints
``name,us_per_call,derived`` CSV lines like the other benches (one line
per cell: wall-clock µs, cost).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import ExperimentSpec, reset_id_counters, run_experiment
from repro.scenarios import build_scenario

DEFAULT_SCENARIOS = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp",
                     "scale-stress", "multi-tenant")
DEFAULT_SCHEDULERS = ("best-fit", "k8s-default", "first-fit", "worst-fit")
DEFAULT_AUTOSCALERS = ("binding", "non-binding")

SMOKE_SCENARIOS = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp")
SMOKE_SCHEDULERS = ("best-fit", "k8s-default")
SMOKE_JOBS = 300
DEFAULT_JOBS = 1500


def run_cell(trace, scheduler: str, autoscaler: str, rescheduler: str,
             seed: int) -> dict:
    # Fresh id counters per cell: every cell's tie-breaks (node ids order
    # lexicographically) depend only on its own run, so cells are
    # reproducible in isolation and in any grid order.
    reset_id_counters()
    spec = ExperimentSpec(trace=trace, scheduler=scheduler,
                          autoscaler=autoscaler, rescheduler=rescheduler,
                          seed=seed)
    t0 = time.perf_counter()
    r = run_experiment(spec)
    wall = time.perf_counter() - t0
    return {
        "scenario": r.workload, "scheduler": scheduler,
        "autoscaler": autoscaler, "rescheduler": rescheduler,
        "n_jobs": trace.n, "completed": r.completed,
        "cost": round(r.cost, 3),
        "duration_s": round(r.duration_s, 1),
        "median_pending_s": round(r.median_pending_s, 3),
        "max_pending_s": round(r.max_pending_s, 3),
        "avg_ram_ratio": round(r.avg_ram_ratio, 4),
        "avg_cpu_ratio": round(r.avg_cpu_ratio, 4),
        "avg_pods_per_node": round(r.avg_pods_per_node, 3),
        "max_nodes": r.max_nodes,
        "node_seconds": r.node_seconds,
        "evictions": r.evictions,
        "scale_outs": r.scale_outs, "scale_ins": r.scale_ins,
        "failures_injected": r.failures_injected,
        "preemption_notices": r.preemption_notices,
        "lost_work_s": round(r.lost_work_s, 3),
        "wall_s": round(wall, 3),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    # Defaults resolve after parsing so --smoke can shrink whichever axes
    # the caller did NOT set explicitly (an explicit axis always wins).
    ap.add_argument("--scenarios",
                    help=f"default {','.join(DEFAULT_SCENARIOS)}")
    ap.add_argument("--schedulers",
                    help=f"default {','.join(DEFAULT_SCHEDULERS)}")
    ap.add_argument("--autoscalers",
                    help=f"default {','.join(DEFAULT_AUTOSCALERS)}")
    # "void" by default: the rescheduling policies run a shadow-capacity
    # pass per blocked pod per cycle, which multiplies wall time on
    # scenarios that intentionally build deep backlogs (flash-crowd,
    # scale-stress under the rate-limited non-binding autoscaler).  Pass
    # --rescheduler binding|non-binding for the full paper-style chain.
    ap.add_argument("--rescheduler", default="void")
    ap.add_argument("--jobs", type=int, default=None,
                    help=f"trace length per scenario (default {DEFAULT_JOBS})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI grid: "
                         f"{len(SMOKE_SCENARIOS)}x{len(SMOKE_SCHEDULERS)}x2 "
                         f"at {SMOKE_JOBS} jobs, runs in seconds")
    ap.add_argument("--out", default="SWEEP_scenarios.json")
    args = ap.parse_args(argv)

    def axis(value, default):
        return tuple(s for s in value.split(",") if s) if value else default

    scenarios = axis(args.scenarios,
                     SMOKE_SCENARIOS if args.smoke else DEFAULT_SCENARIOS)
    schedulers = axis(args.schedulers,
                      SMOKE_SCHEDULERS if args.smoke else DEFAULT_SCHEDULERS)
    autoscalers = axis(args.autoscalers, DEFAULT_AUTOSCALERS)
    n_jobs = args.jobs or (SMOKE_JOBS if args.smoke else DEFAULT_JOBS)

    cells = []
    for scenario in scenarios:
        # One trace per scenario, replayed read-only across every cell —
        # same jobs, same floats, so cells differ only by policy.
        trace = build_scenario(scenario, seed=args.seed, n_jobs=n_jobs)
        for scheduler in schedulers:
            for autoscaler in autoscalers:
                cell = run_cell(trace, scheduler, autoscaler,
                                args.rescheduler, args.seed)
                cells.append(cell)
                print(f"sweep.{scenario}.{scheduler}.{autoscaler},"
                      f"{1e6 * cell['wall_s']:.0f},{cell['cost']}")

    report = {
        "bench": "sweep_scenarios",
        "generated_unix_s": int(time.time()),
        "grid": {"scenarios": list(scenarios),
                 "schedulers": list(schedulers),
                 "autoscalers": list(autoscalers),
                 "rescheduler": args.rescheduler,
                 "n_jobs": n_jobs, "seed": args.seed},
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    n_done = sum(c["completed"] for c in cells)
    print(f"# wrote {args.out} ({n_done}/{len(cells)} cells completed)")
    return report


if __name__ == "__main__":
    main()
