"""Benchmark harness: one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines (one per measured cell).
  * fig3   — cost & scheduling duration, 6 policy combos x 3 workloads
  * fig4   — cost reduction vs. default-K8s static baseline (58 % headline)
  * table5 — median pending time, RAM/CPU req/cap ratios, pods/node
  * roofline — three-term roofline per (arch x shape) from dry-run artifacts

``bench_sched_throughput.py`` (run directly, not via this harness) measures
the simulator's scheduler-cycle throughput — array engine vs. the seed
object-scan engine — at small/medium/large scales (up to 2k nodes x 50k
pods) and writes ``BENCH_sched.json``; ``make check`` runs its small-scale
smoke so cycle-path perf regressions fail CI.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (ablation_schedulers, fig3_cost_duration,
                            fig4_vs_k8s, roofline, table5_utilization)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    benches = {
        "fig3": fig3_cost_duration.main,
        "fig4": fig4_vs_k8s.main,
        "table5": table5_utilization.main,
        "ablation": ablation_schedulers.main,
        "roofline": roofline.main,
    }
    for name, fn in benches.items():
        if only and name != only:
            continue
        print(f"# --- {name} ---")
        fn()


if __name__ == '__main__':
    main()
