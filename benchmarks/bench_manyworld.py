"""Many-world lane evaluator benchmark: batched JAX lanes vs serial cells.

Measures the throughput of `repro.manyworld.run_cells_lanes` — thousands
of independent void/void cells lowered into one jitted fixed-shape cycle
program — against the serial `run_cell` reference on the same cell specs
(heavy-tail, best-fit, 40 jobs, 4 static nodes; one lane per seed).

Because the lane engine is bit-identical to the serial engine inside its
relaxed envelope (see ``tests/test_manyworld.py``), each lane performs
the same scheduling decisions as its serial twin — so lanes/second vs
cells/second is an apples-to-apples comparison.  The bench asserts that
parity on a row subset before reporting numbers.

Per lane count it records the *cold* wall (first call: jit trace +
compile for that ``(lanes, pods, nodes)`` shape) separately from the
*warm* wall (compile cache hit — the steady state a policy search lives
in), and derives ``speedup_vs_serial`` from the warm wall against the
serial per-cell time measured in the same process.

Usage::

    python benchmarks/bench_manyworld.py                     # 64/256/1024
    python benchmarks/bench_manyworld.py --lanes 256         # CI smoke
    python benchmarks/bench_manyworld.py --out /tmp/b.json   # elsewhere

Merges a ``manyworld`` entry into ``BENCH_sched.json`` (override with
``--out``; existing keys are preserved); prints
``name,us_per_call,derived`` CSV lines like the other benches.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.search.runner import CellSpec, run_cell

# One lane per seed: same scenario shape, different arrival realization —
# the policy-search shape (`run_cells(..., workers="lanes")` buckets
# these into a single (lanes, 64-pod, 4-node) jit program).
BENCH_SCENARIO = "heavy-tail"
BENCH_N_JOBS = 40
BENCH_NODES = 4
SERIAL_CELLS = 24
WARM_REPEATS = 3


def _cells(n_lanes: int):
    return [CellSpec(scenario=BENCH_SCENARIO, scheduler="best-fit",
                     autoscaler="void", rescheduler="void", seed=seed,
                     n_jobs=BENCH_N_JOBS, initial_workers=BENCH_NODES)
            for seed in range(n_lanes)]


def _strip(rows):
    # wall_s is timing, not behavior: serial measures one cell, a lane
    # reports its share of the batch wall.
    return [{k: v for k, v in r.items() if k != "wall_s"} for r in rows]


def bench_manyworld(lane_counts=(64, 256, 1024),
                    serial_cells=SERIAL_CELLS) -> dict:
    from repro.manyworld.evaluator import run_cells_lanes

    out = {
        "scenario": BENCH_SCENARIO, "n_jobs": BENCH_N_JOBS,
        "nodes": BENCH_NODES, "scheduler": "best-fit",
        "serial_cells_measured": serial_cells, "per_lanes": {},
    }
    # Serial baseline: per-cell wall over `serial_cells` cells, traces
    # pre-warmed (the lane path shares the same per-process trace cache,
    # so neither side is billed for scenario generation).
    sub = _cells(serial_cells)
    serial_rows = [run_cell(c) for c in sub]    # warm traces + result set
    serial_samples = []
    for _ in range(WARM_REPEATS):
        gc.collect()
        t0 = time.perf_counter()
        for cell in sub:
            run_cell(cell)
        serial_samples.append((time.perf_counter() - t0) / serial_cells)
    serial_per_cell_s = sorted(serial_samples)[len(serial_samples) // 2]
    out["serial_ms_per_cell"] = round(1e3 * serial_per_cell_s, 3)
    print(f"bench_manyworld.serial,{1e6 * serial_per_cell_s:.0f},"
          f"{1.0 / serial_per_cell_s:.0f}")

    for n_lanes in lane_counts:
        cells = _cells(n_lanes)
        gc.collect()
        t0 = time.perf_counter()
        rows = run_cells_lanes(cells)
        cold_s = time.perf_counter() - t0
        # Median of WARM_REPEATS: single samples wobble with box state
        # (same rationale as the sched bench's full_run/small medians).
        warm_samples = []
        for _ in range(WARM_REPEATS):
            t0 = time.perf_counter()
            rows = run_cells_lanes(cells)
            warm_samples.append(time.perf_counter() - t0)
        warm_s = sorted(warm_samples)[len(warm_samples) // 2]
        # Parity guard: the lanes must reproduce the serial rows bit-for-
        # bit, else the "same work" premise of the comparison is void.
        n_check = min(n_lanes, serial_cells)
        assert _strip(rows[:n_check]) == _strip(serial_rows[:n_check]), (
            f"lane rows diverged from serial rows at {n_lanes} lanes")
        assert all(r["completed"] for r in rows), "a bench lane ran to horizon"
        lanes_per_s = n_lanes / warm_s
        speedup = serial_per_cell_s * n_lanes / warm_s
        out["per_lanes"][str(n_lanes)] = {
            "cold_s": round(cold_s, 3),
            "warm_s": round(warm_s, 3),
            "lanes_per_s": round(lanes_per_s, 1),
            "speedup_vs_serial": round(speedup, 2),
        }
        print(f"bench_manyworld.lanes{n_lanes},{1e6 * warm_s:.0f},"
              f"{speedup:.2f}")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lanes", default="64,256,1024",
                    help="comma-separated lane counts to bench")
    ap.add_argument("--serial-cells", type=int, default=SERIAL_CELLS)
    ap.add_argument("--out", default="BENCH_sched.json")
    args = ap.parse_args(argv)
    lane_counts = tuple(int(x) for x in args.lanes.split(",") if x.strip())
    if not lane_counts:
        ap.error(f"--lanes must name at least one lane count "
                 f"(got {args.lanes!r})")

    report = bench_manyworld(lane_counts, serial_cells=args.serial_cells)
    report["generated_unix_s"] = int(time.time())
    # Merge, don't overwrite: the entry lives alongside the sched-
    # throughput report in the same committed baseline file.
    data = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            data = json.load(f)
    data["manyworld"] = report
    with open(args.out, "w") as f:
        json.dump(data, f, indent=2)
    print(f"# wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
