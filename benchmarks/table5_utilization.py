"""Paper Table 5: median scheduling time, RAM/CPU request-to-capacity
ratios, pods per node — per rescheduler x autoscaler combination."""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from repro.core import run_all_combos


def run(seeds=(0, 1, 2), workloads=("mixed", "slow", "bursty")) -> List[Dict]:
    rows = []
    for wl in workloads:
        acc: Dict[str, Dict[str, List[float]]] = {}
        t0 = time.time()
        for seed in seeds:
            for r in run_all_combos(wl, seed=seed):
                d = acc.setdefault(r.combo(), {k: [] for k in
                                               ("pend", "ram", "cpu", "ppn")})
                d["pend"].append(r.median_pending_s)
                d["ram"].append(r.avg_ram_ratio)
                d["cpu"].append(r.avg_cpu_ratio)
                d["ppn"].append(r.avg_pods_per_node)
        elapsed = (time.time() - t0) / max(len(seeds) * 6, 1)
        for combo, d in acc.items():
            rows.append({
                "workload": wl, "combo": combo,
                "median_pending_s": statistics.fmean(d["pend"]),
                "ram_ratio": statistics.fmean(d["ram"]),
                "cpu_ratio": statistics.fmean(d["cpu"]),
                "pods_per_node": statistics.fmean(d["ppn"]),
                "us_per_call": elapsed * 1e6,
            })
    return rows


def main() -> None:
    for row in run():
        print(f"table5/{row['workload']}/{row['combo']},"
              f"{row['us_per_call']:.0f},"
              f"pend={row['median_pending_s']:.1f}s;"
              f"ram={row['ram_ratio']:.2f};cpu={row['cpu_ratio']:.2f};"
              f"ppn={row['pods_per_node']:.2f}")


if __name__ == "__main__":
    main()
