"""Paper Fig. 4: best rescheduler/autoscaler combos vs. the default-K8s
static baseline — reproduces the cost-reduction headline (paper: >58 % on
the slow workload, NBR-BAS)."""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from repro.core import run_all_combos, run_k8s_baseline


def run(seeds=(0, 1, 2, 3, 4, 5),
        workloads=("bursty", "slow", "mixed")) -> List[Dict]:
    rows = []
    for wl in workloads:
        saves: Dict[str, List[float]] = {}
        durs: Dict[str, List[float]] = {}
        k8s_costs = []
        t0 = time.time()
        for seed in seeds:
            k8s = run_k8s_baseline(wl, seed=seed)
            k8s_costs.append(k8s.cost)
            for r in run_all_combos(wl, seed=seed):
                saves.setdefault(r.combo(), []).append(
                    100.0 * (1 - r.cost / k8s.cost))
                durs.setdefault(r.combo(), []).append(
                    r.duration_s - k8s.duration_s)
        elapsed = (time.time() - t0) / max(len(seeds), 1)
        # paper compares the two best-scoring combos per workload
        ranked = sorted(saves, key=lambda c: -statistics.fmean(saves[c]))
        for combo in ranked:
            rows.append({
                "workload": wl, "combo": combo,
                "save_mean_pct": statistics.fmean(saves[combo]),
                "save_max_pct": max(saves[combo]),
                "extra_duration_s": statistics.fmean(durs[combo]),
                "k8s_cost_mean": statistics.fmean(k8s_costs),
                "rank": ranked.index(combo),
                "us_per_call": elapsed * 1e6,
            })
    return rows


def main() -> None:
    rows = run()
    for row in rows:
        print(f"fig4/{row['workload']}/{row['combo']},"
              f"{row['us_per_call']:.0f},"
              f"save={row['save_mean_pct']:.1f}%(max {row['save_max_pct']:.1f}%);"
              f"extra_dur={row['extra_duration_s']:+.0f}s")
    best_slow = max((r for r in rows if r["workload"] == "slow"),
                    key=lambda r: r["save_mean_pct"])
    print(f"fig4/headline,0,slow best combo {best_slow['combo']} saves "
          f"{best_slow['save_mean_pct']:.1f}% (paper claims >58%)")


if __name__ == "__main__":
    main()
