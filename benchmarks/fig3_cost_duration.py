"""Paper Fig. 3: cost + scheduling duration for the six rescheduler x
autoscaler combinations on each workload (multi-seed)."""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from repro.core import run_all_combos


def run(seeds=(0, 1, 2), workloads=("bursty", "slow", "mixed")) -> List[Dict]:
    rows = []
    for wl in workloads:
        per_combo: Dict[str, Dict[str, List[float]]] = {}
        t0 = time.time()
        for seed in seeds:
            for r in run_all_combos(wl, seed=seed):
                d = per_combo.setdefault(r.combo(), {"cost": [], "dur": []})
                d["cost"].append(r.cost)
                d["dur"].append(r.duration_s)
        elapsed = (time.time() - t0) / max(len(seeds) * 6, 1)
        for combo, d in per_combo.items():
            rows.append({
                "workload": wl, "combo": combo,
                "cost_mean": statistics.fmean(d["cost"]),
                "cost_stdev": statistics.stdev(d["cost"]) if len(d["cost"]) > 1 else 0.0,
                "duration_mean_s": statistics.fmean(d["dur"]),
                "us_per_call": elapsed * 1e6,
            })
    return rows


def main() -> None:
    for row in run():
        print(f"fig3/{row['workload']}/{row['combo']},"
              f"{row['us_per_call']:.0f},"
              f"cost=${row['cost_mean']:.2f}±{row['cost_stdev']:.2f};"
              f"dur={row['duration_mean_s']:.0f}s")


if __name__ == "__main__":
    main()
