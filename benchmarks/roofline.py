"""§Roofline: three-term roofline per (arch x shape) on the single-pod mesh.

Reads the dry-run artifacts (memory + while-aware collective bytes) and the
compositional cost probes (scan-corrected FLOPs/bytes), then derives:

  compute    = FLOPs / (chips x 197 TFLOP/s bf16)
  memory     = HLO bytes / (chips x 819 GB/s HBM)
  collective = collective bytes / (chips x 50 GB/s/link ICI)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve) with N = active params, and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs.  All terms are reported in
seconds per step; the dominant term is the bottleneck the §Perf loop works
on.  FLOPs/bytes from cost_analysis/probes are per-device; collective bytes
are per-device as parsed from post-SPMD HLO.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, list_archs
from repro.launch.shapes import SHAPES, applicable

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
# XLA:CPU HloCostAnalysis counts 1 "flop" per multiply-accumulate; doubling
# recovers true FLOPs (calibrated on a single unrolled layer vs the analytic
# count: 2x matches to within 1.3%).
FMA_FACTOR = 2.0

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts")


def _load(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D for training, 2·N_active·D for serving (global).
    N from the real parameter tree; MoE subtracts inactive routed experts."""
    from repro.models.params import count_params
    from repro.models import transformer as _tf
    cfg = get_config(arch)
    n = count_params(_tf.model_specs(cfg))
    if cfg.n_experts:
        ff = cfg.moe_d_ff or cfg.d_ff
        inactive = (cfg.n_experts - cfg.experts_per_token) * \
            3 * cfg.d_model * ff
        n -= (cfg.num_layers - cfg.first_k_dense) * inactive
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per row


def rows(tag: str = "") -> List[Dict]:
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name in SHAPES:
            if not applicable(cfg, SHAPES[shape_name])[0]:
                continue
            suffix = f"__{tag}" if tag else ""
            dry = _load(os.path.join(
                ART, "dryrun", f"{arch}__{shape_name}__single{suffix}.json"))
            probe = _load(os.path.join(
                ART, "costprobe", f"{arch}__{shape_name}{suffix}.json"))
            if dry is None:
                continue
            chips = dry["devices"]
            # the gradient-accumulation microbatch loop is a lax.scan whose
            # body XLA's cost analysis counts once — scale train cells by
            # the accumulation factor (the loop is homogeneous).
            accum = cfg.train_accum if SHAPES[shape_name].kind == "train" \
                else 1
            flops_dev = accum * FMA_FACTOR * (probe or {}).get(
                "flops_per_device_full", dry["cost"]["flops_per_device"])
            bytes_dev = accum * (probe or {}).get(
                "bytes_per_device_full", dry["cost"]["bytes_per_device"])
            coll_dev = dry["collectives_per_device"]["total"]
            t_compute = flops_dev / PEAK_FLOPS
            t_memory = bytes_dev / HBM_BW
            t_coll = coll_dev / ICI_BW
            dominant = max(
                (("compute", t_compute), ("memory", t_memory),
                 ("collective", t_coll)), key=lambda kv: kv[1])[0]
            mf = model_flops(arch, shape_name)
            hlo_flops_global = flops_dev * chips
            out.append({
                "arch": arch, "shape": shape_name, "chips": chips,
                "compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "dominant": dominant,
                "model_flops": mf,
                "useful_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
                "roofline_bound_s": max(t_compute, t_memory, t_coll),
                "roofline_fraction": t_compute / max(t_compute, t_memory,
                                                     t_coll, 1e-30),
                "fits_hbm": dry["memory"]["peak_estimate_bytes"] < 16 * 2**30,
                "temp_gib": dry["memory"]["temp_bytes"] / 2**30,
                "probe": probe is not None,
            })
    return out


def main() -> None:
    table = rows()
    if not table:
        print("roofline/missing,0,run launch.dryrun + launch.costprobe first")
        return
    for r in table:
        print(f"roofline/{r['arch']}/{r['shape']},0,"
              f"compute={r['compute_s']*1e3:.1f}ms;"
              f"memory={r['memory_s']*1e3:.1f}ms;"
              f"collective={r['collective_s']*1e3:.1f}ms;"
              f"dominant={r['dominant']};"
              f"useful={100*r['useful_ratio']:.0f}%;"
              f"roofline_frac={100*r['roofline_fraction']:.0f}%;"
              f"temp={r['temp_gib']:.1f}GiB;fits={int(r['fits_hbm'])}")


if __name__ == "__main__":
    main()
