"""Scheduler-cycle throughput benchmark: array engine vs. seed object scans.

Measures the end-to-end cycle hot path of the discrete-event simulator —
pending-queue snapshot, wave placement (cached-buffer select + once-per-wave
``bind_wave`` commit) on the array engine vs. per-pod filter+select+bind on
the object engine, plus scale-in — on synthetic batch workloads at three
scales:

* ``small``  —    50 nodes x  2,000 pods (CI smoke; both engines run fully)
* ``medium`` —   500 nodes x 10,000 pods
* ``large``  — 2,000 nodes x 50,000 pods (the ROADMAP's production regime)

Because the two engines are bit-for-bit behaviour-identical (see
``tests/test_engine_parity.py``), cycle *i* performs identical scheduling
work under both — so cycle throughput (pods bound per second of cycle
compute, measured over the same post-warmup cycle window) is an
apples-to-apples comparison.  The object engine is capped to a bounded
number of cycles at the larger scales; the array engine additionally runs
the workload to completion for an end-to-end pods/second figure.

The array engine additionally runs each capped scale to completion for an
**end-to-end full-run** figure (arrival batching + bucketed completions +
incremental Table-5 sampling all live outside the capped cycle window, so
the full run is where they show up); the large scale records the speedup
against PR 2's committed wall time.  ``--kernels`` re-measures the
argmin-vs-segment-tree wave-selection crossover that calibrates
``engine.SEGTREE_AUTO_MIN_NODES``.

Usage::

    python benchmarks/bench_sched_throughput.py                  # all scales
    python benchmarks/bench_sched_throughput.py --scale small    # CI smoke
    python benchmarks/bench_sched_throughput.py --engines array  # skip seed
    python benchmarks/bench_sched_throughput.py --kernels        # + crossover
    python benchmarks/bench_sched_throughput.py --trace-replay   # + 100k trace

``--trace-replay`` (implied by ``--scale all``) replays a 100k-arrival
generated scenario (``repro.scenarios``) end-to-end through the columnar
ingest path — the regression gate for trace-native submission.

Writes ``BENCH_sched.json`` (override with ``--out``); prints
``name,us_per_call,derived`` CSV lines like the other benches.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (Arrival, ExperimentSpec, PodKind, PodSpec,
                        Resources, gi, reset_id_counters)
from repro.core.experiment import build_simulation
from repro.core.simulation import SimConfig

# Average pod: 200m CPU / 614.4 MB on a 940m/3.5Gi node -> CPU binds first
# at ~4.7 pods/node.  Arrival rate targets ~70% steady-state occupancy.
_BATCH_TYPES = [
    PodSpec("bench_small", PodKind.BATCH, Resources(100, gi(0.3)),
            duration_s=120.0),
    PodSpec("bench_med", PodKind.BATCH, Resources(200, gi(0.6)),
            duration_s=180.0),
    PodSpec("bench_large", PodKind.BATCH, Resources(300, gi(0.9)),
            duration_s=240.0),
]
_AVG_CPU_M = 200.0
_AVG_DURATION_S = 180.0
_NODE_CPU_M = 940.0

SCALES = {
    #          nodes   pods   object-engine cycle cap (None = full run)
    "small": dict(nodes=50, pods=2_000, object_cap=None),
    "medium": dict(nodes=500, pods=10_000, object_cap=60),
    "large": dict(nodes=2_000, pods=50_000, object_cap=25),
}
WARMUP_CYCLES = 5
FULL_RUN_REPEATS = 3
# The small scale is the ci.sh regression smoke: like full_run, a single
# sample wobbles far more (observed ±25% on the 1-core container class)
# than the effects the -30% gate wants to resolve, so its per-engine
# measurement is the median of SMALL_SMOKE_REPEATS runs (cheap: ~0.3 s
# per array run).  The larger scales stay single-shot — their object-
# engine runs are the expensive part and they are not absolute-gated.
SMALL_SMOKE_REPEATS = 3

# Committed end-to-end full-run wall times at the large scale: PR 2
# (BENCH_sched.json @ ba0bc49, the telemetry/timeline reference) and PR 3
# (BENCH_sched.json @ b863234, the PodStore/SoA-pod-state reference).
PR2_FULL_RUN_WALL_S = {"large": 1.414}
PR3_FULL_RUN_WALL_S = {"large": 0.63}


def synth_arrivals(n_pods: int, n_nodes: int, seed: int = 0,
                   target_util: float = 0.7):
    """Poisson batch arrivals sized to keep the cluster ~target_util busy."""
    concurrency = target_util * n_nodes * (_NODE_CPU_M / _AVG_CPU_M)
    rate = concurrency / _AVG_DURATION_S
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_pods)
    times = np.cumsum(gaps)
    kinds = rng.integers(0, len(_BATCH_TYPES), size=n_pods)
    return [Arrival(float(t), _BATCH_TYPES[int(k)])
            for t, k in zip(times, kinds)]


def run_one(scale: str, engine: str, max_cycles=None) -> dict:
    # Fresh global id counters per run: both engines must start from the
    # same counter to perform identical per-cycle work (node ids order
    # lexicographically — same reason as test_engine_parity).
    reset_id_counters()
    # Measurement isolation (applies to every engine/scale equally): don't
    # let garbage from the previous run's ~50k-object graph bill its
    # collection pauses to this run's wall clock.
    gc.collect()

    cfg = SCALES[scale]
    spec = ExperimentSpec(
        workload=f"bench-{scale}", scheduler="best-fit", rescheduler="void",
        autoscaler="void", static_workers=cfg["nodes"], engine=engine,
        arrivals=synth_arrivals(cfg["pods"], cfg["nodes"]))
    sim = build_simulation(spec)
    sim.config = SimConfig(cycle_period_s=10.0, max_cycles=max_cycles,
                           record_cycle_times=True)
    t0 = time.perf_counter()
    result = sim.run()
    wall = time.perf_counter() - t0

    walls = np.asarray(sim.cycle_wall_s[WARMUP_CYCLES:])
    placed = np.asarray(sim.cycle_placed[WARMUP_CYCLES:])
    cycle_s = float(walls.sum()) if walls.size else 0.0
    out = {
        "engine": engine,
        "cycles": sim.n_cycles,
        "pods_placed_measured": int(placed.sum()),
        "cycle_compute_s": round(cycle_s, 4),
        "mean_cycle_ms": round(1e3 * float(walls.mean()), 3) if walls.size else 0.0,
        "p95_cycle_ms": round(1e3 * float(np.percentile(walls, 95)), 3) if walls.size else 0.0,
        "cycle_throughput_pods_per_s":
            round(float(placed.sum()) / cycle_s, 1) if cycle_s > 0 else 0.0,
        "wall_s": round(wall, 3),
        "completed": result.completed,
    }
    if max_cycles is None and result.completed:
        out["pods_per_s_end_to_end"] = round(cfg["pods"] / wall, 1)
    return out


def bench_scale(scale: str, engines) -> dict:
    cfg = SCALES[scale]
    row = {"nodes": cfg["nodes"], "pods": cfg["pods"], "engines": {}}
    cap = cfg["object_cap"]
    repeats = SMALL_SMOKE_REPEATS if scale == "small" else 1
    for engine in engines:
        # Both engines are measured over the same capped cycle window for the
        # speedup ratio; the array engine also runs to completion when the
        # object run was capped (for the end-to-end number).
        samples = sorted((run_one(scale, engine, max_cycles=cap)
                          for _ in range(repeats)),
                         key=lambda r: r["cycle_throughput_pods_per_s"])
        row["engines"][engine] = samples[len(samples) // 2]
        print(f"bench_sched.{scale}.{engine},"
              f"{1e3 * row['engines'][engine]['mean_cycle_ms']:.1f},"
              f"{row['engines'][engine]['cycle_throughput_pods_per_s']}")
    if "array" in engines and cap is not None:
        # Median of FULL_RUN_REPEATS: a single full-run sample wobbles by
        # +/-15% with interpreter/allocator state (the preceding capped
        # object run churns the heap), which is larger than the effects the
        # full-run gate wants to resolve.
        runs = sorted((run_one(scale, "array", max_cycles=None)
                       for _ in range(FULL_RUN_REPEATS)),
                      key=lambda r: r["wall_s"])
        full = runs[len(runs) // 2]
        entry = {
            "wall_s": full["wall_s"], "completed": full["completed"],
            "full_run_repeats": FULL_RUN_REPEATS,
            "pods_per_s_end_to_end": full.get("pods_per_s_end_to_end"),
        }
        prev = PR2_FULL_RUN_WALL_S.get(scale)
        if prev and full["wall_s"]:
            entry["pr2_wall_s"] = prev
            entry["speedup_vs_pr2"] = round(prev / full["wall_s"], 2)
        pr3 = PR3_FULL_RUN_WALL_S.get(scale)
        if pr3 and full["wall_s"]:
            entry["pr3_wall_s"] = pr3
            entry["speedup_vs_pr3"] = round(pr3 / full["wall_s"], 2)
            print(f"bench_sched.{scale}.full_run,"
                  f"{1e6 * full['wall_s']:.0f},{entry['speedup_vs_pr3']}")
        row["engines"]["array"]["full_run"] = entry
    if "array" in row["engines"] and "object" in row["engines"]:
        a = row["engines"]["array"]["cycle_throughput_pods_per_s"]
        o = row["engines"]["object"]["cycle_throughput_pods_per_s"]
        row["speedup_cycle_throughput"] = round(a / o, 1) if o else None
        print(f"bench_sched.{scale}.speedup,0,{row['speedup_cycle_throughput']}")
    return row


TRACE_REPLAY_JOBS = 100_000
TRACE_REPLAY_NODES = 2_000
TRACE_REPLAY_REPEATS = 3


def bench_trace_replay(n_jobs=TRACE_REPLAY_JOBS,
                       nodes=TRACE_REPLAY_NODES) -> dict:
    """Columnar trace replay at ingestion scale: a 100k-arrival heavy-tail
    scenario (``repro.scenarios``) runs end-to-end through
    ``Timeline`` → ``Orchestrator.submit_trace`` → ``PodStore.ingest_trace``
    on a static 2k-node cluster — the zero-per-arrival-object path this
    subsystem adds.  Reported wall time excludes trace generation (recorded
    separately as ``build_s``) and is the median of
    ``TRACE_REPLAY_REPEATS`` runs, same rationale as ``full_run``."""
    from repro.scenarios import HeavyTail

    cfg = HeavyTail(n_jobs=n_jobs, rate_per_s=30.0, cap_s=3600.0)
    t0 = time.perf_counter()
    trace = cfg.build(seed=0)
    build_s = time.perf_counter() - t0
    runs = []
    for _ in range(TRACE_REPLAY_REPEATS):
        reset_id_counters()
        gc.collect()
        spec = ExperimentSpec(trace=trace, scheduler="best-fit",
                              rescheduler="void", autoscaler="void",
                              static_workers=nodes)
        sim = build_simulation(spec)
        t0 = time.perf_counter()
        result = sim.run()
        runs.append((time.perf_counter() - t0, result.completed,
                     sim.n_cycles))
    runs.sort()
    wall, completed, cycles = runs[len(runs) // 2]
    out = {
        "scenario": trace.name, "n_jobs": n_jobs, "nodes": nodes,
        "repeats": TRACE_REPLAY_REPEATS,
        "trace_build_s": round(build_s, 3),
        "wall_s": round(wall, 3),
        "cycles": cycles,
        "completed": completed,
        "pods_per_s_end_to_end": round(n_jobs / wall, 1),
    }
    print(f"bench_sched.trace_replay,{1e6 * wall:.0f},"
          f"{out['pods_per_s_end_to_end']}")
    return out


def bench_wave_kernels(ns=(2048, 8192, 32768, 65536), reps=2000) -> dict:
    """Per-placement cost (extremum query + one point update) of the two
    wave-selection kernels, across node counts — re-measures the crossover
    behind ``engine.SEGTREE_AUTO_MIN_NODES`` (the kernels are
    decision-identical, so this is purely a constant-factor question)."""
    from repro.core.engine import SEGTREE_AUTO_MIN_NODES, SegExtTree

    rng = np.random.default_rng(0)
    out = {"auto_threshold_nodes": SEGTREE_AUTO_MIN_NODES, "per_n": {}}
    for n in ns:
        # Each kernel gets its own copy of the same start buffer and applies
        # the identical (query, write-random-value) sequence, so both do the
        # same real work — a constant write value would converge to a fixed
        # minimum and turn the tree updates into early-exit no-ops.
        base = rng.random(n)
        vals = rng.random(reps)
        flat = base.copy()
        t0 = time.perf_counter()
        for i in range(reps):
            flat[int(flat.argmin())] = vals[i]
        flat_us = 1e6 * (time.perf_counter() - t0) / reps
        tree = SegExtTree(base.copy(), True)
        t0 = time.perf_counter()
        for i in range(reps):
            tree.update(tree.argext(), vals[i])
        tree_us = 1e6 * (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(10):
            SegExtTree(base, True)
        build_us = 1e6 * (time.perf_counter() - t0) / 10
        out["per_n"][str(n)] = {
            "argmin_us": round(flat_us, 2),
            "segtree_us": round(tree_us, 2),
            "segtree_build_us": round(build_us, 1),
        }
        print(f"bench_sched.kernels.n{n},{flat_us:.2f},{tree_us:.2f}")
    return out


def bench_sweep_pool(workers: int = 4, n_jobs: int = 300) -> dict:
    """Process-pool speedup of the `repro.search` cell runner on a fixed
    sweep grid (six scenario families × two autoscalers, full rescheduler
    chain), asserting the pool's rows are bit-identical to the serial
    ones before reporting the speedup.  Serial wall time is the unit of
    work; the pool must recover a real fraction of it or the hermetic-
    cell contract (per-process trace memoization, cheap spawn) regressed.
    """
    from repro.search.runner import CellSpec, run_cells

    scenarios = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp",
                 "scale-stress", "multi-tenant")
    cells = [CellSpec(scenario=sc, scheduler="best-fit", autoscaler=asc,
                      rescheduler="non-binding", seed=0, n_jobs=n_jobs)
             for sc in scenarios for asc in ("binding", "non-binding")]
    t0 = time.perf_counter()
    serial = run_cells(cells, workers=1)
    serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    pooled = run_cells(cells, workers=workers)
    pool_s = time.perf_counter() - t0
    strip = lambda rows: [{k: v for k, v in r.items() if k != "wall_s"}
                          for r in rows]
    identical = strip(serial) == strip(pooled)
    assert identical, "pool rows diverged from serial rows"
    speedup = serial_s / pool_s if pool_s > 0 else 0.0
    out = {"cells": len(cells), "n_jobs": n_jobs, "workers": workers,
           "serial_s": round(serial_s, 3), "pool_s": round(pool_s, 3),
           "speedup": round(speedup, 2), "identical": identical}
    print(f"bench_sched.sweep_pool,{1e6 * pool_s:.0f},{speedup:.2f}")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="all",
                    choices=["all", "none"] + list(SCALES))
    ap.add_argument("--engines", default="array,object",
                    help="comma-separated subset of {array,object}")
    ap.add_argument("--kernels", action="store_true",
                    help="also run the wave-selection kernel crossover bench")
    ap.add_argument("--trace-replay", action="store_true",
                    help="also run the 100k-arrival columnar trace-replay "
                         "bench (always included with --scale all)")
    ap.add_argument("--sweep-pool", action="store_true",
                    help="also measure the search cell runner's process-"
                         "pool speedup vs serial (always with --scale all)")
    ap.add_argument("--pool-workers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_sched.json")
    args = ap.parse_args(argv)

    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    bad = [e for e in engines if e not in ("array", "object")]
    if bad or not engines:
        ap.error(f"--engines must name a non-empty subset of array,object "
                 f"(got {args.engines!r})")
    if args.scale == "all":
        scales = list(SCALES)
    elif args.scale == "none":   # e.g. --trace-replay standalone (CI gate)
        scales = []
    else:
        scales = [args.scale]
    report = {"bench": "sched_throughput",
              "generated_unix_s": int(time.time()),
              "warmup_cycles": WARMUP_CYCLES,
              "scales": {}}
    for scale in scales:
        report["scales"][scale] = bench_scale(scale, engines)
    if args.trace_replay or args.scale == "all":
        report["trace_replay"] = bench_trace_replay()
    if args.sweep_pool or args.scale == "all":
        report["sweep_pool"] = bench_sweep_pool(workers=args.pool_workers)
    if args.kernels:
        report["wave_select_kernels"] = bench_wave_kernels()
    # Preserve entries other benches merged into the same file (e.g. the
    # `manyworld` lane-evaluator entry from bench_manyworld.py) and, on a
    # partial --scale run, the scales this invocation didn't re-measure.
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = {}
        for key, value in prev.items():
            if key == "scales":
                for scale, row in value.items():
                    report["scales"].setdefault(scale, row)
            else:
                report.setdefault(key, value)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
