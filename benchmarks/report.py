"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import rows as roofline_rows

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts")


def dryrun_table() -> str:
    out = ["| arch | shape | mesh | chips | peak GiB/dev | coll GiB/dev | "
           "compile s |",
           "|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(ART, "dryrun", "*.json"))):
        if "__bf16gather" in p or "__kvint8" in p or "__padheads" in p:
            continue
        d = json.load(open(p))
        peak = d["memory"]["peak_estimate_bytes"] / 2**30
        out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                   f"{d['devices']} | {peak:.2f} | "
                   f"{d['collectives_per_device']['total']/2**30:.1f} | "
                   f"{d['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table() -> str:
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | useful % | roofline frac % |",
           "|---|---|---|---|---|---|---|---|"]
    for r in roofline_rows():
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"**{r['dominant']}** | {100*r['useful_ratio']:.0f} | "
            f"{100*r['roofline_fraction']:.0f} |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    print(dryrun_table() if which == "dryrun" else roofline_table())
