"""Fault-tolerant training end to end: the *checkpointable batch job*
contract that makes the paper's eviction/recreation semantics real.

1. Train; checkpoint every `--checkpoint-every` steps.
2. A "node failure" kills the trainer mid-run (cooperative preemption from a
   watchdog thread — the orchestrator's evict signal).
3. A fresh Trainer (the rescheduled pod on another node) resumes from the
   last durable step and finishes; loss history is continuous.

Run: ``PYTHONPATH=src python examples/fault_tolerant_train.py``
"""
import argparse
import tempfile
import threading
import time

from repro.configs import get_config
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--kill-after-s", type=float, default=3.0)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    opt = OptimizerConfig(learning_rate=3e-3, warmup_steps=5,
                          total_steps=args.steps)
    data = DataConfig(batch_size=4, seq_len=64)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=args.steps,
                             checkpoint_every=args.checkpoint_every,
                             checkpoint_dir=ckpt_dir, log_every=10)

        print("== incarnation 1 (will be preempted) ==")
        t1 = Trainer(cfg, opt, data, tcfg)
        killer = threading.Timer(args.kill_after_s, t1.request_stop)
        killer.start()
        out1 = t1.run()
        killer.cancel()
        assert out1["completed"] == 0.0, "expected a preemption"
        print(f"   preempted at step {t1.step}; durable checkpoint on disk")

        print("== incarnation 2 (rescheduled; resumes) ==")
        t2 = Trainer(cfg, opt, data, tcfg)
        assert t2.step > 0, "resume failed"
        out2 = t2.run()
        assert out2["completed"] == 1.0 and t2.step == args.steps
        print(f"   resumed from step {out1['step']:.0f} -> finished "
              f"{args.steps}; final loss {out2['final_loss']:.3f}")

        # determinism check: the data pipeline is step-keyed, so the resumed
        # run consumed exactly the batches the preempted run would have.
        print("== determinism: one uninterrupted run for comparison ==")
        with tempfile.TemporaryDirectory() as d2:
            t3 = Trainer(cfg, opt, data,
                         TrainerConfig(total_steps=args.steps,
                                       checkpoint_every=0,
                                       checkpoint_dir=d2, log_every=10))
            out3 = t3.run()
        delta = abs(out3["final_loss"] - out2["final_loss"])
        print(f"   |loss(resumed) - loss(uninterrupted)| = {delta:.4f}")
        assert delta < 0.05, "resume diverged from the uninterrupted run"
        print("[fault_tolerant_train] OK")


if __name__ == "__main__":
    main()
