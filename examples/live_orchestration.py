"""Live orchestration: the paper's control loop scheduling REAL training
jobs, with a real mid-run preemption.

Two checkpointable LM training jobs (actual `Trainer`s on the JAX data
plane) are bin-packed onto in-process nodes.  Mid-run we evict one (the
paper's rescheduling primitive); the orchestrator re-places it next cycle
and it resumes from its durable checkpoint — no steps lost beyond the
checkpoint boundary.

Run: ``PYTHONPATH=src python examples/live_orchestration.py``
"""
import tempfile
import time

from repro.cloud.local_provider import LiveCluster, LocalCloudProvider
from repro.core import CostModel, PodKind, PodSpec, Resources
from repro.configs import get_config
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def trainer_factory(arch: str, ckpt_dir: str, steps: int):
    def build():
        return Trainer(
            get_config(arch, tiny=True),
            OptimizerConfig(learning_rate=3e-3, warmup_steps=5,
                            total_steps=steps),
            DataConfig(batch_size=2, seq_len=32),
            TrainerConfig(total_steps=steps, checkpoint_every=5,
                          checkpoint_dir=ckpt_dir, log_every=1000),
            log_fn=lambda s: None)
    return build


def main() -> None:
    cost = CostModel()
    provider = LocalCloudProvider(Resources(cpu_m=2000, mem_mb=8192), cost)
    live = LiveCluster(provider, cycle_period_s=0.3)
    live.add_static_nodes(2)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        spec = PodSpec("train-job", PodKind.BATCH,
                       Resources(cpu_m=1000, mem_mb=4096), duration_s=0.0,
                       checkpointable=True)
        p1 = live.submit(spec, trainer_factory("deepseek-7b", d1, 40))
        p2 = live.submit(spec, trainer_factory("glm4-9b", d2, 40))

        # let them run a bit, then preempt job 1 (the paper's eviction)
        live.run(until=lambda: live.jobs[p1.uid].thread is not None,
                 timeout_s=30)
        time.sleep(2.0)
        print("[live] >>> preempting job 1 mid-run <<<")
        live.evict(p1)

        ok = live.run(until=live.batch_done, timeout_s=300)
        assert ok, "jobs did not complete"
        print(f"[live] all jobs done; job1 incarnations="
              f"{p1.incarnation + 1} (resumed after eviction), "
              f"cost=${cost.total_cost(time.time()):.2f}")


if __name__ == "__main__":
    main()
