"""Serve a model with continuous batching: per-request prefill, slot-based
batched decode, per-example cache positions — plus the *moveable service*
contract (snapshot -> migrate -> restore without losing in-flight state).

Run: ``PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b``
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import transformer as tf
from repro.models.params import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine, run_server
from repro.serve.sampling import SamplingConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    params = init_params(jax.random.key(0), tf.model_specs(cfg),
                         cfg.param_dtype)
    extra = {}
    rng = np.random.default_rng(0)
    if cfg.family == "vlm":
        extra["pixel_embeds"] = 0.02 * rng.standard_normal(
            (cfg.vision_prefix_len, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extra["audio_embeds"] = 0.02 * rng.standard_normal(
            (cfg.encoder_seq, cfg.d_model)).astype(np.float32)

    engine = ServeEngine(cfg, params,
                         EngineConfig(num_slots=args.slots, cache_len=128,
                                      sampling=SamplingConfig(temperature=0.8,
                                                              top_k=40)),
                         extra_inputs=extra)
    reqs = []
    t = 0.0
    for i in range(args.requests):
        t += float(rng.exponential(0.15))
        reqs.append(Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab_size, 8),
                            max_new_tokens=args.max_new_tokens,
                            submitted_at=t))
    metrics = run_server(engine, reqs)
    print(f"[serve_lm] {metrics}")

    # --- the moveable-service contract: evict mid-flight, restore elsewhere
    print("[serve_lm] demonstrating snapshot -> migrate -> restore")
    engine.admit(Request(uid=99, prompt=np.arange(6) % cfg.vocab_size,
                         max_new_tokens=8))
    engine.step()
    snap = engine.snapshot()               # orchestrator evicts the service
    engine2 = ServeEngine(cfg, params,     # ... recreates it on another node
                          EngineConfig(num_slots=args.slots, cache_len=128),
                          extra_inputs=extra)
    engine2.restore(snap)
    while any(engine2.active):
        engine2.step()
    print("[serve_lm] migrated request finished generation on the new node")


if __name__ == "__main__":
    main()
