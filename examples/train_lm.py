"""Train a language model end to end through the framework's data plane:
config -> synthetic pipeline -> train_step (AdamW, remat, grad-accum) ->
checkpoints.  Any of the 10 assigned architectures is selectable; on this
CPU container the reduced smoke configs are the default (the full configs
are exercised by the production-mesh dry-run).

Run: ``PYTHONPATH=src python examples/train_lm.py --arch glm4-9b --steps 200``
"""
import argparse
import tempfile

from repro.configs import get_config, list_archs
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=True)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg,
            OptimizerConfig(learning_rate=args.lr, warmup_steps=20,
                            total_steps=args.steps),
            DataConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                       accum=args.accum),
            TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                          checkpoint_dir=ckpt_dir, log_every=20))
        result = trainer.run()
        first, last = trainer.history[0], trainer.history[-1]
        print(f"\n[train_lm] {args.arch}: loss {first['loss']:.3f} -> "
              f"{last['loss']:.3f}, accuracy {last['accuracy']:.3f} "
              f"over {args.steps} steps")
        assert last["loss"] < first["loss"], "no learning signal!"


if __name__ == "__main__":
    main()
