"""End-to-end driver: the full paper evaluation + fleet fault tolerance.

* Fig. 3 — all six rescheduler x autoscaler combos on all three workloads.
* Fig. 4 — default-K8s static baseline and cost reductions.
* Fleet extension — the same orchestrator absorbing injected node failures
  (checkpointable batch jobs resume from their last checkpoint boundary).

Run: ``PYTHONPATH=src python examples/orchestrate_cluster.py [--seeds N]``
"""
import argparse
import statistics

from repro.core import (ExperimentSpec, run_all_combos, run_experiment,
                        run_k8s_baseline)
from repro.core.failures import FailureInjector
from repro.core.workload import generate_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    seeds = range(args.seeds)

    for wl in ("bursty", "slow", "mixed"):
        print(f"\n=== workload {wl} ===")
        k8s_costs = []
        for seed in seeds:
            k8s = run_k8s_baseline(wl, seed=seed)
            k8s_costs.append(k8s.cost)
        k8s_mean = statistics.fmean(k8s_costs)
        print(f"  K8S-static baseline: ${k8s_mean:8.2f} (mean of {len(k8s_costs)})")
        combos = {}
        for seed in seeds:
            for r in run_all_combos(wl, seed=seed):
                combos.setdefault(r.combo(), []).append(r)
        for combo, rs in sorted(combos.items()):
            cost = statistics.fmean(x.cost for x in rs)
            dur = statistics.fmean(x.duration_s for x in rs)
            ram = statistics.fmean(x.avg_ram_ratio for x in rs)
            print(f"  {combo:10s} cost=${cost:8.2f} (-{100*(1-cost/k8s_mean):5.1f}%) "
                  f"dur={dur:7.0f}s ram={ram:.2f}")

    print("\n=== fleet fault tolerance: node failures mid-workload ===")
    for mtbf in (3600.0, 900.0):
        r = run_experiment(ExperimentSpec(
            workload="slow", rescheduler="non-binding", autoscaler="binding",
            seed=0, failure_injector=FailureInjector(mtbf_s=mtbf, seed=1)))
        print(f"  MTBF {mtbf:6.0f}s: completed={r.completed} "
              f"failures={r.failures_injected} evictions={r.evictions} "
              f"cost=${r.cost:.2f} dur={r.duration_s:.0f}s")
    print("  (every batch job still ran to completion; services stayed up)")


if __name__ == "__main__":
    main()
