"""Quickstart: the paper's result in 60 seconds.

1. Run the NBR-BAS orchestrator (best combo) on the slow workload.
2. Run the default-K8s static baseline.
3. Print the cost reduction (the paper's Fig. 4 headline: >58 %).
4. Train a tiny LM for 30 steps through the same framework's data plane.

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""
import statistics

from repro.core import ExperimentSpec, run_experiment, run_k8s_baseline


def main() -> None:
    print("== 1-2. cost-efficient autoscaling vs static Kubernetes ==")
    saves = []
    for seed in range(4):
        ours = run_experiment(ExperimentSpec(
            workload="slow", rescheduler="non-binding", autoscaler="binding",
            seed=seed))
        k8s = run_k8s_baseline("slow", seed=seed)
        saves.append(100 * (1 - ours.cost / k8s.cost))
        print(f"  seed {seed}: NBR-BAS ${ours.cost:7.2f}  "
              f"K8S-static(n={k8s.max_nodes}) ${k8s.cost:7.2f}  "
              f"saving {saves[-1]:.1f}%")
    print(f"  mean saving {statistics.fmean(saves):.1f}% "
          f"(paper reports >58% on this workload)")

    print("== 3. the data plane the orchestrator schedules ==")
    from repro.configs import get_config
    from repro.train.data import DataConfig
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import Trainer, TrainerConfig
    trainer = Trainer(get_config("deepseek-7b", tiny=True),
                      OptimizerConfig(learning_rate=3e-3, warmup_steps=5,
                                      total_steps=30),
                      DataConfig(batch_size=4, seq_len=64),
                      TrainerConfig(total_steps=30, checkpoint_every=0,
                                    log_every=10))
    trainer.run()


if __name__ == "__main__":
    main()
