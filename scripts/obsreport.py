#!/usr/bin/env python
"""Obs report CLI: run (or load) a flight-recorded experiment and explain it.

Default mode runs one experiment with the flight recorder + cycle-phase
profiler attached (``repro.obs``) and prints the run report — the phase
breakdown table, the decision summary, and the per-decision drill-down
with each decision's attributed inputs::

    python scripts/obsreport.py --scenario flash-crowd --jobs 400 \
        --autoscaler predictive --rescheduler non-binding
    python scripts/obsreport.py --scenario diurnal --export run.npz \
        --chrome-trace trace.json
    python scripts/obsreport.py --load run.npz --kind evict,resched

``--load`` reports on a previously exported bundle (``.npz`` or the
exact-round-trip ``.json``) with identical output.  ``--chrome-trace``
additionally writes the profiler span ring as Chrome-trace/Perfetto JSON
(open in https://ui.perfetto.dev or chrome://tracing).

CI modes:

* ``--smoke`` — record a small run, export + reload both formats,
  assert the bit-exact round trip, assert an obs-off rerun produces the
  bit-identical ``ExperimentResult``, and render the full report and
  Chrome trace (the observability pipeline end to end).
* ``--overhead-gate`` — median-of-3 obs-off vs obs-on walls on the same
  spec; asserts the results stay bit-identical and the obs-on wall is
  within ``REPRO_OBS_OVERHEAD_MAX`` (default 2.0×) of obs-off
  (measured ~1.6× on the flash-crowd/predictive stress cell).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core import reset_id_counters
from repro.core.experiment import ExperimentSpec, run_experiment
from repro.obs import (ObsConfig, chrome_trace, load_bundle, render_report,
                       run_recorded)


def build_spec(args, obs=None) -> ExperimentSpec:
    kwargs = dict(scheduler=args.scheduler, rescheduler=args.rescheduler,
                  autoscaler=args.autoscaler, seed=args.seed,
                  engine=args.engine, obs=obs)
    if args.scenario is not None:
        kwargs["scenario"] = args.scenario
        kwargs["scenario_jobs"] = args.jobs
    else:
        kwargs["workload"] = args.workload
    return ExperimentSpec(**kwargs)


def record(args):
    reset_id_counters()
    spec = build_spec(args, obs=ObsConfig(capacity=args.capacity,
                                          max_spans=args.capacity))
    result, rec = run_recorded(spec)
    return result, rec


def write_chrome_trace(bundle, path: str) -> int:
    events = chrome_trace(bundle["profile"])
    with open(path, "w") as fh:
        json.dump({"traceEvents": events}, fh)
    return len(events)


def report(args) -> None:
    if args.load:
        bundle = load_bundle(args.load)
    else:
        _result, rec = record(args)
        bundle = rec.bundle()
        if args.export:
            from repro.obs import save_bundle
            save_bundle(bundle, args.export)
            print(f"# exported {args.export}")
    kinds = ([k for k in args.kind.split(",") if k]
             if args.kind else None)
    print(render_report(bundle, kinds=kinds, limit=args.limit))
    if args.chrome_trace:
        n = write_chrome_trace(bundle, args.chrome_trace)
        print(f"# chrome trace: {args.chrome_trace} ({n} spans)")


def smoke(args) -> None:
    """CI gate: the whole obs pipeline on a small run."""
    from repro.obs.recorder import (EV_SCALE_IN, EV_SCALE_OUT, SO_PRELAUNCH,
                                    EventLog)

    args.scenario, args.jobs = args.scenario or "flash-crowd", args.jobs or 200
    result_on, rec = record(args)

    # Obs-off rerun must produce the bit-identical ExperimentResult.
    reset_id_counters()
    result_off = run_experiment(build_spec(args, obs=None))
    assert dataclasses.asdict(result_on) == dataclasses.asdict(result_off), \
        "obs-on run diverged from obs-off run"
    print(f"obs smoke: result parity OK (scale_outs={result_on.scale_outs})")

    # Event-log round trip, both formats, bit-exact.
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        for suffix in (".npz", ".json"):
            path = os.path.join(tmp, f"events{suffix}")
            rec.events.save(path)
            assert rec.events.same_as(EventLog.load(path)), \
                f"event log round trip drifted through {suffix}"
        bundle_path = os.path.join(tmp, "bundle.npz")
        rec.export(bundle_path)
        bundle = load_bundle(bundle_path)
        ev = bundle["events"]
        assert ev["n_seen"] == rec.events.n_seen
        assert ev["n_seen"] <= ev["capacity"], \
            "smoke run wrapped the event ring; raise --capacity"
        so_mask = ev["columns"]["kind"] == EV_SCALE_OUT
        n_out = int((so_mask
                     & (ev["columns"]["v1"] != SO_PRELAUNCH)).sum())
        n_in = int((ev["columns"]["kind"] == EV_SCALE_IN).sum())
        # Every reactive scale-out request must be explained in the log
        # (predictive prelaunches are recorded too, but they are not
        # requests — result.scale_outs counts only the reactive chain).
        assert n_out == result_on.scale_outs, \
            f"{n_out} scale_out events != result.scale_outs " \
            f"{result_on.scale_outs}"
        assert n_in == result_on.scale_ins, \
            f"{n_in} scale_in events != result.scale_ins {result_on.scale_ins}"
        print(f"obs smoke: round trip OK ({len(rec.events)} events, "
              f"{n_out} scale-outs, {n_in} scale-ins attributed)")

        trace_path = os.path.join(tmp, "trace.json")
        n_spans = write_chrome_trace(bundle, trace_path)
        with open(trace_path) as fh:
            loaded = json.load(fh)
        assert len(loaded["traceEvents"]) == n_spans > 0
        assert all(e["ph"] == "X" and e["dur"] >= 0.0
                   for e in loaded["traceEvents"])
        print(f"obs smoke: chrome trace OK ({n_spans} spans)")

    print(render_report(bundle, limit=args.limit))
    print("obs smoke OK")


def overhead_gate(args) -> None:
    """CI gate: obs-off must not get slower; obs-on overhead is bounded."""
    args.scenario, args.jobs = args.scenario or "flash-crowd", args.jobs or 400

    def wall(obs):
        best = float("inf")
        for _ in range(args.repeats):
            reset_id_counters()
            spec = build_spec(args, obs=obs)
            t0 = time.perf_counter()
            result = run_experiment(spec)
            best = min(best, time.perf_counter() - t0)
        return best, result

    off_s, r_off = wall(None)
    on_s, r_on = wall(ObsConfig())
    assert dataclasses.asdict(r_on) == dataclasses.asdict(r_off), \
        "obs-on run diverged from obs-off run"
    limit = float(os.environ.get("REPRO_OBS_OVERHEAD_MAX", "2.0"))
    ratio = on_s / off_s
    print(f"obs overhead: off={1e3 * off_s:.1f}ms on={1e3 * on_s:.1f}ms "
          f"ratio={ratio:.2f} (limit {limit:.2f})")
    assert ratio <= limit, \
        f"obs-on overhead {ratio:.2f}x exceeds {limit:.2f}x bound"
    print("obs overhead gate OK")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--load", help="report on an exported bundle "
                                   "(.npz/.json) instead of running")
    ap.add_argument("--scenario", default=None,
                    help="scenarios.registry name (e.g. flash-crowd)")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--workload", default="mixed",
                    help="paper workload when no --scenario is given")
    ap.add_argument("--scheduler", default="best-fit")
    ap.add_argument("--rescheduler", default="non-binding")
    ap.add_argument("--autoscaler", default="predictive")
    ap.add_argument("--engine", default=None, choices=(None, "array",
                                                       "object"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--capacity", type=int, default=1 << 16,
                    help="event/span ring slots")
    ap.add_argument("--kind", default=None,
                    help="drill-down filter, comma-separated kind names "
                         "(default scale_out,scale_in)")
    ap.add_argument("--limit", type=int, default=50,
                    help="drill-down: show only the last N events")
    ap.add_argument("--export", default=None,
                    help="also export the bundle (.npz or .json)")
    ap.add_argument("--chrome-trace", default=None,
                    help="also write Chrome-trace/Perfetto JSON spans")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: pipeline end-to-end on a small run")
    ap.add_argument("--overhead-gate", action="store_true",
                    help="CI gate: obs-on wall within bound of obs-off")
    ap.add_argument("--repeats", type=int, default=3,
                    help="overhead gate: best-of-N walls")
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(args)
    elif args.overhead_gate:
        overhead_gate(args)
    else:
        report(args)


if __name__ == "__main__":
    main()
