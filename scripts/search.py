#!/usr/bin/env python
"""Policy search CLI: NSGA-II over the paper's policy knobs.

Default mode runs the seeded multi-objective search (`repro.search`)
over six scenario families, evaluates the paper's Table-4 default chain
on the same traces, and writes the Pareto-front artifact with the
"beats the paper's defaults by X% on scenario Y" comparison::

    python scripts/search.py                      # committed-artifact run
    python scripts/search.py --generations 4 --pop 12 --workers 8
    python scripts/search.py --scenarios diurnal,heavy-tail --jobs 300
    python scripts/search.py --chaos --objectives cost,mean_pending_s,lost_work_s
    python scripts/search.py --smoke              # the CI gate

The default settings reproduce the committed ``SEARCH_policy.json``
bit-for-bit (seeded rng + hermetic cells; ``--workers`` changes only
wall-clock time, never results).

``--smoke`` is the seeded CI gate: a 2-generation × 6-individual
micro-search on two scenario families, run serially *and* on a
2-worker pool, asserting

1. the Pareto front is non-empty and every front config was actually
   simulated on every scenario;
2. the parallel run's front is **bit-identical** to the serial one
   (same vectors, same objective floats, same history).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.search import (baseline_rows, build_report, default_space,
                          run_search, summarize)
from repro.search.nsga2 import DEFAULT_OBJECTIVES, OBJECTIVES

DEFAULT_SCENARIOS = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp",
                     "scale-stress", "multi-tenant")
SMOKE_SCENARIOS = ("diurnal", "heavy-tail")


def run_smoke(out: str) -> dict:
    space = default_space()
    settings = dict(generations=2, pop_size=6, seed=7, n_jobs=40)
    t0 = time.perf_counter()
    serial = run_search(space, SMOKE_SCENARIOS, workers=1, **settings)
    parallel = run_search(space, SMOKE_SCENARIOS, workers=2, **settings)
    wall = time.perf_counter() - t0

    assert serial.front, "smoke search produced an empty Pareto front"
    for ind in serial.front:
        assert set(ind.per_scenario) == set(SMOKE_SCENARIOS), (
            f"front config missing scenario evaluations: {ind.config}")
    assert [i.vector for i in serial.front] == \
           [i.vector for i in parallel.front], "pool front drifted (vectors)"
    assert [i.objectives for i in serial.front] == \
           [i.objectives for i in parallel.front], (
               "pool front drifted (objectives not bit-identical)")
    assert serial.history == parallel.history, "pool history drifted"

    base = baseline_rows(SMOKE_SCENARIOS, seed=settings["seed"],
                         n_jobs=settings["n_jobs"])
    report = build_report(serial, base)
    report["smoke"] = {"wall_s": round(wall, 2), "serial_vs_pool": "identical"}
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"search smoke OK: front={len(serial.front)} "
          f"evals={serial.evaluations}, serial == 2-worker pool "
          f"(bit-identical), {wall:.1f}s")
    return report


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenarios",
                    help=f"default {','.join(DEFAULT_SCENARIOS)}")
    ap.add_argument("--generations", type=int, default=6)
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--jobs", type=int, default=120,
                    help="trace length per scenario family")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool size (results are identical for "
                         "any value; >1 only helps on multi-core hosts)")
    ap.add_argument("--engine", default=None,
                    help="force array|object (default: engine env/default)")
    ap.add_argument("--chaos", action="store_true",
                    help="evaluate on the chaos scenario families with "
                         "their seeded disruption schedules")
    ap.add_argument("--objectives", default=",".join(DEFAULT_OBJECTIVES),
                    help=f"comma-separated subset of {sorted(OBJECTIVES)}")
    ap.add_argument("--smoke", action="store_true",
                    help="seeded CI micro-search + serial-vs-pool "
                         "bit-identity check, runs in seconds")
    ap.add_argument("--out", default=None,
                    help="default SEARCH_policy.json "
                         "(/tmp/SEARCH_smoke.json with --smoke)")
    args = ap.parse_args(argv)

    if args.smoke:
        return run_smoke(args.out or "/tmp/SEARCH_smoke.json")

    if args.scenarios:
        scenarios = tuple(s for s in args.scenarios.split(",") if s)
    elif args.chaos:
        from repro.scenarios.chaos import CHAOS_SCENARIOS
        scenarios = tuple(sorted(CHAOS_SCENARIOS))
    else:
        scenarios = DEFAULT_SCENARIOS
    objectives = tuple(s for s in args.objectives.split(",") if s)

    t0 = time.perf_counter()
    result = run_search(default_space(), scenarios,
                        generations=args.generations, pop_size=args.pop,
                        seed=args.seed, workers=args.workers,
                        n_jobs=args.jobs, engine=args.engine,
                        objectives=objectives, chaos=args.chaos,
                        log=print)
    base = baseline_rows(scenarios, seed=args.seed, n_jobs=args.jobs,
                         engine=args.engine, chaos=args.chaos,
                         workers=args.workers)
    report = build_report(result, base)
    report["settings"] = {
        "generations": args.generations, "pop_size": args.pop,
        "n_jobs": args.jobs, "engine": args.engine, "chaos": args.chaos,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    out = args.out or "SEARCH_policy.json"
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    for line in summarize(report):
        print(line)
    print(f"# wrote {out}")
    return report


if __name__ == "__main__":
    main()
