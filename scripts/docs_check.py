#!/usr/bin/env python
"""Docs gate (``make docs-check``): keep the markdown honest.

Three checks over the repo's markdown (README.md, ROADMAP.md, docs/*.md...):

1. **Relative links resolve** — every ``[text](target)`` pointing inside the
   repo must name an existing file/directory (anchors and external URLs are
   skipped).
2. **Command snippets name real files** — repo-relative paths mentioned in
   fenced code blocks (``benchmarks/foo.py``, ``requirements-dev.txt``, ...)
   must exist, and ``make <target>`` invocations must name targets the
   Makefile defines.  This is the feasible equivalent of doctesting shell
   snippets: the commands aren't executed, but they can't silently rot.
3. **Doctest** — any ``>>>`` interactive examples in the markdown run under
   ``doctest`` (none is fine; the check is a no-op then).

Exit status is non-zero with one line per violation, so CI fails loudly.
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

MD_FILES = sorted(
    p for p in list(REPO.glob("*.md")) + list(REPO.glob("docs/**/*.md"))
    if ".claude" not in p.parts)

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)
# Repo-relative path tokens inside code fences: dir/file.ext or top-level
# known files.  Deliberately conservative — only tokens that look like paths.
PATH_TOKEN_RE = re.compile(
    r"(?<![\w/.-])((?:[A-Za-z_][\w.-]*/)+[\w.-]+\.[A-Za-z]{1,4}"
    r"|requirements[\w.-]*\.txt|Makefile)(?![\w/])")
MAKE_RE = re.compile(r"\bmake\s+([A-Za-z][\w-]*)")
# Generated artifacts a snippet may legitimately reference before they exist.
GENERATED_OK = {"BENCH_sched.json", "SEARCH_policy.json",
                "SWEEP_scenarios.json"}


def check_links(md: Path, text: str, errors: list) -> None:
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists() and not (REPO / path).exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")


def _make_targets() -> set:
    """Targets the Makefile defines (rule lines only, not recipe text)."""
    return {m.group(1) for m in re.finditer(
        r"^([A-Za-z][\w-]*):", (REPO / "Makefile").read_text(), re.M)}


def check_snippets(md: Path, text: str, errors: list,
                   make_targets: set) -> None:
    for block in FENCE_RE.findall(text):
        for token in PATH_TOKEN_RE.findall(block):
            name = Path(token).name
            if name in GENERATED_OK or token.startswith("/"):
                continue
            if not (REPO / token).exists():
                errors.append(
                    f"{md.relative_to(REPO)}: snippet references missing "
                    f"file -> {token}")
        for target in MAKE_RE.findall(block):
            if target not in make_targets:
                errors.append(
                    f"{md.relative_to(REPO)}: snippet references unknown "
                    f"make target -> {target}")


def check_doctests(md: Path, text: str, errors: list) -> None:
    if ">>>" not in text:
        return
    results = doctest.testfile(str(md), module_relative=False,
                               optionflags=doctest.ELLIPSIS, verbose=False)
    if results.failed:
        errors.append(f"{md.relative_to(REPO)}: {results.failed} doctest "
                      f"failure(s)")


def main() -> int:
    errors: list = []
    make_targets = _make_targets()
    for md in MD_FILES:
        text = md.read_text()
        check_links(md, text, errors)
        check_snippets(md, text, errors, make_targets)
        check_doctests(md, text, errors)
    for err in errors:
        print(f"docs-check: {err}")
    print(f"docs-check: {len(MD_FILES)} markdown files, "
          f"{len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
