#!/usr/bin/env python
"""Forecaster training + evaluation CLI (ROADMAP item 2 tooling).

Builds the (history-window -> next-window rate) dataset from the seeded
scenario families, trains the mLSTM forecaster on the jax_pallas train
substrate, scores it against the numpy baselines (EWMA, AR(1)) on the
held-out validation seeds, round-trips the result through the shared
`CheckpointManager`, and writes a JSON report::

    python scripts/forecast.py                        # full eval
    python scripts/forecast.py --smoke                # the CI gate
    python scripts/forecast.py --ckpt runs/forecast   # also keep params

All metrics are log1p-space MSE (the training objective): rates are
nonnegative and heavy-tailed across families, and log space stops
flash-crowd peaks from drowning the quiet regimes.

Requires JAX; `scripts/ci.sh` gates the call on ``import jax`` so
JAX-less environments skip it cleanly rather than half-running.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DEFAULT_FAMILIES = ("diurnal", "flash-crowd", "heavy-tail", "mix-ramp",
                    "scale-stress", "multi-tenant")
SMOKE_FAMILIES = ("flash-crowd", "scale-stress")


def _ewma_log_mse(X, y) -> float:
    """Score the online EWMA the way the autoscaler uses it: replay each
    example's history bins through a fresh forecaster, predict once."""
    import numpy as np

    from repro.forecast import EwmaForecaster
    errs = []
    for hist, target in zip(X, y):
        f = EwmaForecaster()
        for r in hist:
            f.observe_bin(float(r))
        pred, _conf = f.predict()
        errs.append((np.log1p(pred) - np.log1p(float(target))) ** 2)
    return float(np.mean(errs))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--families", help=f"default {','.join(DEFAULT_FAMILIES)}")
    ap.add_argument("--seeds", type=int, default=48,
                    help="scenario seeds 0..N-1 per family (seed %% 4 == 3 "
                         "is the validation split)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="trace length per (family, seed); default = each "
                         "family's native size")
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--d-model", type=int, default=32)
    ap.add_argument("--train-seed", type=int, default=0,
                    help="param-init / batch-order seed")
    ap.add_argument("--ckpt", default=None, metavar="DIR",
                    help="also persist trained params under DIR (default: "
                         "round-trip through a temp dir only)")
    ap.add_argument("--smoke", action="store_true",
                    help=f"small CI gate: {','.join(SMOKE_FAMILIES)}, 4 "
                         "seeds, 300-job traces, 60 steps")
    ap.add_argument("--out", default="FORECAST_eval.json")
    args = ap.parse_args(argv)

    try:
        import jax  # noqa: F401
    except ImportError:
        raise SystemExit(
            "scripts/forecast.py requires JAX (gate the call on "
            "`python -c 'import jax'`, as scripts/ci.sh does)")

    import numpy as np

    from repro.forecast import Ar1Baseline, WindowConfig, make_dataset
    from repro.forecast import model as fmodel

    if args.smoke:
        families = tuple((args.families or ",".join(SMOKE_FAMILIES))
                         .split(","))
        seeds = range(min(args.seeds, 4))
        n_jobs = args.jobs or 300
        steps = min(args.steps, 60)
    else:
        families = tuple((args.families or ",".join(DEFAULT_FAMILIES))
                         .split(","))
        seeds = range(args.seeds)
        n_jobs = args.jobs
        steps = args.steps

    window = WindowConfig()
    t0 = time.perf_counter()
    data = make_dataset(families, seeds, window, n_jobs=n_jobs)
    t_data = time.perf_counter() - t0
    print(f"dataset: train={data['X_train'].shape[0]} "
          f"val={data['X_val'].shape[0]} examples "
          f"({len(families)} families x {len(seeds)} seeds, {t_data:.1f}s)")

    t0 = time.perf_counter()
    result = fmodel.train_forecaster(
        data["X_train"], data["y_train"], window=window,
        X_val=data["X_val"], y_val=data["y_val"],
        seed=args.train_seed, steps=steps, d_model=args.d_model)
    t_train = time.perf_counter() - t0

    first = float(np.mean(result.losses[:10]))
    last = float(np.mean(result.losses[-10:]))
    ewma_mse = _ewma_log_mse(data["X_val"], data["y_val"])
    ar1 = Ar1Baseline.fit(data["X_train"], data["y_train"])
    ar1_mse = float(np.mean(
        (np.log1p(np.maximum(ar1.predict_batch(data["X_val"]), 0.0))
         - np.log1p(data["y_val"])) ** 2))

    # Checkpoint round-trip through the shared manager: saved params must
    # reload into a LearnedForecaster that accepts the online contract.
    ckpt_dir = args.ckpt or os.path.join(
        tempfile.mkdtemp(prefix="forecast_ckpt_"), "forecast")
    fmodel.save_forecaster(ckpt_dir, result, step=steps)
    restored = fmodel.load_forecaster(ckpt_dir)
    for r in data["X_val"][0] if data["X_val"].shape[0] else []:
        restored.observe_bin(float(r))
    rate, conf = restored.predict()
    print(f"train: loss {first:.4f} -> {last:.4f} over {steps} steps "
          f"({t_train:.1f}s); reload predict=({rate:.3f}, conf={conf:.2f})")
    print(f"val log-MSE: mlstm={result.val_mse:.4f} ewma={ewma_mse:.4f} "
          f"ar1={ar1_mse:.4f}")

    report = {
        "bench": "forecast_eval",
        "generated_unix_s": int(time.time()),
        "config": {"families": list(families), "seeds": len(seeds),
                   "n_jobs": n_jobs, "steps": steps,
                   "d_model": args.d_model, "train_seed": args.train_seed,
                   "window": {"bin_s": window.bin_s,
                              "history_bins": window.history_bins,
                              "horizon_bins": window.horizon_bins}},
        "dataset": {"n_train": int(data["X_train"].shape[0]),
                    "n_val": int(data["X_val"].shape[0])},
        "train": {"loss_first10": round(first, 6),
                  "loss_last10": round(last, 6),
                  "wall_s": round(t_train, 3)},
        "val_log_mse": {"mlstm": round(result.val_mse, 6),
                        "ewma": round(ewma_mse, 6),
                        "ar1": round(ar1_mse, 6)},
        "reload_predict": {"rate": round(rate, 6), "conf": round(conf, 6)},
        "checkpoint": ckpt_dir if args.ckpt else None,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {args.out}")

    assert last < first, (
        f"training loss did not decrease: {first:.4f} -> {last:.4f}")
    return report


if __name__ == "__main__":
    main()
