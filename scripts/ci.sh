#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast smoke of the scheduler-cycle throughput
# benchmark, so perf regressions in the cycle hot path fail loudly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs checks (links + snippet references) =="
python scripts/docs_check.py

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== scheduler throughput smoke (small scale, both engines) =="
python benchmarks/bench_sched_throughput.py --scale small \
    --out /tmp/BENCH_sched_smoke.json
python - <<'EOF'
import json
row = json.load(open("/tmp/BENCH_sched_smoke.json"))["scales"]["small"]
arr = row["engines"]["array"]
assert arr["completed"], "array engine failed to complete the smoke workload"
# Machine-independent gate: the array engine must beat the seed object
# engine measured on the same box in the same run (~3-4x at this scale;
# 1.5 leaves slack for noisy CI runners).
speedup = row["speedup_cycle_throughput"]
assert speedup and speedup >= 1.5, f"cycle-path regression: speedup={speedup}"
print(f"smoke OK: {arr['cycle_throughput_pods_per_s']} pods/s "
      f"(speedup vs object engine: {speedup}x)")
EOF
