#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast smoke of the scheduler-cycle throughput
# benchmark, so perf regressions in the cycle hot path fail loudly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs checks (links + snippet references) =="
python scripts/docs_check.py

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== scheduler throughput smoke (small scale, both engines) =="
python benchmarks/bench_sched_throughput.py --scale small \
    --out /tmp/BENCH_sched_smoke.json
python - <<'EOF'
import json
import os
row = json.load(open("/tmp/BENCH_sched_smoke.json"))["scales"]["small"]
arr = row["engines"]["array"]
assert arr["completed"], "array engine failed to complete the smoke workload"
# Machine-independent gate: the array engine must beat the seed object
# engine measured on the same box in the same run (~3-4x at this scale;
# 1.5 leaves slack for noisy CI runners).
speedup = row["speedup_cycle_throughput"]
assert speedup and speedup >= 1.5, f"cycle-path regression: speedup={speedup}"
print(f"smoke OK: {arr['cycle_throughput_pods_per_s']} pods/s "
      f"(speedup vs object engine: {speedup}x)")

# Bench-regression gate: the smoke's absolute cycle throughput must stay
# within BENCH_REGRESSION_TOLERANCE (default 30%) of the committed
# BENCH_sched.json baseline.  Machine-dependent by design — the committed
# numbers come from the same container class; set BENCH_REGRESSION_SKIP=1
# when running on unrelated hardware.
if os.environ.get("BENCH_REGRESSION_SKIP") == "1":
    print("bench-regression gate skipped (BENCH_REGRESSION_SKIP=1)")
else:
    tolerance = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30"))
    base_row = json.load(open("BENCH_sched.json"))["scales"]["small"]
    base = base_row["engines"]["array"]["cycle_throughput_pods_per_s"]
    now = arr["cycle_throughput_pods_per_s"]
    floor = (1.0 - tolerance) * base
    assert now >= floor, (
        f"cycle-throughput regression: {now} pods/s < {floor:.0f} "
        f"(committed baseline {base} pods/s - {tolerance:.0%})")
    print(f"bench-regression gate OK: {now} pods/s vs committed {base} "
          f"(floor {floor:.0f})")
EOF
