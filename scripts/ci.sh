#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast smoke of the scheduler-cycle throughput
# benchmark, so perf regressions in the cycle hot path fail loudly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs checks (links + snippet references) =="
python scripts/docs_check.py

echo "== tier-1 tests (with wall-time budget) =="
# The parity suite grows with every engine refactor; --durations surfaces
# the slowest tests and the budget gate keeps total wall time bounded so
# new property tests must pay for themselves.  Override with
# TEST_BUDGET_S=<seconds>, or TEST_BUDGET_SKIP=1 on unusually slow runners.
TEST_BUDGET_S="${TEST_BUDGET_S:-480}"
test_t0=$SECONDS
python -m pytest -x -q --durations=15
test_elapsed=$(( SECONDS - test_t0 ))
if [ "${TEST_BUDGET_SKIP:-0}" = "1" ]; then
    echo "test-budget gate skipped (TEST_BUDGET_SKIP=1; took ${test_elapsed}s)"
elif [ "$test_elapsed" -gt "$TEST_BUDGET_S" ]; then
    echo "test-budget gate FAILED: suite took ${test_elapsed}s > ${TEST_BUDGET_S}s budget"
    exit 1
else
    echo "test-budget gate OK: ${test_elapsed}s <= ${TEST_BUDGET_S}s"
fi

echo "== scheduler throughput smoke (small scale, both engines) =="
python benchmarks/bench_sched_throughput.py --scale small \
    --out /tmp/BENCH_sched_smoke.json
python - <<'EOF'
import json
import os
row = json.load(open("/tmp/BENCH_sched_smoke.json"))["scales"]["small"]
arr = row["engines"]["array"]
assert arr["completed"], "array engine failed to complete the smoke workload"
# Machine-independent gate: the array engine must beat the seed object
# engine measured on the same box in the same run (~3-4x at this scale;
# 1.5 leaves slack for noisy CI runners).
speedup = row["speedup_cycle_throughput"]
assert speedup and speedup >= 1.5, f"cycle-path regression: speedup={speedup}"
print(f"smoke OK: {arr['cycle_throughput_pods_per_s']} pods/s "
      f"(speedup vs object engine: {speedup}x)")

# Bench-regression gate: the smoke's absolute cycle throughput must stay
# within BENCH_REGRESSION_TOLERANCE (default 30%) of the committed
# BENCH_sched.json baseline.  Machine-dependent by design — the committed
# numbers come from the same container class; set BENCH_REGRESSION_SKIP=1
# when running on unrelated hardware.
if os.environ.get("BENCH_REGRESSION_SKIP") == "1":
    print("bench-regression gate skipped (BENCH_REGRESSION_SKIP=1)")
else:
    tolerance = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30"))
    base_row = json.load(open("BENCH_sched.json"))["scales"]["small"]
    base = base_row["engines"]["array"]["cycle_throughput_pods_per_s"]
    now = arr["cycle_throughput_pods_per_s"]
    floor = (1.0 - tolerance) * base
    assert now >= floor, (
        f"cycle-throughput regression: {now} pods/s < {floor:.0f} "
        f"(committed baseline {base} pods/s - {tolerance:.0%})")
    print(f"bench-regression gate OK: {now} pods/s vs committed {base} "
          f"(floor {floor:.0f})")
EOF

echo "== scenario smoke sweep (small scheduler x autoscaler x scenario grid) =="
# The scenario subsystem's end-to-end gate: a small grid over four generated
# scenario families must run to completion through trace-native replay.
python benchmarks/sweep_scenarios.py --smoke --out /tmp/SWEEP_smoke.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/SWEEP_smoke.json"))
cells = rep["cells"]
assert len(cells) >= 16, f"smoke grid shrank to {len(cells)} cells"
bad = [(c["scenario"], c["scheduler"], c["autoscaler"])
       for c in cells if not c["completed"]]
assert not bad, f"sweep cells failed to complete: {bad}"
scenarios = sorted({c["scenario"] for c in cells})
assert len(scenarios) >= 4, f"too few scenario families: {scenarios}"
assert all(c["cost"] > 0 for c in cells), "a completed cell priced at $0"
print(f"scenario sweep OK: {len(cells)} cells over {scenarios}")
EOF

echo "== policy-search smoke (seeded micro-search, serial vs pool bit-identity) =="
# The search subsystem's end-to-end gate: a 2-gen x 6-individual NSGA-II
# micro-search must produce a non-empty Pareto front, and a 2-worker
# process pool must reproduce the serial run bit-for-bit (the script
# asserts both and exits non-zero on drift).
python scripts/search.py --smoke --out /tmp/SEARCH_smoke.json

echo "== sweep-pool gate (search cell runner process-pool overhead) =="
# The cell runner's perf gate: pool speedup on the fixed 12-cell sweep
# grid must stay within BENCH_REGRESSION_TOLERANCE (default 30%) of the
# committed BENCH_sched.json entry.  On the 1-core container class this
# guards pool *overhead* (committed speedup ~1.0); on wider hosts it
# guards real parallel speedup.  Machine-dependent like the other bench
# gates.
if [ "${BENCH_REGRESSION_SKIP:-0}" = "1" ]; then
    echo "sweep-pool gate skipped (BENCH_REGRESSION_SKIP=1)"
else
python benchmarks/bench_sched_throughput.py --scale none --sweep-pool \
    --out /tmp/BENCH_pool_smoke.json
python - <<'EOF'
import json
import os
tolerance = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30"))
now = json.load(open("/tmp/BENCH_pool_smoke.json"))["sweep_pool"]
assert now["identical"], "pool rows diverged from serial rows"
base = json.load(open("BENCH_sched.json"))["sweep_pool"]
floor = (1.0 - tolerance) * base["speedup"]
assert now["speedup"] >= floor, (
    f"sweep-pool regression: speedup {now['speedup']} < {floor:.2f} "
    f"(committed {base['speedup']} - {tolerance:.0%})")
print(f"sweep-pool gate OK: speedup {now['speedup']} vs committed "
      f"{base['speedup']} (floor {floor:.2f}), rows bit-identical")
EOF
fi

echo "== chaos smoke (seeded disruption schedules, parity + column audits) =="
# The disruption subsystem's end-to-end gate: per chaos scenario, the
# unspied array fast path runs with PodStore.audit_columns after every
# disruption event, both engines must produce bit-identical event logs,
# and the array trace must match the committed golden chaos fixture.
python scripts/chaos.py --smoke --out /tmp/CHAOS_smoke.json

echo "== obs smoke (flight recorder -> export -> report, end to end) =="
# The observability pipeline's end-to-end gate: record a small run, assert
# the obs-on ExperimentResult is bit-identical to obs-off, round-trip the
# event log through .npz and .json bit-exactly, check every reactive
# scale-out request is attributed in the log, and render the report +
# Chrome trace.  (Obs *off* is the default path every other gate in this
# file runs — the throughput/full-run gates against the committed
# BENCH_sched.json baselines already pin its cost to within noise.)
python scripts/obsreport.py --smoke --limit 5

echo "== obs overhead gate (obs-on wall vs obs-off, same spec) =="
# Recording is passive but not free: the obs-on wall on the flash-crowd/
# predictive stress cell must stay within REPRO_OBS_OVERHEAD_MAX (default
# 2.0x, measured ~1.6x) of obs-off, and the results must stay
# bit-identical.  Machine-dependent timing — skipped with the other bench
# gates on unrelated hardware.
if [ "${BENCH_REGRESSION_SKIP:-0}" = "1" ]; then
    echo "obs overhead gate skipped (BENCH_REGRESSION_SKIP=1)"
else
    python scripts/obsreport.py --overhead-gate
fi

echo "== trace-replay gate (100k-arrival columnar ingest, array engine) =="
# Regression gate for the trace-native submission path (Timeline ->
# submit_trace -> PodStore.ingest_trace): end-to-end pods/s on a 100k-
# arrival generated scenario vs the committed BENCH_sched.json baseline.
# Machine-dependent like the other bench gates.
if [ "${BENCH_REGRESSION_SKIP:-0}" = "1" ]; then
    echo "trace-replay gate skipped (BENCH_REGRESSION_SKIP=1)"
else
python benchmarks/bench_sched_throughput.py --scale none --trace-replay \
    --out /tmp/BENCH_trace_smoke.json
python - <<'EOF'
import json
import os
tolerance = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30"))
now = json.load(open("/tmp/BENCH_trace_smoke.json"))["trace_replay"]
assert now["completed"], "100k trace replay failed to complete"
base = json.load(open("BENCH_sched.json"))["trace_replay"]
floor = (1.0 - tolerance) * base["pods_per_s_end_to_end"]
assert now["pods_per_s_end_to_end"] >= floor, (
    f"trace-replay regression: {now['pods_per_s_end_to_end']} pods/s < "
    f"{floor:.0f} (committed {base['pods_per_s_end_to_end']} - {tolerance:.0%})")
print(f"trace-replay gate OK: {now['pods_per_s_end_to_end']} pods/s vs "
      f"committed {base['pods_per_s_end_to_end']} (floor {floor:.0f})")
EOF
fi

echo "== full-run gate (large scale, array engine) =="
# Cycle throughput alone misses regressions in the event path (arrival
# ingest, completion commits, telemetry): gate the *end-to-end* 2k-node x
# 50k-pod full-run wall time at -30% vs the committed BENCH_sched.json.
# Skipped wholesale on unrelated hardware — unlike the small smoke, this
# run exists only for the machine-dependent comparison.
if [ "${BENCH_REGRESSION_SKIP:-0}" = "1" ]; then
    echo "full-run gate skipped (BENCH_REGRESSION_SKIP=1)"
else
python benchmarks/bench_sched_throughput.py --scale large --engines array \
    --out /tmp/BENCH_sched_full_smoke.json
python - <<'EOF'
import json
import os
tolerance = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30"))
row = json.load(open("/tmp/BENCH_sched_full_smoke.json"))
full = row["scales"]["large"]["engines"]["array"]["full_run"]
assert full["completed"], "large-scale full run failed to complete"
base = json.load(open("BENCH_sched.json"))
base_wall = base["scales"]["large"]["engines"]["array"]["full_run"]["wall_s"]
# -30% throughput == wall time growing past base / (1 - tolerance).
ceiling = base_wall / (1.0 - tolerance)
assert full["wall_s"] <= ceiling, (
    f"full-run regression: {full['wall_s']}s > {ceiling:.3f}s "
    f"(committed baseline {base_wall}s + {tolerance:.0%})")
print(f"full-run gate OK: {full['wall_s']}s vs committed {base_wall}s "
      f"(ceiling {ceiling:.3f}s)")
EOF
fi

echo "== forecaster smoke (train + predict on the JAX substrate) =="
# The learned-forecaster gate: a small train run must show decreasing
# loss and a checkpoint save/load round-trip that still serves the
# online observe/predict contract (the script asserts both).  Needs JAX
# (mLSTM + jitted train step); the numpy forecast pieces are covered by
# tier-1 either way.
if ! python -c "import jax" >/dev/null 2>&1; then
    echo "forecaster smoke skipped (JAX not importable)"
else
    python scripts/forecast.py --smoke --out /tmp/FORECAST_smoke.json
fi

echo "== predictive-autoscaler gate (flash-crowd dominance vs NBAS) =="
# The predictive autoscaler must beat the paper's non-binding autoscaler
# (Alg. 5) on mean pending time at equal-or-lower cost on the burst
# scenario prediction exists for — and, since sweep cells are fully
# deterministic, reproduce the committed BENCH_sched.json baseline pair
# exactly (no tolerance: same spec, same floats).
python benchmarks/sweep_scenarios.py --scenarios flash-crowd \
    --schedulers best-fit --autoscalers non-binding,predictive \
    --jobs 600 --out /tmp/SWEEP_predictive_smoke.json
python - <<'EOF'
import json
cells = {c["autoscaler"]: c
         for c in json.load(open("/tmp/SWEEP_predictive_smoke.json"))["cells"]}
nbas, pred = cells["non-binding"], cells["predictive"]
assert pred["mean_pending_s"] < nbas["mean_pending_s"], (
    f"predictive lost on pending: {pred['mean_pending_s']} vs "
    f"NBAS {nbas['mean_pending_s']}")
assert pred["cost"] <= nbas["cost"], (
    f"predictive dominance broke on cost: {pred['cost']} vs "
    f"NBAS {nbas['cost']}")
base = json.load(open("BENCH_sched.json"))["predictive_flash"]
for name, cell in (("non-binding", nbas), ("predictive", pred)):
    for metric in ("cost", "mean_pending_s"):
        got, want = cell[metric], base[name][metric]
        assert got == want, (
            f"{name} {metric} drifted from committed baseline: "
            f"{got} != {want} (deterministic cell — regen the baseline "
            f"only with an intended behavior change)")
print(f"predictive gate OK: mean pending {pred['mean_pending_s']}s vs "
      f"NBAS {nbas['mean_pending_s']}s at cost {pred['cost']} vs "
      f"{nbas['cost']}, matching committed baseline")
EOF

echo "== many-world lane gates (parity smoke + speedup + regression) =="
# The lane evaluator's end-to-end gates.  All of them need JAX — without
# it `workers="lanes"` falls back to serial `run_cell` (covered by
# tier-1), so the perf comparison would be measuring nothing.
if ! python -c "import jax" >/dev/null 2>&1; then
    echo "many-world gates skipped (JAX not importable)"
else
# Lane-parity smoke: every scheduler in the lane envelope, two seeds —
# `workers="lanes"` must reproduce the serial rows bit-for-bit (wall_s
# excepted: a lane reports its share of the batch wall).
python - <<'EOF'
from repro.manyworld.lanes import SCHEDULERS
from repro.search.runner import CellSpec, run_cells

cells = [CellSpec(scenario="heavy-tail", scheduler=sched, autoscaler="void",
                  rescheduler="void", seed=seed, n_jobs=30,
                  initial_workers=3)
         for sched in SCHEDULERS for seed in (0, 1)]
strip = lambda rows: [{k: v for k, v in r.items() if k != "wall_s"}
                      for r in rows]
serial = run_cells(cells, workers=1)
lanes = run_cells(cells, workers="lanes")
assert strip(lanes) == strip(serial), "lane rows diverged from serial rows"
print(f"lane-parity smoke OK: {len(cells)} cells over "
      f"{len(SCHEDULERS)} schedulers, rows bit-identical")
EOF
# Speedup gate (machine-independent: lanes vs serial measured on the
# same box in the same run; the bench re-asserts row parity internally):
# the 256-lane warm batch must clear the 5x bar over serial cells.
python benchmarks/bench_manyworld.py --lanes 256 \
    --out /tmp/BENCH_manyworld_smoke.json
python - <<'EOF'
import json
import os

cur = json.load(open("/tmp/BENCH_manyworld_smoke.json"))
cur = cur["manyworld"]["per_lanes"]["256"]
assert cur["speedup_vs_serial"] >= 5.0, (
    f"lane-evaluator speedup collapsed: {cur['speedup_vs_serial']}x < 5x")
print(f"lane-speedup gate OK: {cur['speedup_vs_serial']}x at 256 lanes")
# Bench-regression gate: warm lanes/s within tolerance of the committed
# BENCH_sched.json baseline.  Machine-dependent like the other bench
# gates; skipped with BENCH_REGRESSION_SKIP=1.
if os.environ.get("BENCH_REGRESSION_SKIP") == "1":
    print("lane-regression gate skipped (BENCH_REGRESSION_SKIP=1)")
else:
    tolerance = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.30"))
    base = json.load(open("BENCH_sched.json"))["manyworld"]["per_lanes"]["256"]
    floor = (1.0 - tolerance) * base["lanes_per_s"]
    assert cur["lanes_per_s"] >= floor, (
        f"lane-evaluator regression: {cur['lanes_per_s']} lanes/s < "
        f"{floor:.0f} (committed {base['lanes_per_s']} - {tolerance:.0%})")
    print(f"lane-regression gate OK: {cur['lanes_per_s']} lanes/s vs "
          f"committed {base['lanes_per_s']} (floor {floor:.0f})")
EOF
fi
