#!/usr/bin/env python
"""Chaos harness CLI: resilience sweep + chaos-parity smoke.

Default mode runs every chaos scenario (``repro.scenarios.chaos``) through
`run_chaos_cell` and writes a Fig-3-style resilience table — recovery
times, lost work, eviction counts and the cost delta against the same
trace without disruptions::

    python scripts/chaos.py                       # full resilience table
    python scripts/chaos.py --scenarios spot-spike --seed 7
    python scripts/chaos.py --smoke               # the CI gate

``--smoke`` is the seeded CI gate.  Per scenario it

1. runs the **unspied** array engine (column-native bulk eviction path)
   with `PodStore.audit_columns` after every disruption event;
2. captures the spied event log on both engines and asserts they are
   bit-identical;
3. asserts the array trace matches the committed golden chaos fixture
   (``tests/data/golden_chaos_trace.json``, regenerate with
   ``PYTHONPATH=src python tests/test_chaos_trace.py --regen``);

then writes the resilience table for the smoke grid.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core import reset_id_counters
from repro.core.experiment import build_simulation
from repro.scenarios.chaos import (CHAOS_SCENARIOS, GOLDEN_JOBS,
                                   capture_chaos_trace, chaos_spec,
                                   run_chaos_cell)

GOLDEN_FIXTURE = os.path.join(REPO, "tests", "data",
                              "golden_chaos_trace.json")


def run_fast_path_audited(name: str, seed: int, n_jobs) -> dict:
    """Unspied array run — the column-native bulk-eviction fast path —
    with a full column audit after every disruption event."""
    reset_id_counters()
    sim = build_simulation(chaos_spec(name, seed=seed, n_jobs=n_jobs,
                                      engine="array"))
    audits = [0]

    def on_disruption(s, kind):
        s.cluster.pod_store.audit_columns(s.cluster)
        audits[0] += 1

    sim.on_disruption = on_disruption
    result = sim.run()
    assert result.completed, f"{name}: fast-path chaos run did not complete"
    assert audits[0] > 0, f"{name}: no disruption events fired"
    return {"audits": audits[0], "evictions": result.evictions,
            "failures_injected": result.failures_injected}


def smoke(seed: int, out: str) -> None:
    with open(GOLDEN_FIXTURE) as f:
        golden = json.load(f)
    cells = []
    for name in CHAOS_SCENARIOS:
        fast = run_fast_path_audited(name, seed, GOLDEN_JOBS)
        print(f"chaos.{name}: fast-path OK "
              f"({fast['audits']} audits, {fast['evictions']} evictions)")

        arr = capture_chaos_trace(name, "array", seed=seed,
                                  n_jobs=GOLDEN_JOBS)
        obj = capture_chaos_trace(name, "object", seed=seed,
                                  n_jobs=GOLDEN_JOBS)
        assert arr == obj, f"{name}: engines disagree under disruption"
        print(f"chaos.{name}: engine parity OK "
              f"({len(arr['binds'])} binds bit-identical)")

        if seed == 0:
            assert name in golden, f"{name} missing from golden chaos fixture"
            for key in golden[name]:
                assert arr[key] == golden[name][key], (
                    f"{name}: golden chaos drift in {key!r} — if intentional, "
                    f"regenerate with `PYTHONPATH=src python "
                    f"tests/test_chaos_trace.py --regen`")
            print(f"chaos.{name}: golden fixture OK")
        else:
            print(f"chaos.{name}: golden fixture skipped (seed={seed} != 0)")

        cells.append(run_chaos_cell(name, seed=seed, n_jobs=GOLDEN_JOBS))
    write_table(cells, out)
    print(f"chaos smoke OK: {len(cells)} scenarios")


def write_table(cells, out: str) -> None:
    report = {"bench": "chaos_resilience",
              "generated_unix_s": int(time.time()), "cells": cells}
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    for c in cells:
        print(f"chaos.{c['scenario']},{1e6 * c['wall_s']:.0f},"
              f"{c['cost_delta']}")
    print(f"# wrote {out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenarios",
                    help=f"default {','.join(CHAOS_SCENARIOS)}")
    ap.add_argument("--jobs", type=int, default=None,
                    help="trace length override (default: family default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="array")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: fast-path audits + engine parity + "
                         "golden chaos fixture, at the fixture's job count")
    ap.add_argument("--obs-dir", default=None,
                    help="also run each scenario with the flight recorder "
                         "attached and export <dir>/chaos.<name>.npz "
                         "(inspect with scripts/obsreport.py --load)")
    ap.add_argument("--out", default="CHAOS_resilience.json")
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(args.seed, args.out)
        return

    scenarios = (tuple(s for s in args.scenarios.split(",") if s)
                 if args.scenarios else tuple(CHAOS_SCENARIOS))
    cells = [run_chaos_cell(name, seed=args.seed, n_jobs=args.jobs,
                            engine=args.engine)
             for name in scenarios]
    write_table(cells, args.out)

    if args.obs_dir:
        from repro.obs import run_recorded
        os.makedirs(args.obs_dir, exist_ok=True)
        for name in scenarios:
            reset_id_counters()
            _result, rec = run_recorded(
                chaos_spec(name, seed=args.seed, n_jobs=args.jobs,
                           engine=args.engine))
            path = os.path.join(args.obs_dir,
                                f"chaos.{name}.seed{args.seed}.npz")
            rec.export(path)
            print(f"# obs bundle: {path} ({rec.events.n_seen} events)")


if __name__ == "__main__":
    main()
