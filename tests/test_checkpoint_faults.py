"""Fault-injection tests for `CheckpointManager.save` re-save atomicity.

Kept separate from tests/test_train_substrate.py (which is skipped wholesale
when the dev-only `hypothesis` dep is absent) so the crash-safety contract is
exercised wherever JAX itself is available.
"""
import os
import tempfile

import numpy as np
import pytest

pytest.importorskip("jax")   # checkpoint module flattens pytrees via jax

from repro.train.checkpoint import CheckpointManager


class TestResaveAtomicity:
    def test_resave_swap_failure_keeps_old_step(self, monkeypatch):
        """Fault injection: re-saving an existing step must never pass
        through a state where the step dir is deleted while LATEST still
        names it.  The old code did `rmtree(final)` before
        `rename(tmp, final)`; if the rename then failed (or the process
        died), `restore()` lost the newest valid checkpoint."""
        v1 = {"a": np.full(2, 1.0, np.float32)}
        v2 = {"a": np.full(2, 2.0, np.float32)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(7, v1)
            final = os.path.join(d, "step_00000007")
            real_rename = os.rename

            def failing_rename(src, dst):
                if dst == final and ".tmp_" in os.path.basename(src):
                    raise OSError("injected crash during swap")
                return real_rename(src, dst)

            monkeypatch.setattr(os, "rename", failing_rename)
            with pytest.raises(OSError, match="injected"):
                mgr.save(7, v2)
            monkeypatch.undo()
            restored, step, _ = mgr.restore(v1)
            assert step == 7
            np.testing.assert_array_equal(restored["a"], v1["a"])

    def test_resave_crash_between_renames_recovers_aside(self):
        """A hard crash after the old dir was parked aside but before the
        new dir landed leaves only `.step_XXXXXXXX.old` on disk; a fresh
        manager must recover it so LATEST keeps resolving."""
        v1 = {"a": np.arange(3, dtype=np.float32)}
        with tempfile.TemporaryDirectory() as d:
            CheckpointManager(d).save(4, v1)
            final = os.path.join(d, "step_00000004")
            os.rename(final, os.path.join(d, ".step_00000004.old"))
            assert not os.path.isdir(final)       # the crash-window state
            mgr = CheckpointManager(d)
            assert mgr.latest_step() == 4
            restored, step, _ = mgr.restore(v1)
            assert step == 4
            np.testing.assert_array_equal(restored["a"], v1["a"])

    def test_resave_success_replaces_and_cleans_aside(self):
        v1 = {"a": np.full(2, 1.0, np.float32)}
        v2 = {"a": np.full(2, 2.0, np.float32)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(3, v1)
            mgr.save(3, v2)
            restored, _, _ = mgr.restore(v1)
            np.testing.assert_array_equal(restored["a"], v2["a"])
            assert not os.path.exists(os.path.join(d, ".step_00000003.old"))
            assert mgr.all_steps() == [3]
