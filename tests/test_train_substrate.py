"""Unit tests: optimizer, losses, data pipeline, checkpoint manager."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # dev-only dep: requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.train import losses
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import (AdamWState, OptimizerConfig, adamw_update,
                                   clip_by_global_norm, global_norm,
                                   init_opt_state, schedule)


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                              total_steps=110, min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        end = float(schedule(cfg, jnp.asarray(110)))
        assert end == pytest.approx(0.1, abs=1e-6)

    def test_adamw_moves_toward_minimum(self):
        cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=1,
                              total_steps=200, weight_decay=0.0)
        params = {"w": jnp.asarray([[3.0, -2.0]])}
        state = init_opt_state(params)
        for _ in range(150):
            grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||^2
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.15

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_weight_decay_only_on_matrices(self):
        cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=1,
                              weight_decay=10.0)
        params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
        state = init_opt_state(params)
        zero_grads = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(cfg, params, zero_grads, state)
        assert float(jnp.max(jnp.abs(new["scale"] - 1.0))) < 1e-6   # no decay
        assert float(jnp.max(new["w"])) < 1.0                        # decayed


class TestLosses:
    def test_uniform_logits_give_log_vocab(self):
        B, T, V = 2, 8, 100
        logits = jnp.zeros((B, T, V))
        labels = jnp.zeros((B, T), jnp.int32)
        loss, m = losses.cross_entropy(logits, labels)
        assert float(loss) == pytest.approx(np.log(V), rel=1e-5)

    def test_padded_vocab_masked(self):
        B, T, V, Vp = 1, 4, 7, 16
        logits = jnp.zeros((B, T, Vp))
        labels = jnp.zeros((B, T), jnp.int32)
        loss, _ = losses.cross_entropy(logits, labels, vocab_size=V)
        assert float(loss) == pytest.approx(np.log(V), rel=1e-5)

    def test_loss_mask(self):
        logits = jnp.zeros((1, 4, 8))
        logits = logits.at[0, 0, 3].set(100.0)
        labels = jnp.asarray([[3, 0, 0, 0]], jnp.int32)
        mask = jnp.asarray([[1.0, 0.0, 0.0, 0.0]])
        loss, m = losses.cross_entropy(logits, labels, mask)
        assert float(loss) == pytest.approx(0.0, abs=1e-4)
        assert float(m["accuracy"]) == 1.0

    @settings(max_examples=10, deadline=None)
    @given(chunk=st.sampled_from([2, 4, 8]), seed=st.integers(0, 100))
    def test_chunked_ce_matches_dense(self, chunk, seed):
        B, T, D, V = 2, 16, 12, 40
        ks = jax.random.split(jax.random.key(seed), 3)
        x = jax.random.normal(ks[0], (B, T, D))
        w = jax.random.normal(ks[1], (D, 64)) * 0.1
        labels = jax.random.randint(ks[2], (B, T), 0, V)
        dense_logits = jnp.einsum("btd,dv->btv", x, w)
        want, _ = losses.cross_entropy(dense_logits, labels, vocab_size=V)
        got, _ = losses.chunked_ce(x, w, labels, None, vocab_size=V,
                                   chunk=chunk)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_chunked_ce_gradients_match(self):
        B, T, D, V = 2, 8, 12, 32
        ks = jax.random.split(jax.random.key(0), 3)
        x = jax.random.normal(ks[0], (B, T, D))
        w = jax.random.normal(ks[1], (D, V)) * 0.1
        labels = jax.random.randint(ks[2], (B, T), 0, V)

        def dense(xw):
            x_, w_ = xw
            lg = jnp.einsum("btd,dv->btv", x_, w_)
            return losses.cross_entropy(lg, labels, vocab_size=V)[0]

        def chunked(xw):
            x_, w_ = xw
            return losses.chunked_ce(x_, w_, labels, None, vocab_size=V,
                                     chunk=4)[0]

        g1 = jax.grad(dense)((x, w))
        g2 = jax.grad(chunked)((x, w))
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-4)


class TestData:
    def test_deterministic_and_step_keyed(self):
        cfg = get_config("deepseek-7b", tiny=True)
        d1 = SyntheticLM(cfg, DataConfig(batch_size=2, seq_len=16, seed=1))
        d2 = SyntheticLM(cfg, DataConfig(batch_size=2, seq_len=16, seed=1))
        np.testing.assert_array_equal(d1.batch(5)["tokens"],
                                      d2.batch(5)["tokens"])
        assert not np.array_equal(d1.batch(5)["tokens"],
                                  d1.batch(6)["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = get_config("deepseek-7b", tiny=True)
        b = SyntheticLM(cfg, DataConfig(batch_size=2, seq_len=16)).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_accum_leading_axis(self):
        cfg = get_config("deepseek-7b", tiny=True)
        b = SyntheticLM(cfg, DataConfig(batch_size=2, seq_len=16,
                                        accum=3)).batch(0)
        assert b["tokens"].shape == (3, 2, 16)


class TestCheckpoint:
    def test_save_restore_roundtrip(self):
        tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                "b": {"c": np.asarray([1, 2], np.int32)}}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            mgr.save(3, tree, extra={"note": "hi"})
            restored, step, extra = mgr.restore(tree)
            assert step == 3 and extra == {"note": "hi"}
            np.testing.assert_array_equal(restored["a"], tree["a"])

    def test_keep_n_gc(self):
        tree = {"a": np.zeros(2, np.float32)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            for s in (1, 2, 3, 4):
                mgr.save(s, tree)
            assert mgr.all_steps() == [3, 4]
            assert mgr.latest_step() == 4

    def test_latest_pointer_atomic(self):
        tree = {"a": np.zeros(2, np.float32)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, tree)
            with open(os.path.join(d, "LATEST")) as f:
                assert f.read().strip() == "step_00000001"


class TestK8sObjects:
    def test_manifest_roundtrip(self):
        from repro.k8s import from_manifest
        manifest = {
            "apiVersion": "apps/v1", "kind": "Deployment",
            "metadata": {"generateName": "nginx-"},
            "spec": {"replicas": 1, "template": {
                "metadata": {"labels": {"app": "nginx",
                                        "rescheduling": "moveable"}},
                "spec": {"schedulerName": "customScheduler",
                         "containers": [{"name": "nginx", "image": "nginx",
                                         "resources": {
                                             "requests": {"memory": "1.4Gi",
                                                          "cpu": "100m"},
                                             "limits": {"memory": "1.4Gi",
                                                        "cpu": "100m"}}}]}}},
        }
        spec = from_manifest(manifest)
        assert spec.moveable and spec.requests.cpu_m == 100
        assert spec.requests.mem_mb == pytest.approx(1.4 * 1024)

    def test_guaranteed_qos_enforced(self):
        from repro.k8s import from_manifest
        bad = {"kind": "Deployment", "metadata": {},
               "spec": {"template": {"metadata": {}, "spec": {"containers": [
                   {"resources": {"requests": {"memory": "1Gi", "cpu": "1"},
                                  "limits": {"memory": "2Gi", "cpu": "1"}}}
               ]}}}}
        with pytest.raises(ValueError):
            from_manifest(bad)
