"""Determinism contract of the parallel cell runner + seeded search.

* same seed ⇒ identical cell rows and identical Pareto fronts;
* a 2-worker process pool is **bit-identical** to the serial path —
  same floats, same result ordering (submission order, not completion
  order);
* a failing cell raises `CellError` naming the cell, and a hard worker
  death (``os._exit`` via the ``REPRO_SEARCH_TEST_CRASH`` hook) also
  surfaces as `CellError` instead of hanging the pool.
"""
import pytest

from repro.search import (CellError, CellSpec, default_space, run_cells,
                          run_search)
from repro.search.runner import _CRASH_ENV

# Small enough that the whole module stays in CI seconds; 2 scenario
# families × a handful of policy cells exercise scheduler, autoscaler,
# rescheduler and template axes.
N_JOBS = 40

CELLS = [
    CellSpec(scenario="diurnal", scheduler="best-fit", autoscaler="binding",
             rescheduler="non-binding", seed=3, n_jobs=N_JOBS),
    CellSpec(scenario="heavy-tail", scheduler="weighted",
             autoscaler="non-binding", rescheduler="binding", seed=3,
             n_jobs=N_JOBS, scheduler_weights=(0.5, 0.3, 0.2),
             scale_out_bypass_util=0.8, scale_in_util_ceiling=0.6),
    CellSpec(scenario="diurnal", scheduler="k8s-default", autoscaler="binding",
             rescheduler="void", seed=3, n_jobs=N_JOBS,
             template_name="m2.medium"),
    CellSpec(scenario="heavy-tail", scheduler="best-fit",
             autoscaler="non-binding", rescheduler="non-binding", seed=3,
             n_jobs=N_JOBS, max_pod_age_s=30.0, provisioning_interval_s=20.0),
    CellSpec(scenario="flash-crowd", scheduler="best-fit",
             autoscaler="binding", rescheduler="void", seed=3, n_jobs=N_JOBS,
             template_name="m2.tiny"),   # infeasible: exercises short-circuit
]


def test_same_seed_same_rows():
    a = run_cells(CELLS, workers=1)
    b = run_cells(CELLS, workers=1)
    for ra, rb in zip(a, b):
        ra.pop("wall_s"), rb.pop("wall_s")
        assert ra == rb     # bit-identical floats, not approx


def test_parallel_bit_identical_to_serial_and_stable_order():
    serial = run_cells(CELLS, workers=1)
    parallel = run_cells(CELLS, workers=2)
    assert [r["label"] for r in parallel] == [c.label for c in CELLS]
    for rs, rp in zip(serial, parallel):
        rs.pop("wall_s"), rp.pop("wall_s")   # the only nondeterministic key
        assert rs == rp     # == on raw floats: bit-identical or bust


def test_infeasible_cell_short_circuits():
    [row] = run_cells([CELLS[4]], workers=1)
    assert row["infeasible"] is True
    assert row["completed"] is False
    assert row["cost"] == 0 and row["wall_s"] == 0.0


def test_failing_cell_raises_cell_error_naming_it():
    bad = CellSpec(scenario="no-such-scenario", seed=0, n_jobs=N_JOBS)
    with pytest.raises(CellError, match="no-such-scenario"):
        run_cells([bad], workers=1)
    with pytest.raises(CellError, match="no-such-scenario"):
        run_cells([bad, CELLS[0]], workers=2)


def test_worker_crash_surfaces_error_not_hang(monkeypatch):
    crash = CELLS[0]
    monkeypatch.setenv(_CRASH_ENV, crash.label)
    with pytest.raises(CellError, match=crash.scenario):
        run_cells([crash] + CELLS[1:3], workers=2)


def test_search_same_seed_identical_front_serial_vs_parallel():
    space = default_space()
    kwargs = dict(generations=1, pop_size=4, seed=11, n_jobs=N_JOBS)
    a = run_search(space, ("diurnal", "heavy-tail"), workers=1, **kwargs)
    b = run_search(space, ("diurnal", "heavy-tail"), workers=1, **kwargs)
    c = run_search(space, ("diurnal", "heavy-tail"), workers=2, **kwargs)
    for other in (b, c):
        assert [i.vector for i in a.front] == [i.vector for i in other.front]
        assert ([i.objectives for i in a.front]
                == [i.objectives for i in other.front])   # bit-identical
        assert a.history == other.history
    # Fronts are genuinely non-dominated and vector-sorted (stable order).
    vecs = [i.vector for i in a.front]
    assert vecs == sorted(vecs)
