import os
import sys

# Make `import repro` work without installation (PYTHONPATH=src also works).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single-device CPU; only launch/dryrun.py
# forces 512 placeholder devices (see the system design brief).
