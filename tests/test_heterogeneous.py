"""Heterogeneous instance types (paper §8 direction): type choice, pricing,
and end-to-end cost improvement over homogeneous autoscaling."""
import pytest

from repro.core import (Cluster, CostModel, ExperimentSpec, Orchestrator,
                        Resources, SimConfig, Simulation,
                        BestFitBinPackingScheduler, NonBindingRescheduler,
                        gi, run_experiment)
from repro.core.heterogeneous import (NECTAR_CATALOG,
                                      HeterogeneousBindingAutoscaler,
                                      HeterogeneousProvider, InstanceCatalog,
                                      InstanceType)
from repro.core.workload import generate_workload


def test_cheapest_fitting_picks_smallest_feasible():
    small = NECTAR_CATALOG.cheapest_fitting(Resources(100, gi(1.0)))
    assert small.name == "m2.tiny"
    med = NECTAR_CATALOG.cheapest_fitting(Resources(100, gi(2.4)))
    assert med.name == "m2.small"
    big = NECTAR_CATALOG.cheapest_fitting(Resources(1500, gi(5.0)))
    assert big.name == "m2.medium"
    assert NECTAR_CATALOG.cheapest_fitting(Resources(100, gi(50.0))) is None


def _run_hetero(workload="slow", seed=0):
    cost = CostModel()
    provider = HeterogeneousProvider(NECTAR_CATALOG, cost)
    cluster = Cluster()
    cluster.add_node(provider.make_static_node(NECTAR_CATALOG.types[1], 0.0))
    orch = Orchestrator(cluster, BestFitBinPackingScheduler(),
                        NonBindingRescheduler(max_pod_age_s=60.0),
                        HeterogeneousBindingAutoscaler(provider))
    sim = Simulation(orch, cost, generate_workload(workload, seed=seed),
                     config=SimConfig())
    provider.attach(sim)
    result = sim.run()
    result.workload = workload
    return result, provider


def test_hetero_workload_completes_and_uses_multiple_types():
    result, provider = _run_hetero(seed=0)
    assert result.completed
    assert len(set(provider.launched_types)) >= 2, provider.launched_types


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_hetero_cheaper_than_homogeneous_on_average(seed):
    """The paper's §8 hypothesis: type-aware provisioning reduces cost.
    Right-sizing small pods onto m2.tiny should not cost MORE than
    homogeneous m2.small autoscaling (same policies otherwise)."""
    hetero, _ = _run_hetero(seed=seed)
    homo = run_experiment(ExperimentSpec(
        workload="slow", rescheduler="non-binding", autoscaler="binding",
        seed=seed))
    assert hetero.completed and homo.completed
    assert hetero.cost <= homo.cost * 1.10   # never much worse
