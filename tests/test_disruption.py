"""Disruption machinery: spot reclaims, zone outages, crash-loops, the
BindingAutoscaler stranded-pod leak fix, provisioning-race recovery, and
billing double-provision/deprovision errors."""
import dataclasses

import pytest

from repro.cloud.adapter import M2_SMALL
from repro.core import (Arrival, Cluster, CostModel, CrashLoopInjector,
                        ExperimentSpec, Node, NodeState, Resources,
                        SpotReclaimInjector, StragglerInjector,
                        reset_id_counters, run_experiment)
from repro.core.autoscaler import BindingAutoscaler, NodeProvider
from repro.core.experiment import build_simulation
from repro.core.heterogeneous import (NECTAR_CATALOG,
                                      HeterogeneousBindingAutoscaler,
                                      HeterogeneousProvider)
from repro.core.pods import Pod
from repro.core.scheduler import BestFitBinPackingScheduler
from repro.core.simulation import ZONE_OUTAGE
from repro.core.workload import JOB_TYPES, make_fleet_job_types


class _StubProvider(NodeProvider):
    """Launches PROVISIONING nodes without a simulation attached."""

    def __init__(self):
        self.launched = 0

    def launch_node(self, now: float) -> Node:
        self.launched += 1
        return Node(allocatable=M2_SMALL.allocatable,
                    node_type=M2_SMALL.name, autoscaled=True,
                    provision_time=now)

    def terminate_node(self, node: Node, now: float) -> None:
        pass


class TestBindingAutoscalerLeak:
    def test_node_lost_while_provisioning_releases_pods(self):
        """The stranded-pod leak: a node dying while PROVISIONING used to
        leave its tracker and pod associations behind, so the associated
        pods could never trigger another launch."""
        provider = _StubProvider()
        bas = BindingAutoscaler(provider)
        cluster = Cluster()
        pod = Pod(spec=JOB_TYPES["batch_small"], submit_time=0.0)

        bas.scale_out(cluster, pod, 0.0)
        assert provider.launched == 1
        node = next(iter(bas._tracked.values())).node
        assert node.state == NodeState.PROVISIONING

        # Still associated: re-requesting must not launch again.
        bas.scale_out(cluster, pod, 5.0)
        assert provider.launched == 1

        bas.notify_node_lost(node)
        assert not bas._tracked and not bas._pod_to_node

        # Association released: the pod can now get replacement capacity.
        bas.scale_out(cluster, pod, 10.0)
        assert provider.launched == 2

    def test_notify_node_lost_unknown_node_is_noop(self):
        bas = BindingAutoscaler(_StubProvider())
        node = Node(allocatable=M2_SMALL.allocatable, autoscaled=True)
        bas.notify_node_lost(node)   # never tracked: must not raise


@dataclasses.dataclass
class _ProvisioningKiller:
    """Test injector: poll every ``period_s`` and fail any node still in
    PROVISIONING — the race the leak fix exists for — until ``max_kills``
    nodes have died.  Speaks the ZONE_OUTAGE payload protocol
    (``on_outage``); polling stops once the budget is spent so the
    timeline can drain."""

    period_s: float = 20.0
    max_kills: int = 3
    killed: int = 0

    def prime(self, sim) -> None:
        sim.push(self.period_s, ZONE_OUTAGE, self)

    def arm_node(self, sim, node) -> None:
        pass

    def on_outage(self, sim) -> None:
        for node in list(sim.cluster.nodes.values()):
            if (self.killed < self.max_kills
                    and node.state == NodeState.PROVISIONING):
                self.killed += 1
                sim.fail_node(node)
        if self.killed < self.max_kills:
            sim.push(sim.now + self.period_s, ZONE_OUTAGE, self)


class TestProvisioningRaces:
    @pytest.mark.parametrize("engine", ["array", "object"])
    def test_fail_during_provisioning_recovers(self, engine):
        """Nodes killed mid-boot must not strand their associated pods:
        the workload still completes because notify_node_lost releases
        the associations and the next cycle launches replacements."""
        reset_id_counters()
        killer = _ProvisioningKiller()
        spec = ExperimentSpec(workload="slow", rescheduler="non-binding",
                              autoscaler="binding", seed=0, engine=engine,
                              failure_injector=killer)
        r = run_experiment(spec)
        assert killer.killed > 0, "no provisioning node was ever killed"
        assert r.completed
        assert r.failures_injected == killer.killed

    def test_both_engines_agree_under_provisioning_kills(self):
        results = []
        for engine in ("array", "object"):
            reset_id_counters()
            spec = ExperimentSpec(
                workload="slow", rescheduler="non-binding",
                autoscaler="binding", seed=0, engine=engine,
                failure_injector=_ProvisioningKiller(max_kills=2))
            results.append(run_experiment(spec).as_dict())
        assert results[0] == results[1]


class TestSpotReclaim:
    @pytest.mark.parametrize("engine", ["array", "object"])
    def test_reclaim_mid_wave_recovers(self, engine):
        reset_id_counters()
        inj = SpotReclaimInjector(default_mtbr_s=400.0, notice_s=60.0,
                                  seed=11)
        spec = ExperimentSpec(workload="slow", rescheduler="non-binding",
                              autoscaler="binding", seed=0, engine=engine,
                              failure_injector=inj)
        r = run_experiment(spec)
        assert r.completed
        assert r.preemption_notices > 0
        assert r.failures_injected > 0
        assert r.evictions >= r.failures_injected

    def test_engines_bit_identical_under_reclaims(self):
        results = []
        for engine in ("array", "object"):
            reset_id_counters()
            spec = ExperimentSpec(
                workload="mixed", rescheduler="non-binding",
                autoscaler="binding", seed=3, engine=engine,
                failure_injector=SpotReclaimInjector(
                    default_mtbr_s=500.0, notice_s=60.0, seed=5))
            results.append(run_experiment(spec).as_dict())
        assert results[0] == results[1]

    def test_fast_path_matches_spied_object_path(self):
        """The unspied array run takes the column-native bulk-eviction
        fast path; spying on_unbind forces per-pod materialization.  The
        two must produce the identical ExperimentResult."""
        def run(spied: bool) -> dict:
            reset_id_counters()
            spec = ExperimentSpec(
                workload="mixed", rescheduler="non-binding",
                autoscaler="binding", seed=3, engine="array",
                failure_injector=SpotReclaimInjector(
                    default_mtbr_s=500.0, notice_s=60.0, seed=5))
            sim = build_simulation(spec)
            if spied:
                inner = sim.cluster.on_unbind
                def on_unbind(pod):
                    inner(pod)
                sim.cluster.on_unbind = on_unbind
            return sim.run().as_dict()

        assert run(spied=False) == run(spied=True)

    def test_unlisted_type_with_no_default_is_never_reclaimed(self):
        reset_id_counters()
        inj = SpotReclaimInjector(reclaim_mtbr_s={"other-type": 100.0},
                                  default_mtbr_s=None, seed=1)
        spec = ExperimentSpec(workload="bursty", rescheduler="non-binding",
                              autoscaler="binding", seed=0,
                              failure_injector=inj)
        r = run_experiment(spec)
        assert r.completed
        assert r.preemption_notices == 0 and r.failures_injected == 0


class TestCrashLoop:
    def test_restart_budget_and_backoff(self):
        types = make_fleet_job_types()
        from repro.cloud.adapter import TPU_V5E_HOST
        reset_id_counters()
        inj = CrashLoopInjector(mtbc_s=60.0, seed=2, restart_budget=2,
                                backoff_base_s=30.0)
        arrivals = [Arrival(0.0, types["train_large"])]   # one 15 min job
        spec = ExperimentSpec(workload="fleet", arrivals=arrivals,
                              template=TPU_V5E_HOST, initial_workers=1,
                              rescheduler="void", autoscaler="binding",
                              failure_injector=inj)
        r = run_experiment(spec)
        assert r.completed
        counts = inj.crash_counts()
        assert counts, "the lone job was never crashed"
        assert all(c <= inj.restart_budget for c in counts.values())
        # With mtbc 60 s on a multi-incarnation 900 s job, the budget is
        # the only thing stopping further crashes: it must be exhausted.
        assert max(counts.values()) == inj.restart_budget
        assert r.evictions >= sum(counts.values())


class TestStragglerWiring:
    def test_injector_slows_launched_nodes_end_to_end(self):
        reset_id_counters()
        straggler = StragglerInjector(every_k=2, slow_factor=0.5)
        spec = ExperimentSpec(workload="slow", rescheduler="non-binding",
                              autoscaler="binding", seed=0,
                              straggler_injector=straggler,
                              straggler_threshold=0.8)
        r = run_experiment(spec)
        assert r.completed
        assert straggler._count > 0, "no launched node passed the injector"

    def test_slow_nodes_actually_marked(self):
        straggler = StragglerInjector(every_k=2, slow_factor=0.5)
        nodes = [Node(allocatable=Resources(940, 3584), autoscaled=True)
                 for _ in range(4)]
        factors = [straggler.maybe_slow(n).speed_factor for n in nodes]
        assert factors == [1.0, 0.5, 1.0, 0.5]


class TestHeterogeneousReplacement:
    def test_replacement_matches_reclaimed_instance_type(self):
        class _FakeSim:
            def schedule_node_ready(self, node, at):
                pass

        cost = CostModel()
        provider = HeterogeneousProvider(NECTAR_CATALOG, cost)
        provider.attach(_FakeSim())
        bas = HeterogeneousBindingAutoscaler(provider)
        cluster = Cluster()
        tiny = NECTAR_CATALOG.type_by_name("m2.tiny")
        node = provider.make_static_node(tiny, 0.0)
        cluster.add_node(node)
        pod = Pod(spec=JOB_TYPES["batch_small"], submit_time=0.0)
        assert BestFitBinPackingScheduler().schedule(cluster, pod, 0.0)

        bas.notify_preemption_notice(cluster, node, 10.0)
        assert provider.launched_types == ["m2.tiny"]
        # One replacement per reclaimed node, ever.
        bas.notify_preemption_notice(cluster, node, 11.0)
        assert provider.launched_types == ["m2.tiny"]

    def test_empty_node_gets_no_replacement(self):
        class _FakeSim:
            def schedule_node_ready(self, node, at):
                pass

        provider = HeterogeneousProvider(NECTAR_CATALOG, CostModel())
        provider.attach(_FakeSim())
        bas = HeterogeneousBindingAutoscaler(provider)
        cluster = Cluster()
        node = provider.make_static_node(NECTAR_CATALOG.types[0], 0.0)
        cluster.add_node(node)
        bas.notify_preemption_notice(cluster, node, 5.0)
        assert provider.launched_types == []


class TestCostModelErrors:
    def _node(self):
        return Node(allocatable=M2_SMALL.allocatable,
                    node_type=M2_SMALL.name, autoscaled=True)

    def test_double_provision_raises_value_error(self):
        cost, node = CostModel(), self._node()
        cost.on_provision(node, 0.0)
        with pytest.raises(ValueError, match="double provision"):
            cost.on_provision(node, 5.0)

    def test_double_deprovision_raises_value_error(self):
        cost, node = CostModel(), self._node()
        cost.on_provision(node, 0.0)
        cost.on_deprovision(node, 5.0)
        with pytest.raises(ValueError, match="no open billing record"):
            cost.on_deprovision(node, 6.0)

    def test_unknown_node_deprovision_raises_value_error(self):
        with pytest.raises(ValueError, match="no open billing record"):
            CostModel().on_deprovision(self._node(), 1.0)
