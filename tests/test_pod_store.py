"""PodStore parity: SoA pod columns + on-demand shells vs. seed Pod objects.

Three layers:

* **Shell-view property** — randomized interleavings of submit / bind /
  complete / fail, replayed through (a) the store fast path (bulk ingest,
  ``bind_wave_store`` / ``complete_wave_store`` column commits) and (b) the
  seed object path (``Pod`` construction + ``cluster.bind/complete/unbind``),
  must yield identical ``Pod`` attribute views — including shells that
  materialize mid-sequence and keep mutating afterwards.  A numpy-seeded
  driver always runs; a hypothesis wrapper widens the search when the
  dependency is installed.
* **Bulk arrival-merge** — ``submit_wave``'s append-only arrival stream +
  eviction heap must snapshot in exactly the order one-at-a-time heappush
  produces, including equal ``pending_since`` ties broken by uid.
* **Store consistency** — ``PodStore.verify_against`` cross-checks columns,
  shells and node residency after every scripted interleaving.
"""
import heapq

import numpy as np
import pytest

from repro.core import (Arrival, Cluster, Node, Pod, PodKind, PodSpec,
                        Resources, gi, reset_id_counters)
from repro.core.engine import POD_PENDING, PodStore
from repro.core.orchestrator import Orchestrator

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False


SPECS = [
    PodSpec("ps-batch-s", PodKind.BATCH, Resources(100, gi(0.3)),
            duration_s=120.0),
    PodSpec("ps-batch-l", PodKind.BATCH, Resources(300, gi(0.9)),
            duration_s=300.0),
    PodSpec("ps-svc", PodKind.SERVICE, Resources(200, gi(0.6)),
            moveable=True),
    PodSpec("ps-svc-pin", PodKind.SERVICE, Resources(100, gi(0.4))),
]

# Attributes a shell must reproduce bit-for-bit (the full observable Pod
# surface minus `spec`, which is asserted to be the identical object).
POD_ATTRS = ("uid", "phase", "node_id", "submit_time", "pending_since",
             "bound_time", "finish_time", "incarnation", "progress_s",
             "checkpointed_s", "pending_intervals", "requests", "is_batch",
             "is_service", "moveable")

N_NODES = 4


def _script(rng, n_ops):
    """A backend-agnostic op script: every random choice is made here, so
    both replays perform the identical sequence.

    The script mirrors the replays' queue model — pending kept in uid
    order, bound in bind order — so it can address pods by index and knows
    each bound pod's kind (only batch pods may complete, exactly like the
    simulator)."""
    ops = []
    t = 0.0
    uid = 0
    pending = []        # (model uid, spec idx), uid order
    bound = []          # (model uid, spec idx), bind order
    for _ in range(n_ops):
        t += float(rng.integers(1, 30))
        roll = int(rng.integers(0, 10))
        batch_positions = [i for i, (_, s) in enumerate(bound)
                           if SPECS[s].kind == PodKind.BATCH]
        if roll < 4 or (not pending and not bound):
            k = int(rng.integers(1, 4))
            spec_idxs = [int(rng.integers(0, len(SPECS))) for _ in range(k)]
            ops.append(("submit", t, spec_idxs))
            for s in spec_idxs:
                pending.append((uid, s))
                uid += 1
        elif roll < 7 and pending:
            k = int(rng.integers(0, len(pending)))
            ops.append(("bind", t, k, int(rng.integers(0, N_NODES))))
            bound.append(pending.pop(k))
        elif roll < 8 and batch_positions:
            k = int(rng.integers(0, len(batch_positions)))
            ops.append(("complete", t, batch_positions[k]))
            bound.pop(batch_positions[k])
        elif roll < 9 and bound:
            k = int(rng.integers(0, len(bound)))
            ops.append(("fail", t, k, bool(rng.integers(0, 2))))
            pending.append(bound.pop(k))
            pending.sort()
        else:
            # Materialize a shell mid-sequence (API-boundary probe); the
            # index is resolved against live rows at replay time.
            ops.append(("materialize", t, int(rng.integers(0, 1 << 16))))
    return ops


def _mk_nodes(cluster):
    for i in range(N_NODES):
        node = Node(allocatable=Resources(100_000, gi(400.0)),
                    node_id=f"store-n{i}")
        node.mark_ready(0.0)
        cluster.add_node(node)


def _replay_store(ops):
    """Replay through the PodStore fast path (no Pod objects unless an op
    forces a boundary crossing)."""
    reset_id_counters()
    cluster = Cluster(use_arrays=True)
    store = PodStore(cluster.arrays)
    cluster.pod_store = store
    _mk_nodes(cluster)
    pending = []        # rows, uid order
    bound = []          # rows, bind order
    for op in ops:
        kind = op[0]
        if kind == "submit":
            _, t, spec_idxs = op
            rows, _uids = store.ingest(
                [Arrival(t, SPECS[s]) for s in spec_idxs])
            pending.extend(rows)
        elif kind == "bind":
            _, t, k, node_idx = op
            row = pending.pop(k)
            node = cluster.nodes[f"store-n{node_idx}"]
            cluster.bind_wave_store([(row, node._slot)], t)
            bound.append(row)
        elif kind == "complete":
            _, t, k = op
            row = bound.pop(k)
            cluster.complete_wave_store([row], t)
        elif kind == "fail":
            _, t, k, failed = op
            row = bound.pop(k)
            # Eviction is an object API: the shell materializes here.
            cluster.unbind(store.pod_at(row), t, failed=failed)
            pending.append(row)
            pending.sort(key=lambda r: store.uid[r])
        elif kind == "materialize":
            _, _t, pick = op
            if store.n_rows:
                store.pod_at(pick % store.n_rows)
        cluster.check_invariants(deep=True)
        store.verify_against(cluster)
    # Final views: materialize everything (the API boundary the satellite
    # is about) and snapshot the attribute surface.
    views = {}
    for row in range(store.n_rows):
        pod = store.pod_at(row)
        views[pod.uid] = ([getattr(pod, a) for a in POD_ATTRS], pod.spec)
    store.verify_against(cluster)
    return views


def _replay_object(ops):
    """The seed-semantics reference: real Pods from day one."""
    reset_id_counters()
    cluster = Cluster(use_arrays=False)
    _mk_nodes(cluster)
    pods = []
    pending = []
    bound = []
    for op in ops:
        kind = op[0]
        if kind == "submit":
            _, t, spec_idxs = op
            for s in spec_idxs:
                pod = Pod(spec=SPECS[s], submit_time=t)
                pods.append(pod)
                pending.append(pod)
        elif kind == "bind":
            _, t, k, node_idx = op
            pod = pending.pop(k)
            cluster.bind(pod, cluster.nodes[f"store-n{node_idx}"], t)
            bound.append(pod)
        elif kind == "complete":
            _, t, k = op
            cluster.complete(bound.pop(k), t)
        elif kind == "fail":
            _, t, k, failed = op
            pod = bound.pop(k)
            cluster.unbind(pod, t, failed=failed)
            pending.append(pod)
            pending.sort(key=lambda p: p.uid)
        # "materialize" is a no-op on the object path
        cluster.check_invariants(deep=True)
    return {p.uid: ([getattr(p, a) for a in POD_ATTRS], p.spec)
            for p in pods}


def _assert_views_identical(store_views, object_views):
    assert store_views.keys() == object_views.keys()
    for uid, (vals, spec) in object_views.items():
        got_vals, got_spec = store_views[uid]
        assert got_spec is spec, f"uid {uid}: shell spec is not the object"
        for name, want, got in zip(POD_ATTRS, vals, got_vals):
            assert got == want, f"uid {uid}: {name} {got!r} != {want!r}"


class TestPodStoreShellParity:
    """Satellite: randomized submit/bind/complete/fail interleavings yield
    identical Pod attribute views from the SoA columns and the seed object
    path — including shells that materialize mid-sequence."""

    @pytest.mark.parametrize("seed", range(10))
    def test_random_interleavings(self, seed):
        rng = np.random.default_rng(seed)
        ops = _script(rng, 120)
        _assert_views_identical(_replay_store(ops), _replay_object(ops))

    def test_shell_identity_is_stable(self):
        """Materializing twice returns the same object, and a shell keeps
        tracking column state mutated through later fast-path commits."""
        reset_id_counters()
        cluster = Cluster(use_arrays=True)
        store = PodStore(cluster.arrays)
        cluster.pod_store = store
        _mk_nodes(cluster)
        rows, _ = store.ingest([Arrival(5.0, SPECS[0])])
        row = rows[0]
        pod = store.pod_at(row)
        assert store.pod_at(row) is pod
        assert pod.phase.value == "pending"
        node = cluster.nodes["store-n0"]
        cluster.bind_wave_store([(row, node._slot)], 7.0)
        # The shell existed before the fast-path bind: the commit must have
        # gone through the object transition, not just the columns.
        assert pod.phase.value == "bound"
        assert pod.node_id == "store-n0"
        assert pod.bound_time == 7.0
        assert pod.pending_intervals == [2.0]
        cluster.complete_wave_store([row], 100.0)
        assert pod.phase.value == "succeeded"
        assert pod.finish_time == 100.0
        store.verify_against(cluster)


if HAVE_HYPOTHESIS:
    class TestPodStoreShellParityHypothesis:
        @settings(max_examples=30, deadline=None)
        @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
               n_ops=st.integers(min_value=5, max_value=150))
        def test_random_interleavings(self, seed, n_ops):
            rng = np.random.default_rng(seed)
            ops = _script(rng, n_ops)
            _assert_views_identical(_replay_store(ops), _replay_object(ops))


def _null_orchestrator():
    from repro.core.autoscaler import VoidAutoscaler
    from repro.core.rescheduler import VoidRescheduler
    from repro.core.scheduler import BestFitBinPackingScheduler

    class _NullProvider:
        def launch_node(self, now):
            raise AssertionError("no launches expected")

        def terminate_node(self, node, now):
            pass

    cluster = Cluster(use_arrays=True)
    node = Node(allocatable=Resources(1_000_000, gi(4000.0)),
                node_id="merge-n0")
    node.mark_ready(0.0)
    cluster.add_node(node)
    return Orchestrator(cluster, BestFitBinPackingScheduler(),
                        VoidRescheduler(max_pod_age_s=0.0),
                        VoidAutoscaler(_NullProvider()))


class TestBulkArrivalMerge:
    """Satellite: arrival batches merged into the pending columns agree with
    one-at-a-time heappush ordering, including equal pending_since ties
    broken by uid."""

    def test_batches_with_ties_match_heappush_order(self):
        reset_id_counters()
        orch = _null_orchestrator()
        store = orch.store
        reference = []
        # Batches with duplicate timestamps inside and *across* batches.
        for batch_times in ([0.0, 0.0, 5.0], [5.0, 5.0], [5.0, 9.0, 9.0]):
            arrivals = [Arrival(t, SPECS[i % len(SPECS)])
                        for i, t in enumerate(batch_times)]
            orch.submit_wave(arrivals)
        for row in range(store.n_rows):
            heapq.heappush(reference,
                           (store.pending_since[row], store.uid[row], row))
        expected = [heapq.heappop(reference)[2] for _ in range(store.n_rows)]
        assert orch.pending_rows() == expected

    @pytest.mark.parametrize("seed", range(6))
    def test_randomized_batches_and_evictions(self, seed):
        """Multiple snapshot windows with interleaved eviction re-pends:
        every snapshot must equal the heappush reference over the live
        pending set, with stale entries (bound since) dropped."""
        rng = np.random.default_rng(seed)
        reset_id_counters()
        orch = _null_orchestrator()
        cluster = orch.cluster
        store = orch.store
        node = cluster.nodes["merge-n0"]
        t = 0.0
        bound_rows = []
        for _window in range(6):
            # 1-3 arrival batches, nondecreasing times, deliberate ties.
            for _ in range(int(rng.integers(1, 4))):
                n = int(rng.integers(1, 6))
                times = sorted(t + float(x)
                               for x in rng.integers(0, 4, size=n))
                orch.submit_wave([Arrival(tt, SPECS[int(rng.integers(
                    0, len(SPECS)))]) for tt in times])
                t = max([t] + times)
            snapshot = orch.pending_rows()
            # Reference: all live pending rows through a heap, keyed
            # exactly like the seed queue.
            ref_heap = []
            for row in range(store.n_rows):
                if store.phase[row] == POD_PENDING:
                    heapq.heappush(ref_heap, (store.pending_since[row],
                                              store.uid[row], row))
            expected = [heapq.heappop(ref_heap)[2] for _ in range(len(ref_heap))]
            assert snapshot == expected
            # Bind a random prefix slice, evict some (re-pends push into the
            # heap stream with pending_since == t, tying with arrivals).
            for row in snapshot[:int(rng.integers(0, len(snapshot) + 1))]:
                cluster.bind_wave_store([(row, node._slot)], t)
                bound_rows.append(row)
            while bound_rows and rng.integers(0, 2):
                row = bound_rows.pop(int(rng.integers(0, len(bound_rows))))
                cluster.unbind(store.pod_at(row), t)
            store.verify_against(cluster)
